"""Observability plane (repro.obs): metrics registry, span tracer, arbiter
audit, schema-versioned serialization, and the end-to-end telemetry bundle
from a mixed-workload run.

The contract under test: telemetry off is free (shared no-op handles, no
records), telemetry on is complete (every serve/train/pool/energy/thermal
stat in one schema, every phase a span, every migration an audit record
carrying the scores that decided it)."""
import io
import json

import numpy as np
import pytest

from repro import obs
from repro.engine.timeline import Timeline
from repro.obs.metrics import NOOP, MetricsRegistry
from repro.obs.schema import SCHEMA_VERSION, encode_record, versioned
from repro.obs.trace import _NOOP_SPAN, SpanTracer


@pytest.fixture(autouse=True)
def _isolated_telemetry():
    """Fresh disabled global per test; whatever a test installs (including
    CLI mains calling obs.enable()) is torn back down afterwards."""
    prev = obs.set_telemetry(obs.Telemetry(enabled=False))
    yield
    obs.set_telemetry(prev)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_labels_round_trip():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "requests seen")
    c.labels(job="serve", outcome="ok").inc()
    c.labels(job="serve", outcome="ok").inc(2)
    c.labels(outcome="shed", job="serve").inc()  # label order is irrelevant
    assert c.value(job="serve", outcome="ok") == 3.0
    assert c.value(outcome="ok", job="serve") == 3.0
    assert c.value(job="serve", outcome="shed") == 1.0
    assert c.value(job="serve", outcome="missing") is None

    g = reg.gauge("occupancy")
    g.set(0.25)
    g.set(0.75)  # gauges overwrite
    assert g.value() == 0.75

    snap = reg.snapshot()
    assert snap["schema_version"] == SCHEMA_VERSION
    series = snap["metrics"]["requests_total"]["series"]
    assert {"labels": {"job": "serve", "outcome": "ok"}, "value": 3.0} in series

    line = reg.snapshot_line(7)
    assert line["tick"] == 7
    assert line["metrics"]["requests_total{job=serve,outcome=ok}"] == 3.0
    assert line["metrics"]["occupancy"] == 0.75


def test_metrics_histogram_quantiles():
    reg = MetricsRegistry()
    h = reg.histogram("latency_s")
    for v in range(1, 101):  # 1..100
        h.observe(float(v))
    s = h.value()
    assert s["count"] == 100 and s["min"] == 1.0 and s["max"] == 100.0
    assert s["mean"] == pytest.approx(50.5)
    assert h.quantile(0.5) == pytest.approx(50.5)
    assert h.quantile(0.0) == 1.0 and h.quantile(1.0) == 100.0
    assert s["p99"] == pytest.approx(99.01)
    # ring buffer: quantiles track the most recent cap samples
    hc = reg.histogram("small", max_samples=4)
    for v in [1.0, 2.0, 3.0, 4.0, 100.0]:
        hc.labels(job="x").observe(v)
    assert hc.value(job="x")["count"] == 5  # count/sum stay exact
    assert hc.value(job="x")["max"] == 100.0
    assert hc.quantile(1.0, job="x") == 100.0  # 1.0 evicted from the ring
    assert hc.quantile(0.0, job="x") == 2.0


def test_metrics_disabled_is_shared_noop():
    reg = MetricsRegistry(enabled=False)
    c = reg.counter("a")
    g = reg.gauge("b")
    h = reg.histogram("c")
    assert c is NOOP and g is NOOP and h is NOOP
    assert c.labels(job="x") is NOOP
    # all mutations are free no-ops and nothing is registered
    c.inc()
    g.set(1.0)
    h.observe(2.0)
    assert reg.names() == []
    assert reg.snapshot()["metrics"] == {}


def test_metrics_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(TypeError, match="registered as counter"):
        reg.gauge("m")
    with pytest.raises(TypeError, match="is a counter"):
        reg.counter("m").set(1.0)


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------


def test_tracer_nesting_and_chrome_trace_ordering():
    tr = SpanTracer()
    with tr.span("tick", tick=0):
        with tr.span("step", job="train"):
            pass
        with tr.span("decode", batch=2):
            pass
    recs = {s.name: s for s in tr.spans()}
    assert recs["tick"].depth == 0
    assert recs["step"].depth == 1 and recs["decode"].depth == 1
    # children are contained in the parent interval, and ordered
    tick, step, dec = recs["tick"], recs["step"], recs["decode"]
    assert tick.ts_us <= step.ts_us
    assert step.ts_us + step.dur_us <= dec.ts_us
    assert dec.ts_us + dec.dur_us <= tick.ts_us + tick.dur_us

    doc = tr.chrome_trace()
    doc2 = json.loads(json.dumps(doc))  # must be strict-JSON serializable
    xs = [e for e in doc2["traceEvents"] if e["ph"] == "X"]
    assert [e["name"] for e in xs] == ["step", "decode", "tick"]  # exit order
    assert all(e["pid"] == 1 and "ts" in e and "dur" in e for e in xs)
    assert xs[0]["args"] == {"job": "train"}
    metas = [e for e in doc2["traceEvents"] if e["ph"] == "M"]
    assert any(e["name"] == "process_name" for e in metas)
    assert any(e["name"] == "thread_name" for e in metas)
    assert doc2["otherData"]["schema_version"] == SCHEMA_VERSION
    assert doc2["otherData"]["dropped_spans"] == 0


def test_tracer_disabled_returns_shared_noop_span():
    tr = SpanTracer(enabled=False)
    s1 = tr.span("a", k=1)
    s2 = tr.span("b")
    assert s1 is _NOOP_SPAN and s2 is _NOOP_SPAN
    with s1:
        pass
    assert tr.spans() == []


def test_tracer_records_exception_and_reraises():
    tr = SpanTracer()
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    (rec,) = tr.spans()
    assert rec.args["error"] == "ValueError"


def test_tracer_cap_counts_drops():
    tr = SpanTracer(max_spans=2)
    for i in range(4):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.spans()) == 2 and tr.dropped == 2
    assert tr.chrome_trace()["otherData"]["dropped_spans"] == 2


# ---------------------------------------------------------------------------
# schema / serialization
# ---------------------------------------------------------------------------


def test_encode_record_strict_json():
    rec = encode_record({"inf": float("inf"), "ninf": float("-inf"),
                         "nan": float("nan"), "np": np.float32(1.5),
                         "ok": 2.0, "nested": [np.int64(3), float("inf")]})
    assert rec == {"inf": None, "ninf": None, "nan": None, "np": 1.5,
                   "ok": 2.0, "nested": [3, None]}
    json.dumps(rec, allow_nan=False)  # strict JSON, no Infinity/NaN literals
    assert versioned({"a": 1}) == {"schema_version": SCHEMA_VERSION, "a": 1}


def test_timeline_merged_round_trips_bitwise():
    a, b = Timeline(), Timeline()
    a.record_step(step=0, rung="full", latency_s=0.5, observed_s=0.5,
                  loss=2.25, warmup=True, work=8.0)
    a.record_migration(step=1, from_rung="full", to_rung="accum",
                       reason="interference", kind="in-place", cost_s=0.125)
    b.record_step(step=0, rung="serve-full", latency_s=0.25, observed_s=0.25,
                  loss=0.0, work=4.0)
    merged = Timeline.merged({"train": a, "serve": b})
    doc = merged.to_json()
    assert doc["schema_version"] == SCHEMA_VERSION
    wire = json.loads(json.dumps(doc))
    back = Timeline.from_json(wire)  # extra top-level keys must be ignored
    assert back.to_json() == doc
    assert set(back.jobs()) == {"train", "serve"}


def test_audit_log_round_trips_bitwise():
    log = obs.AuditLog()
    log.record(tick=3, job="train", event="commit", direction="down",
               rule="interference", from_rung="full", to_rung="accum",
               scores={"train": 4.5, "serve": float("-inf")},
               slo_headroom={"serve": 0.125, "train": None},
               proposals={"train": "down"},
               energy={"loan_j": 2.0, "available": True,
                       "battery_level": 0.5},
               thermal={"temp": 0.75, "throttled": True})
    log.record(tick=4, job="serve", event="veto", direction="down",
               rule="slo", detail="ladder bottom")
    doc = log.to_json()
    assert doc["schema_version"] == SCHEMA_VERSION
    wire = json.loads(json.dumps(doc, allow_nan=False))  # -inf became None
    back = obs.AuditLog.from_json(wire)
    assert back.to_json() == wire
    assert len(back) == 2
    assert back.commits()[0].scores == {"train": 4.5, "serve": None}
    assert back.for_tick(4)[0].event == "veto"
    assert back.for_job("serve")[0].detail == "ladder bottom"


# ---------------------------------------------------------------------------
# Telemetry bundle + debug dump
# ---------------------------------------------------------------------------


def test_telemetry_bundle_save_and_debug_dump(tmp_path):
    tel = obs.Telemetry(enabled=True)
    tel.metrics.gauge("g").set(1.0)
    with tel.span("outer", tick=0):
        with tel.span("inner"):
            buf = io.StringIO()
            tel.debug_dump(file=buf, last=5)
    dump = buf.getvalue()
    assert "active span stacks" in dump
    assert "outer" in dump and "inner" in dump
    tel.audit.record(tick=0, job="train", event="commit", direction="down",
                     rule="energy", from_rung="full", to_rung="accum")
    tel.snap(0)

    paths = tel.save(str(tmp_path / "tel"))
    lines = [json.loads(l) for l in open(paths["metrics"])]
    assert lines[0] == versioned({"stream": "metrics"})
    assert lines[1]["tick"] == 0 and lines[1]["metrics"]["g"] == 1.0
    assert lines[-1]["tick"] == "final"
    span_lines = [json.loads(l) for l in open(paths["spans"])]
    assert span_lines[0] == versioned({"stream": "spans"})
    assert {s["name"] for s in span_lines[1:]} == {"outer", "inner"}
    trace = json.load(open(paths["trace"]))
    assert {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"} == \
        {"outer", "inner"}
    audit = json.load(open(paths["audit"]))
    assert audit["schema_version"] == SCHEMA_VERSION
    assert audit["records"][0]["rule"] == "energy"

    buf2 = io.StringIO()
    tel.debug_dump(file=buf2, last=5)
    out2 = buf2.getvalue()
    assert "no active spans" in out2
    assert "audit records" in out2 and "latest metrics snapshot" in out2


def test_disabled_telemetry_dump_and_noop_identity():
    tel = obs.get_telemetry()
    assert not tel.enabled
    assert tel.span("x") is _NOOP_SPAN
    assert tel.metrics.counter("c") is NOOP
    buf = io.StringIO()
    tel.debug_dump(file=buf)
    assert "telemetry disabled" in buf.getvalue()
    tel.snap(0)
    assert tel.snapshots == []


# ---------------------------------------------------------------------------
# engine / checkpoint instrumentation (in-process, tiny model)
# ---------------------------------------------------------------------------


def _tiny_engine(**kw):
    import jax
    from repro.configs.base import ModelConfig
    from repro.launch.serve import ContinuousBatchingEngine
    from repro.models.registry import build_model
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      tie_embeddings=True, source="test")
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    return ContinuousBatchingEngine(model, params, **kw)


def test_cow_and_prefill_spans_recorded():
    from repro.launch.serve import Request
    tel = obs.set_telemetry(obs.Telemetry(enabled=True)) and obs.get_telemetry()
    prompt = np.arange(3, 13, dtype=np.int32)  # partial tail block
    engine = _tiny_engine(max_batch=3, max_seq=32, kv_layout="paged",
                          block_size=4)
    engine.run([Request(uid=i, prompt=prompt.copy(), max_new_tokens=4)
                for i in range(3)])
    assert engine.stats()["cow_copies"] > 0
    agg = tel.tracer.by_name()
    assert agg["serve.cow_copy"]["count"] == engine.stats()["cow_copies"]
    assert agg["serve.prefill_chunk"]["count"] > 0
    assert agg["serve.decode"]["count"] == engine.decode_steps


def test_swap_spans_recorded():
    from repro.launch.serve import Request
    tel = obs.set_telemetry(obs.Telemetry(enabled=True)) and obs.get_telemetry()
    engine = _tiny_engine(max_batch=2, max_seq=32, kv_layout="paged",
                          block_size=4, num_blocks=6,
                          admission_policy="swap", prefix_cache=False)
    engine.run([Request(uid=i, prompt=np.arange(2, 10, dtype=np.int32),
                        max_new_tokens=4) for i in range(3)])
    st = engine.stats()
    assert st["swap_outs"] >= 1
    agg = tel.tracer.by_name()
    assert agg["serve.swap_out"]["count"] == st["swap_outs"]
    assert agg["serve.swap_in"]["count"] == st["swap_ins"]


def test_checkpoint_spans_recorded(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    tel = obs.set_telemetry(obs.Telemetry(enabled=True)) and obs.get_telemetry()
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": np.ones(4, np.float32)}
    mgr.save(3, state)
    step, restored = mgr.restore_latest()
    assert step == 3 and np.array_equal(restored["w"], state["w"])
    agg = tel.tracer.by_name()
    assert agg["ckpt.save"]["count"] == 1
    assert agg["ckpt.restore"]["count"] == 1
    recs = {s.name: s for s in tel.tracer.spans()}
    assert recs["ckpt.save"].args == {"step": 3}


# ---------------------------------------------------------------------------
# end to end: one mixed run -> full bundle
# ---------------------------------------------------------------------------


def _metric_families(metrics_path):
    lines = [json.loads(l) for l in open(metrics_path)]
    body = [l for l in lines if "metrics" in l]
    assert lines[0]["schema_version"] == SCHEMA_VERSION
    return body, {k.split("{")[0] for l in body for k in l["metrics"]}


def test_mixed_run_emits_complete_bundle(tmp_path):
    from repro.launch import mixed as M
    outdir = tmp_path / "tel"
    tl_out = tmp_path / "merged.json"
    json_out = tmp_path / "run.json"
    M.main(["--arch", "llama3.2-1b", "--reduced", "--ticks", "12",
            "--batch", "4", "--seq", "32", "--slots", "2",
            "--requests", "5", "--prompt-len", "8", "--gen", "6",
            "--kv-layout", "paged", "--battery-j", "200",
            "--thermal-trace", "0.3:0.25:3.0:0.5:0.4", "--quiet",
            "--telemetry-out", str(outdir), "--timeline-out", str(tl_out),
            "--json-out", str(json_out)])

    # (a) one metrics schema covering serve / train / pool / energy / thermal
    body, fams = _metric_families(outdir / "metrics.jsonl")
    assert len(body) >= 12  # one line per tick + final
    for fam in ["serve_tokens_out", "serve_occupancy", "train_loss",
                "train_steps_total", "pool_utilization", "pool_fragmentation",
                "pool_total_cow", "prefix_hit_rate", "energy_loan_j",
                "battery_level", "thermal_temp_c", "thermal_throttled",
                "job_rung_idx", "job_step_latency_s",
                "runtime_migrations_total"]:
        assert fam in fams, f"metric family {fam} missing from the stream"

    # (b) a Perfetto-loadable trace with the expected span vocabulary
    trace = json.load(open(outdir / "trace.json"))
    json.dumps(trace, allow_nan=False)
    xs = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    names = {e["name"] for e in xs}
    assert {"runtime.tick", "train.step", "serve.decode",
            "serve.prefill_chunk"} <= names
    assert all(e["dur"] >= 0 and e["ts"] >= 0 for e in xs)
    ticks = sorted(e["ts"] for e in xs if e["name"] == "runtime.tick")
    steps = [e for e in xs if e["name"] == "train.step"]
    assert len(ticks) == 12 and len(steps) >= 1
    assert any(e["args"].get("compile") for e in steps)  # warmup tagged

    # (c) every migration in the merged timeline has an audit record with
    # the scores that decided it
    tl = Timeline.from_json(json.load(open(tl_out)))
    assert tl.migrations, "thermal trace must force at least one migration"
    audit = obs.AuditLog.from_json(json.load(open(outdir / "audit.json")))
    commits = audit.commits()
    for m in tl.migrations:
        matches = [r for r in commits
                   if r.tick == m.step and r.job == m.job
                   and r.from_rung == m.from_rung and r.to_rung == m.to_rung]
        assert matches, f"no audit record for migration {m}"
        rec = matches[0]
        assert rec.rule == m.reason
        assert rec.scores, f"audit record for {m} carries no scores"
        assert rec.thermal is not None and rec.energy is not None
    assert any(r.event == "propose" for r in audit.records())

    # satellite: the ad-hoc CLI JSON now rides the same schema
    payload = json.load(open(json_out))
    assert payload["schema_version"] == SCHEMA_VERSION
    assert json.load(open(tl_out))["schema_version"] == SCHEMA_VERSION

    # obs_report consumes the bundle and re-derives a chrome trace
    from repro.launch import obs_report
    chrome2 = tmp_path / "chrome2.json"
    rep = obs_report.main([str(outdir), "--top", "5", "--audit-limit", "5",
                           "--chrome-trace", str(chrome2)])
    assert rep["spans"][0]["name"] == "runtime.tick"  # ticks dominate
    assert rep["final_metrics"]
    doc2 = json.load(open(chrome2))
    assert {e["name"] for e in doc2["traceEvents"] if e["ph"] == "X"} == names
