import os
import signal
import sys

import pytest

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device. Multi-device dry-run tests spawn subprocesses.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Per-test wall-clock timeout via SIGALRM (pytest-timeout is not available
# in this environment). A hung test — a stuck subprocess wait, a runtime
# loop that never converges — fails loudly with a traceback instead of
# stalling the whole suite until CI's job-level kill. Override with
# REPRO_TEST_TIMEOUT (seconds; 0 disables). Unix-only; a no-op elsewhere.
_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT", "900"))


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    if _TIMEOUT_S <= 0 or not hasattr(signal, "SIGALRM"):
        yield
        return

    def _timed_out(signum, frame):
        # Telemetry post-mortem: where was the run when it hung? The active
        # span stack names the phase (tick N, prefill chunk, swap-in...) and
        # the recent audit tail names the last arbiter decisions. Guarded —
        # a broken dump must not mask the timeout itself.
        try:
            from repro import obs
            obs.get_telemetry().debug_dump(file=sys.stderr, last=20)
        except Exception as e:  # pragma: no cover - diagnostics only
            print(f"[obs] telemetry dump failed: {e!r}", file=sys.stderr)
        raise TimeoutError(
            f"test exceeded REPRO_TEST_TIMEOUT={_TIMEOUT_S}s")

    prev = signal.signal(signal.SIGALRM, _timed_out)
    signal.alarm(_TIMEOUT_S)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev)
