import os
import sys

# NOTE: no xla_force_host_platform_device_count here — smoke tests and
# benches must see 1 device. Multi-device dry-run tests spawn subprocesses.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
