"""Swan core: cost order (paper §4.3), pruning, controller, energy loan."""
import pytest

from repro.core import energy as E
from repro.core.choices import CoreChoice, MeshChoice, enumerate_core_choices, \
    enumerate_mesh_choices
from repro.core.controller import SwanController
from repro.core.cost import ChoiceProfile, pareto_prune, pick_fastest, total_order
from repro.core.planner import explore_soc, fleet_explore, merge_fleet_profiles
from repro.core.profiler import greedy_baseline_profile, profile_soc_choice


def _prof(name, lat, cost, energy=1.0):
    return ChoiceProfile(choice=type("C", (), {"name": name})(), latency_s=lat,
                         energy_j=energy, power_w=1.0, cost_key=cost)


def test_paper_pixel3_cost_order():
    """Paper: cost('4567')>cost('456')>cost('45')>cost('4')>cost('0123')>..."""
    model = E.SOC_MODELS["pixel3"]
    names = ["4567", "456", "45", "4", "0123", "012", "01", "0"]
    choices = [CoreChoice(tuple(int(c) for c in n), "pixel3") for n in names]
    keys = [c.cost_key(model) for c in choices]
    assert keys == sorted(keys, reverse=True), "cost order violates paper §4.3"


def test_cost_rules_prime_and_class():
    model = E.SOC_MODELS["s10e"]  # cores 0-3 little, 4-6 big, 7 prime
    c47 = CoreChoice((4, 7), "s10e").cost_key(model)
    c45 = CoreChoice((4, 5), "s10e").cost_key(model)
    assert c47 > c45, "rule 3: prime costlier than big"
    c4 = CoreChoice((4,), "s10e").cost_key(model)
    c0123 = CoreChoice((0, 1, 2, 3), "s10e").cost_key(model)
    assert c4 > c0123, "rule 2: any big > any little"


def test_pareto_prune_keeps_fastest_and_drops_dominated():
    profs = [
        _prof("fast_expensive", 1.0, (2,)),
        _prof("slow_expensive", 2.0, (2,)),  # dominated: slower, same cost
        _prof("slow_cheap", 3.0, (1,)),
        _prof("slower_cheaper", 4.0, (0,)),
    ]
    kept = [p.name for p in pareto_prune(profs)]
    assert kept == ["fast_expensive", "slow_cheap", "slower_cheaper"]


def test_shufflenet_ladder_collapses():
    """O2: for depthwise workloads multi-core choices are dominated."""
    plan = explore_soc("pixel3", "shufflenet-v2")
    names = [p.name for p in plan.ladder]
    assert "4567" not in names and names[0] == "4"
    plan_r = explore_soc("pixel3", "resnet34")
    assert plan_r.ladder[0].name == "4567"


def test_controller_downgrades_and_recovers():
    plan = explore_soc("s10e", "shufflenet-v2")
    ctl = SwanController(plan.ladder, upgrade_patience=3)
    start = ctl.active.name
    for _ in range(6):  # sustained 2x interference
        ctl.observe_step(ctl.active.latency_s * 2.0)
    assert ctl.idx > 0, "controller failed to downgrade under interference"
    for _ in range(20):  # clean
        ctl.observe_step(ctl.active.latency_s)
    assert ctl.active.name == start, "controller failed to recover"
    assert any(m.reason == "interference" for m in ctl.migrations)
    assert any(m.reason == "clear" for m in ctl.migrations)


def test_energy_loan_gates_availability():
    loan = E.EnergyLoan(battery_j=100.0, daily_charge_j=60.0, daily_usage_j=50.0,
                        critical_frac=0.2)
    assert loan.available(0.5)
    loan.borrow(40.0)  # 40% of battery
    assert not loan.available(0.5)  # 0.5 - 0.4 = 0.1 < 0.2
    loan.repay_daily()  # repays 10J
    assert loan.loan_j == pytest.approx(30.0)
    assert loan.available(0.6)  # 0.6 - 0.3 = 0.3 > 0.2


def test_fleet_exploration_amortizes():
    assignment = fleet_explore("s10e", "shufflenet-v2", n_devices=4)
    model = E.SOC_MODELS["s10e"]
    all_names = {c.name for c in enumerate_core_choices(model)}
    covered = {n for names in assignment.values() for n in names}
    assert covered == all_names
    per_dev = max(len(v) for v in assignment.values())
    assert per_dev <= -(-len(all_names) // 4) + 1


def test_merge_fleet_profiles_dedupes_and_orders():
    model = E.SOC_MODELS["pixel3"]
    p1 = [profile_soc_choice(c, model, "resnet34")
          for c in enumerate_core_choices(model)[:4]]
    p2 = [profile_soc_choice(c, model, "resnet34")
          for c in enumerate_core_choices(model)[2:]]
    merged = merge_fleet_profiles([p1, p2])
    names = [p.name for p in merged]
    assert len(names) == len(set(names))
    lats = [p.latency_s for p in merged]
    assert lats == sorted(lats)


def test_mesh_choice_cost_order():
    full = MeshChoice((16, 16), ("data", "model"), prime_pod=True)
    half = MeshChoice((8, 16), ("data", "model"), prime_pod=False)
    small_tp = MeshChoice((16, 8), ("data", "model"), prime_pod=False)
    assert full.cost_key() > half.cost_key()
    assert half.cost_key() > small_tp.cost_key()  # same chips? 128 vs 128, tp 16>8
    choices = enumerate_mesh_choices(256)
    assert len(choices) > 20
    assert any(c.n_chips < 256 for c in choices)


def test_pick_fastest_respects_memory_limit():
    profs = [_prof("big", 1.0, (2,)), _prof("small", 2.0, (1,))]
    profs[0] = ChoiceProfile(choice=profs[0].choice, latency_s=1.0, energy_j=1.0,
                             power_w=1.0, cost_key=(2,), memory_bytes=32 << 30)
    profs[1] = ChoiceProfile(choice=profs[1].choice, latency_s=2.0, energy_j=1.0,
                             power_w=1.0, cost_key=(1,), memory_bytes=8 << 30)
    assert pick_fastest(profs, memory_limit=16 << 30).name == "small"
