"""Shared-SoC arbitration: SwanRuntime over co-tenant SocJobs.

Covers the runtime's closed loop across jobs (summed-power thermals,
sensitivity-weighted downgrade ordering), device loss mid-co-tenancy (the
mesh-backed job remeshes, serving keeps streaming), merged-timeline tag
integrity, ServeJob rung-migration token parity, the energy budget, and the
controller's post-migration no-bounce regression.
"""
import json
import os
import subprocess
import sys
import warnings

import jax
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.controller import SwanController
from repro.core.cost import ChoiceProfile
from repro.core.energy import EnergyLoan
from repro.engine.events import InterferenceTrace, ThermalTrace
from repro.engine.jobs import (ServeJob, ServeRung,
                              default_serve_ladder, trace_latency_fn)
from repro.engine.runtime import SwanRuntime
from repro.engine.rungs import default_rung_ladder
from repro.engine.session import TrainSession
from repro.engine.timeline import Timeline
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.launch.train import make_batch_fn
from repro.models.registry import build_model
from repro.optim.optimizers import sgd

TINY = ModelConfig(name="arb-tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                   tie_embeddings=True, source="tests/test_arbitration.py")
KEY = jax.random.PRNGKey(0)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _train_job(trace, ticks, *, sens=None, rel=None, name="train",
               priority=1.0, patience=4):
    rungs = default_rung_ladder(batch=8, microbatch=1, attn_impl="naive")
    if sens is not None:
        rungs = rungs[:len(sens)]
        for r, s in zip(rungs, sens):
            r.interference_sensitivity = s
    if rel is not None:
        for r, rl in zip(rungs, rel):
            r.rel_latency = rl
    for r in rungs:
        r.latency_estimate_s = 0.1 * r.rel_latency
    ses = TrainSession(TINY, rungs, optimizer=sgd(), lr=0.05,
                       batch_fn=make_batch_fn(TINY, 8, 32),
                       latency_fn=trace_latency_fn(trace), adaptive=True,
                       upgrade_patience=patience, verbose=False, name=name,
                       priority=priority)
    return ses.bind(ticks)


def _serve_rungs(slots, *, sens=(1.0, 0.4), rel=(1.0, 1.5), kv_dtype=None):
    names = ("serve-full", "serve-capped", "serve-lean")
    caps = (None, max(1, slots // 2), 1)
    return [ServeRung(name=names[i], slot_cap=caps[i],
                      interference_sensitivity=s, rel_latency=r,
                      latency_estimate_s=0.1 * r,
                      kv_dtype=kv_dtype if i == len(sens) - 1 else None)
            for i, (s, r) in enumerate(zip(sens, rel))]


def _serve_job(trace, *, slots=2, n_req=8, gen=8, rungs=None, name="serve",
               priority=1.0, patience=4, adaptive=True, impl="naive"):
    model = build_model(TINY, impl=impl)
    params = model.init(KEY)
    engine = ContinuousBatchingEngine(model, params, max_batch=slots,
                                      max_seq=48)
    rng = np.random.default_rng(7)
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 5).astype(np.int32),
                    max_new_tokens=gen) for i in range(n_req)]
    return ServeJob(engine, reqs,
                    rungs=rungs or _serve_rungs(slots),
                    latency_fn=trace_latency_fn(trace), adaptive=adaptive,
                    upgrade_patience=patience, name=name, priority=priority)


# ---------------------------------------------------------------------------
# controller regression: migrate -> no bounce
# ---------------------------------------------------------------------------


def _profiles(lats):
    return [ChoiceProfile(choice=f"r{i}", latency_s=l, energy_j=l,
                          power_w=1.0, cost_key=(len(lats) - i,))
            for i, l in enumerate(lats)]


def test_controller_skips_first_post_migration_sample():
    """The first sample after a migration carries the compile/remesh tail;
    feeding it would re-anchor the EWMA on a one-off spike and immediately
    re-migrate (downgrade bounce). It must be dropped."""
    ctl = SwanController(_profiles([0.1, 0.15, 0.2]), upgrade_patience=3)
    for _ in range(3):
        ctl.observe_step(0.1)
    ctl.observe_step(0.3)  # genuine interference -> downgrade
    assert ctl.idx == 1 and len(ctl.migrations) == 1
    # compile/remesh tail on the new rung: way over trigger, must be ignored
    ctl.observe_step(10.0)
    assert ctl.idx == 1 and len(ctl.migrations) == 1, \
        "post-migration tail sample caused a bounce"
    # clean steps on the new rung: stays put (and eventually recovers)
    for _ in range(2):
        ctl.observe_step(0.15)
    assert ctl.idx == 1 and len(ctl.migrations) == 1


def test_controller_propose_commit_veto_keeps_monitor_state():
    """A vetoed proposal (arbiter picked another job) migrates nothing and
    keeps the monitor pressured, so it re-proposes next step."""
    ctl = SwanController(_profiles([0.1, 0.15]), upgrade_patience=3)
    ctl.observe_step(0.1)
    assert ctl.propose(0.4) == "down"
    assert ctl.idx == 0 and not ctl.migrations  # nothing committed
    assert ctl.propose(0.4) == "down"  # still pressured
    ctl.commit("down", "arbitration")
    assert ctl.idx == 1 and ctl.migrations[-1].reason == "arbitration"


# ---------------------------------------------------------------------------
# two-job thermal arbitration: downgrade order follows sensitivity
# ---------------------------------------------------------------------------


def _thermal():
    return ThermalTrace(heat_rate=0.4, cool_rate=0.3, slowdown=4.0,
                        trigger_temp=1.0, release_temp=0.4)


def _first_downgrade_job(train_sens, serve_sens, ticks=10):
    trace = _thermal()
    # identical rel ladders: the relinquish score differs only through the
    # sensitivity gap, so the arbiter's pick isolates that term
    train = _train_job(trace, ticks, sens=train_sens, rel=(1.0, 1.5))
    serve = _serve_job(trace, rungs=_serve_rungs(2, sens=serve_sens,
                                                 rel=(1.0, 1.5)),
                       n_req=12, gen=12)
    res = SwanRuntime([train, serve], trace=trace).run(ticks)
    downs = [m for m in res.timeline.migrations if m.reason != "clear"]
    assert downs, "combined power must trip the shared throttle"
    return downs[0].job


def test_thermal_pressure_downgrades_serve_first_when_more_sensitive():
    assert _first_downgrade_job((1.0, 0.6), (1.0, 0.2)) == "serve"


def test_thermal_pressure_downgrades_train_first_when_more_sensitive():
    assert _first_downgrade_job((1.0, 0.2), (1.0, 0.6)) == "train"


def test_priority_tilts_arbitration():
    """With symmetric ladders, the lower-priority job is downgraded first."""
    trace = _thermal()
    train = _train_job(trace, 10, sens=(1.0, 0.4), rel=(1.0, 1.5),
                       priority=0.5)
    serve = _serve_job(trace, rungs=_serve_rungs(2, sens=(1.0, 0.4),
                                                 rel=(1.0, 1.5)),
                       n_req=12, gen=12, priority=2.0)
    res = SwanRuntime([train, serve], trace=trace).run(10)
    downs = [m for m in res.timeline.migrations if m.reason != "clear"]
    assert downs and downs[0].job == "train"


def test_shared_thermal_integrates_summed_power():
    """Co-tenancy heats the die faster than either job alone: the combined
    run throttles (and downgrades) while the single job stays clean."""
    def run(jobs, trace):
        return SwanRuntime(jobs, trace=trace).run(12)

    # alone: heat 0.4*1.0 just exceeds cooling; never reaches trigger in 12
    t_alone = ThermalTrace(heat_rate=0.35, cool_rate=0.3, slowdown=4.0,
                           trigger_temp=1.0, release_temp=0.4)
    res_alone = run([_train_job(t_alone, 12)], t_alone)
    assert not res_alone.timeline.migrations

    t_both = ThermalTrace(heat_rate=0.35, cool_rate=0.3, slowdown=4.0,
                          trigger_temp=1.0, release_temp=0.4)
    res_both = run([_train_job(t_both, 12), _serve_job(t_both, n_req=12,
                                                       gen=12)], t_both)
    assert res_both.timeline.migrations, \
        "summed draw of two jobs must trip the throttle one alone does not"


# ---------------------------------------------------------------------------
# merged timeline: tag integrity
# ---------------------------------------------------------------------------


def test_merged_timeline_tags_and_roundtrip(tmp_path):
    trace = _thermal()
    train = _train_job(trace, 8)
    serve = _serve_job(trace, n_req=10, gen=10)
    res = SwanRuntime([train, serve], trace=trace).run(8)
    tl = res.timeline
    assert set(tl.jobs()) == {"train", "serve"}
    assert all(s.job in ("train", "serve") for s in tl.steps)
    assert all(m.job in ("train", "serve") for m in tl.migrations)
    # per-job views partition the merged record set exactly
    for name, job in (("train", train), ("serve", serve)):
        view = tl.for_job(name)
        assert len(view.steps) == len(job.timeline.steps)
        assert len(view.migrations) == len(job.timeline.migrations)
        assert [s.step for s in view.steps] == \
            [s.step for s in job.timeline.steps]
    assert len(tl.steps) == len(train.timeline.steps) + \
        len(serve.timeline.steps)
    # json roundtrip preserves tags and the per-job summary
    p = str(tmp_path / "merged.json")
    tl.save(p)
    with open(p) as f:
        back = Timeline.from_json(json.load(f))
    assert set(back.jobs()) == {"train", "serve"}
    assert back.summary()["jobs"].keys() == tl.summary()["jobs"].keys()
    assert back.summary() == tl.summary()


# ---------------------------------------------------------------------------
# ServeJob: rung migration is bookkeeping, never math
# ---------------------------------------------------------------------------


def test_serve_rung_migration_token_parity():
    """A serve stream that migrates down (slot cap) mid-flight and back up
    must emit token-for-token what a fixed-rung engine emits: concurrency
    rungs change scheduling, not math."""
    def run_engine(migrating):
        trace = InterferenceTrace.parse("3:7:4.0") if migrating else None
        job = _serve_job(trace, slots=2, n_req=6, gen=8,
                         rungs=_serve_rungs(2, sens=(1.0, 0.4),
                                            rel=(1.0, 1.5)),
                         adaptive=migrating)
        res = SwanRuntime([job], trace=trace).run(200)
        return job, res

    fixed, _ = run_engine(False)
    moved, res = run_engine(True)
    migs = [m for m in moved.timeline.migrations]
    assert migs, "the burst must force at least one serve rung migration"
    ref = {u: f.tokens for u, f in fixed.result().items()}
    got = {u: f.tokens for u, f in moved.result().items()}
    assert got == ref, "rung migration changed the served tokens"


def test_serve_job_slot_cap_limits_concurrency():
    engine_model = build_model(TINY, impl="naive")
    params = engine_model.init(KEY)
    engine = ContinuousBatchingEngine(engine_model, params, max_batch=4,
                                      max_seq=32)
    engine.set_slot_cap(2)
    rng = np.random.default_rng(3)
    for i in range(6):
        engine.submit(Request(uid=i,
                              prompt=rng.integers(0, 64, 4).astype(np.int32),
                              max_new_tokens=4))
    while engine.queue or any(u is not None for u in engine.slot_uid):
        engine.step()
        assert sum(1 for u in engine.slot_uid if u is not None) <= 2
    assert sorted(engine.finished) == list(range(6))


def test_default_serve_ladder_dedupes_tiny_batches():
    full = default_serve_ladder(8)
    assert [r.slot_cap for r in full] == [None, 4, 2]
    sens = [r.interference_sensitivity for r in full]
    assert sens == sorted(sens, reverse=True) and sens[0] == 1.0
    tiny = default_serve_ladder(1)
    assert len(tiny) == 2  # cap rungs collapse; the bf16-KV rung survives
    assert tiny[-1].kv_dtype == "bfloat16"


# ---------------------------------------------------------------------------
# speculative decoding rung: draft depth relinquishes before slot caps
# ---------------------------------------------------------------------------


def test_default_serve_ladder_puts_spec_rungs_above_slot_caps():
    rungs = default_serve_ladder(8, draft_depth=4)
    assert [r.name for r in rungs] == \
        ["serve-full", "serve-spec-half", "serve-spec-off",
         "serve-capped", "serve-lean"]
    assert [r.draft_depth for r in rungs] == [None, 2, 0, 0, 0]
    assert [r.slot_cap for r in rungs] == [None, None, None, 4, 2]
    # depth 1: no half rung to insert, straight to spec-off
    assert [r.name for r in default_serve_ladder(8, draft_depth=1)] == \
        ["serve-full", "serve-spec-off", "serve-capped", "serve-lean"]
    # non-speculating engines keep the original ladder shape
    assert [r.draft_depth for r in default_serve_ladder(8)] == [None] * 3


def test_thermal_walks_draft_depth_down_before_slot_cap():
    """Under sustained thermal pressure a speculating ServeJob must give up
    draft depth first — halve it, then switch speculation off — and only
    then start capping slots: depth costs nothing but the speculative
    speedup (streams are depth-invariant), a slot cap costs admissions."""
    trace = _thermal()
    model = build_model(TINY, impl="naive")
    params = model.init(KEY)
    engine = ContinuousBatchingEngine(model, params, max_batch=4, max_seq=48,
                                      draft_depth=4)
    rungs = default_serve_ladder(4, draft_depth=4)
    for r in rungs:
        r.latency_estimate_s = 0.1 * r.rel_latency
    rng = np.random.default_rng(7)
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 5).astype(np.int32),
                    max_new_tokens=20) for i in range(16)]
    serve = ServeJob(engine, reqs, rungs=rungs,
                     latency_fn=trace_latency_fn(trace), adaptive=True,
                     upgrade_patience=100, name="serve")
    res = SwanRuntime([serve], trace=trace).run(14)
    assert engine.spec_rounds > 0, "speculation must run at the full rung"
    downs = [m for m in res.timeline.migrations if m.reason != "clear"]
    assert downs, "the thermal trace must force serve downgrades"
    names = [m.to_rung for m in downs]
    assert names[0] == "serve-spec-half"
    if len(names) > 1:
        assert names[1] == "serve-spec-off"
    first_cap = next((i for i, n in enumerate(names)
                      if n in ("serve-capped", "serve-lean")), None)
    if first_cap is not None:
        assert {"serve-spec-half", "serve-spec-off"} <= set(names[:first_cap])
    # the walk actually reached the engine knob
    assert engine.draft_depth in (0, 2, 4)
    assert engine.draft_depth < 4 or not downs


# ---------------------------------------------------------------------------
# energy budget: low battery forces low-power rungs
# ---------------------------------------------------------------------------


def test_energy_budget_low_battery_forces_downgrade():
    def run(level):
        trace = None
        train = _train_job(trace, 8)
        loan = EnergyLoan(battery_j=50.0, daily_charge_j=0.0,
                          daily_usage_j=0.0)
        rt = SwanRuntime([train], energy=loan, battery_level=level)
        return rt.run(8)

    low = run(0.2)   # 0.2 - loan/50 crosses critical (0.15) within ~3 ticks
    full = run(1.0)  # a full battery never crosses in 8 ticks
    low_energy = [m for m in low.timeline.migrations if m.reason == "energy"]
    assert low_energy, "depleted budget must push toward low-power rungs"
    assert low_energy[0].to_rung != "full"
    assert not [m for m in full.timeline.migrations if m.reason == "energy"], \
        "a full battery must not force energy downgrades"


def test_energy_budget_blocks_upgrades():
    """Once the budget is depleted the runtime must also refuse to upgrade
    back, even on a clean monitor."""
    trace = None
    train = _train_job(trace, 12, patience=2)
    loan = EnergyLoan(battery_j=20.0, daily_charge_j=0.0, daily_usage_j=0.0)
    res = SwanRuntime([train], energy=loan, battery_level=0.2).run(12)
    ups = [m for m in res.timeline.migrations if m.reason == "clear"]
    assert not ups, "upgrades must be blocked while the budget is depleted"
    assert train.rung.name == train.rungs()[-1].name  # walked to the bottom


# ---------------------------------------------------------------------------
# device loss mid-co-tenancy: train remeshes, serve keeps streaming
# ---------------------------------------------------------------------------

DEVICE_LOSS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine.events import ScriptedFaults
from repro.engine.jobs import ServeJob, ServeRung
from repro.engine.runtime import SwanRuntime
from repro.engine.rungs import default_rung_ladder
from repro.engine.session import TrainSession
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.launch.train import make_batch_fn
from repro.models.registry import build_model
from repro.optim.optimizers import sgd
from repro.runtime.elastic import ElasticController

cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  tie_embeddings=True, source="test")
TICKS = 8

def serve_requests():
    rng = np.random.default_rng(5)
    return [Request(uid=i, prompt=rng.integers(0, 64, 5).astype(np.int32),
                    max_new_tokens=6) for i in range(4)]

def make_serve():
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    engine = ContinuousBatchingEngine(model, params, max_batch=2, max_seq=32)
    return ServeJob(engine, serve_requests(), adaptive=False,
                    rungs=[ServeRung(name="serve-full")], name="serve")

# --- co-tenant run: device loss at tick 3 ---
elastic = ElasticController(total_devices=8)
rungs = default_rung_ladder(batch=8, microbatch=1, attn_impl="naive",
                            include_bf16=False)
train = TrainSession(cfg, rungs, optimizer=sgd(), lr=0.05,
                     batch_fn=make_batch_fn(cfg, 8, 16), elastic=elastic,
                     fault_events=ScriptedFaults({3: (6, 7)}),
                     adaptive=False, verbose=False, name="train").bind(TICKS)
serve = make_serve()
res = SwanRuntime([train, serve],
                  elastic=elastic,
                  fault_events=train.fault_events).run(TICKS)
cotenant = {u: f.tokens for u, f in serve.result().items()}

# --- oracle: the same serve stream alone, no faults ---
alone_job = make_serve()
SwanRuntime([alone_job]).run(TICKS)
alone = {u: f.tokens for u, f in alone_job.result().items()}

remesh = [dict(step=m.step, kind=m.kind, reason=m.reason, job=m.job)
          for m in res.timeline.migrations if m.kind == "remesh"]
print("RESULT:" + json.dumps({
    "n_healthy": elastic.n_healthy,
    "remesh": remesh,
    "train_steps": len(train.result().losses),
    "serve_cotenant": {str(k): v for k, v in cotenant.items()},
    "serve_alone": {str(k): v for k, v in alone.items()},
    "serve_steps": [s.step for s in serve.timeline.steps],
}))
"""


def test_device_loss_mid_cotenancy_train_remeshes_serve_streams(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", DEVICE_LOSS_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         env=env, cwd=str(tmp_path))
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT:")][-1]
    payload = json.loads(line[len("RESULT:"):])
    assert payload["n_healthy"] == 6
    # the training job remeshed off the dead devices...
    assert payload["remesh"], "device loss must force a train remesh"
    assert all(m["job"] == "train" and m["reason"] == "device-loss"
               for m in payload["remesh"])
    assert payload["remesh"][0]["step"] == 3
    assert payload["train_steps"] == 8
    # ...and the serving job never noticed: same stream, token for token
    assert payload["serve_cotenant"] == payload["serve_alone"]


# ---------------------------------------------------------------------------
# MLA pallas prefill: fall back, never garbage
# ---------------------------------------------------------------------------


def test_mla_prefill_pallas_falls_back_to_chunked():
    from repro.configs import ASSIGNED
    cfg = ASSIGNED["deepseek-v3-671b"].reduced()
    assert cfg.use_mla
    tokens = np.arange(2 * 16, dtype=np.int32).reshape(2, 16) % cfg.vocab_size
    ref_model = build_model(cfg, impl="chunked")
    params = ref_model.init(KEY)
    ref = ref_model.forward(params, {"tokens": tokens})
    pal_model = build_model(cfg, impl="pallas")
    import repro.models.attention as A
    A._MLA_PALLAS_WARNED = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        got = pal_model.forward(params, {"tokens": tokens})
        got2 = pal_model.forward(params, {"tokens": tokens})
    fallback = [x for x in w if "falling back to 'chunked'" in str(x.message)]
    assert len(fallback) == 1, "exactly one fallback warning"
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got2), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_flash_attention_mha_rejects_asymmetric_heads():
    from repro.kernels.flash_attention import flash_attention_mha
    q = np.zeros((1, 2, 8, 24), np.float32)
    k = np.zeros((1, 2, 8, 24), np.float32)
    v = np.zeros((1, 2, 8, 16), np.float32)
    with pytest.raises(ValueError, match="matching q/k/v head dims"):
        flash_attention_mha(q, k, v)


# ---------------------------------------------------------------------------
# mixed CLI
# ---------------------------------------------------------------------------


def test_mixed_cli_cotenancy_under_thermal_trace(tmp_path):
    from repro.launch import mixed as M
    out = str(tmp_path / "merged.json")
    res = M.main(["--arch", "llama3.2-1b", "--reduced", "--ticks", "12",
                  "--batch", "8", "--seq", "32", "--slots", "2",
                  "--requests", "4", "--prompt-len", "8", "--gen", "6",
                  "--thermal-trace", "0.5:0.3:4.0", "--quiet",
                  "--timeline-out", out])
    with open(out) as f:
        tl = Timeline.from_json(json.load(f))
    assert set(tl.jobs()) == {"train", "serve"}
    assert any(m.reason in ("interference", "arbitration")
               for m in tl.migrations), \
        "the shared thermal trace must force at least one downgrade"
    assert len(res.jobs["train"].result().losses) == 12
    assert res.jobs["serve"].result(), "serve stream must finish requests"
