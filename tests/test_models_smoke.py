"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER_MODELS, REGISTRY
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    if cfg.family == "cnn":
        return {"images": jax.random.normal(KEY, (B, cfg.image_size, cfg.image_size,
                                                   cfg.in_channels)),
                "labels": jnp.zeros((B,), jnp.int32)}
    b = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        b["image_embed"] = jax.random.normal(KEY, (B, cfg.n_image_tokens, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        b["audio_embed"] = jax.random.normal(KEY, (B, cfg.n_audio_frames, cfg.d_model)) * 0.02
    return b


@pytest.mark.parametrize("arch", sorted(REGISTRY))
def test_smoke_forward_and_train_step(arch):
    cfg = REGISTRY[arch].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(KEY)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    if cfg.family != "cnn":
        logits = model.forward(params, batch)
        assert logits.shape == (B, S, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any()), "NaN in logits"
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert not bool(jnp.isnan(loss)), "NaN loss"
    gn = jax.tree_util.tree_reduce(
        lambda a, g: a + float(jnp.abs(g).sum()), grads, 0.0)
    assert np.isfinite(gn) and gn > 0, "degenerate gradients"


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_decode_step(arch):
    cfg = ASSIGNED[arch].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(KEY)
    B = 2
    cache = model.init_cache(B, 24, jnp.float32)
    logits, new_cache = model.decode_step(params, cache,
                                          jnp.zeros((B, 1), jnp.int32), jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert jax.tree_util.tree_structure(cache) == jax.tree_util.tree_structure(new_cache)
