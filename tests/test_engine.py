"""Adaptive training runtime: Rungs, events, timeline, TrainSession."""
import dataclasses
import json

import jax
import pytest

from repro.configs.base import ModelConfig
from repro.core.choices import MeshChoice
from repro.core.cost import ChoiceProfile, ladder, ladder_sensitivities
from repro.engine.events import (Burst, InterferenceTrace, ScriptedFaults,
                                 ThermalTrace)
from repro.engine.rungs import Rung, default_rung_ladder, rungs_from_ladder
from repro.engine.session import TrainSession
from repro.engine.timeline import Timeline
from repro.kernels.backend import auto_attn_impl
from repro.launch.train import make_batch_fn
from repro.optim.optimizers import sgd

TINY = ModelConfig(name="engine-tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                   tie_embeddings=True, source="tests/test_engine.py")


# ---------------------------------------------------------------------------
# events
# ---------------------------------------------------------------------------


def test_trace_parse_and_slowdown():
    tr = InterferenceTrace.parse("10:20:2.5, 30:35:4")
    assert tr.bursts == (Burst(10, 20, 2.5), Burst(30, 35, 4.0))
    assert tr.slowdown(9) == 1.0
    assert tr.slowdown(10) == 2.5
    assert tr.slowdown(19) == 2.5 and tr.slowdown(20) == 1.0
    assert tr.effective_slowdown(30, 0.5) == pytest.approx(2.5)
    assert tr.effective_slowdown(30, 0.0) == 1.0
    assert tr.active(12) and not tr.active(25)


@pytest.mark.parametrize("bad", ["10:5:2", "10:20:0.5", "10:20", "x:y:z"])
def test_trace_parse_rejects(bad):
    with pytest.raises((ValueError, TypeError)):
        InterferenceTrace.parse(bad)


def test_thermal_trace_parse_and_hysteresis():
    tr = ThermalTrace.parse("0.5:0.2:3.0")
    assert (tr.heat_rate, tr.cool_rate, tr.slowdown) == (0.5, 0.2, 3.0)
    tr5 = ThermalTrace.parse("0.5:0.2:3.0:2.0:1.0")
    assert (tr5.trigger_temp, tr5.release_temp) == (2.0, 1.0)

    # full power heats 0.3/step: clean until temp crosses 1.0, then throttled
    tr = ThermalTrace(heat_rate=0.5, cool_rate=0.2, slowdown=3.0,
                      trigger_temp=1.0, release_temp=0.3)
    seen = [tr.effective_slowdown(s, 1.0) for s in range(5)]
    assert seen[:3] == [1.0, 1.0, 1.0] and seen[3] == 3.0  # temp 1.2 at step 3
    # a downgraded rung (sensitivity 0.2) sheds heat, but hysteresis keeps
    # the throttle on until temp falls below release, not trigger
    slows = [tr.effective_slowdown(5 + s, 0.2) for s in range(20)]
    assert slows[0] == pytest.approx(1.4)  # still throttled, scaled by sens
    assert 1.0 in slows  # ...then released after cooling
    released = slows.index(1.0)
    assert released > 3  # cooled past trigger yet stayed throttled (hysteresis)
    assert not tr.throttled

    # re-evaluating one step (e.g. comparing candidate rungs for an
    # adaptive-vs-static curve) reads the state without advancing it
    tr2 = ThermalTrace(heat_rate=0.5, cool_rate=0.2, slowdown=3.0,
                       trigger_temp=1.0, release_temp=0.3)
    tr2.effective_slowdown(0, 1.0)
    t_after = tr2.temp
    for sens in (1.0, 0.4, 0.16):
        tr2.effective_slowdown(0, sens)
    assert tr2.temp == t_after


@pytest.mark.parametrize("bad", ["0.5:0.2", "0:0.2:3", "0.5:0.2:0.5",
                                 "0.5:0.2:3:1.0:1.5", "x:y:z"])
def test_thermal_trace_parse_rejects(bad):
    with pytest.raises((ValueError, TypeError)):
        ThermalTrace.parse(bad)


def test_scripted_faults_respect_healthy_pool():
    ev = ScriptedFaults({3: (5, 6), 7: (5,)})
    assert ev(3, [0, 1, 5, 6]) == (5, 6)
    assert ev(7, [0, 1, 6]) == ()  # 5 already dead
    assert ev(4, [0, 1]) == ()


# ---------------------------------------------------------------------------
# rungs
# ---------------------------------------------------------------------------


def test_rungs_from_mesh_choice_ladder():
    choices = [
        MeshChoice((16, 16), ("data", "model"), microbatch=1,
                   attn_impl="pallas", prime_pod=True),
        MeshChoice((8, 16), ("data", "model"), microbatch=4,
                   remat="full", prime_pod=False),
        MeshChoice((8, 8), ("data", "model"), microbatch=16,
                   prime_pod=False),
    ]
    profiles = [ChoiceProfile(choice=c, latency_s=0.1 * (i + 1), energy_j=1.0,
                              power_w=1.0, cost_key=c.cost_key())
                for i, c in enumerate(choices)]
    rungs = rungs_from_ladder(ladder(profiles))
    assert [r.mesh_shape for r in rungs] == [(16, 16), (8, 16), (8, 8)]
    assert [r.microbatch for r in rungs] == [1, 4, 16]
    assert rungs[0].attn_impl == "pallas" and rungs[1].remat == "full"
    # sensitivities decay down the ladder, latency estimates ride along
    sens = [r.interference_sensitivity for r in rungs]
    assert sens == sorted(sens, reverse=True) and sens[0] == 1.0
    assert [r.latency_estimate_s for r in rungs] == [0.1, 0.2, pytest.approx(0.3)]
    assert rungs[1].rel_latency == pytest.approx(2.0)


def test_ladder_sensitivities_shape():
    s = ladder_sensitivities(5)
    assert len(s) == 5 and s[0] == 1.0
    assert all(a >= b for a, b in zip(s, s[1:]))
    assert min(s) >= 0.1


def test_default_rung_ladder_divides_batch():
    rungs = default_rung_ladder(batch=4, microbatch=1)
    assert all(4 % r.microbatch == 0 for r in rungs)
    assert len(rungs) == 3
    only_head = default_rung_ladder(batch=3, microbatch=3)
    assert len(only_head) == 1 and only_head[0].microbatch == 3
    with pytest.raises(ValueError):
        default_rung_ladder(batch=6, microbatch=4)


def test_rung_jitted_step_is_cached():
    rung = Rung(name="r", microbatch=1, attn_impl="naive")
    opt = sgd()
    f1 = rung.jitted_step(TINY, opt, lr=0.05)
    f2 = rung.jitted_step(TINY, opt, lr=0.05)
    assert f1 is f2
    rung.invalidate()
    assert rung.jitted_step(TINY, opt, lr=0.05) is not f1


# ---------------------------------------------------------------------------
# attention auto policy (kernels/backend.py capability table)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seq,interpret,expect", [
    (128, True, "naive"), (128, False, "naive"),
    (512, False, "naive"), (513, False, "pallas"),
    (1024, False, "pallas"), (1024, True, "chunked"),
    (4096, True, "chunked"),
])
def test_auto_attn_impl_policy_table(seq, interpret, expect):
    assert auto_attn_impl(seq, interpret=interpret) == expect


def test_auto_attn_impl_consults_backend():
    expect = "pallas" if jax.default_backend() == "tpu" else "chunked"
    assert auto_attn_impl(2048) == expect


# ---------------------------------------------------------------------------
# timeline
# ---------------------------------------------------------------------------


def test_timeline_summary_bottom_remesh_is_not_a_downgrade():
    tl = Timeline()
    tl.record_migration(step=3, from_rung="lean", to_rung="lean",
                        reason="device-loss", kind="remesh", cost_steps=1)
    s = tl.summary()
    assert s["n_migrations"] == 1 and s["remesh_migrations"] == 1
    assert s["downgrades"] == 0 and s["upgrades"] == 0


def test_timeline_json_roundtrip(tmp_path):
    tl = Timeline()
    tl.record_step(step=0, rung="full", latency_s=0.1, observed_s=0.1,
                   loss=2.0, warmup=True)
    tl.record_step(step=1, rung="full", latency_s=0.1, observed_s=0.3, loss=1.9)
    tl.record_migration(step=1, from_rung="full", to_rung="lean",
                        reason="interference", kind="in-place")
    p = str(tmp_path / "tl.json")
    tl.save(p)
    with open(p) as f:
        back = Timeline.from_json(json.load(f))
    assert len(back.steps) == 2 and len(back.migrations) == 1
    assert back.migrations[0].to_rung == "lean"
    assert back.summary()["downgrades"] == 1
    assert back.rung_at(1) == "full"


# ---------------------------------------------------------------------------
# the integration test: synthetic burst -> downgrade -> recover, no restart
# ---------------------------------------------------------------------------


def _ladder_with_estimates():
    rungs = default_rung_ladder(batch=8, microbatch=1, attn_impl="naive")
    for r in rungs:
        r.latency_estimate_s = 0.1 * r.rel_latency
    return rungs


def _session(rungs, trace, **kw):
    def latency_fn(step, rung, dt):
        eff = trace.effective_slowdown(step, rung.interference_sensitivity) \
            if trace else 1.0
        return rung.latency_estimate_s * eff

    return TrainSession(TINY, rungs, optimizer=sgd(), lr=0.05,
                        batch_fn=make_batch_fn(TINY, 8, 32),
                        latency_fn=latency_fn, trace=trace,
                        adaptive=True, upgrade_patience=4, verbose=False, **kw)


def test_session_burst_downgrade_recover_no_restart():
    steps, burst = 34, (8, 20, 3.0)
    trace = InterferenceTrace.parse(f"{burst[0]}:{burst[1]}:{burst[2]}")
    res = _session(_ladder_with_estimates(), trace).run(steps)
    tl = res.timeline

    # (a) downgrades to a cheaper rung within the monitor's detection window
    downs = [m for m in tl.migrations if m.reason == "interference"]
    assert downs, "no downgrade under a 3x burst"
    assert burst[0] <= downs[0].step <= burst[0] + 3, \
        f"detection too slow: {downs[0].step}"

    # (b) upgrades back after the clear-streak hysteresis
    ups = [m for m in tl.migrations if m.reason == "clear"]
    assert ups and all(m.step >= burst[1] for m in ups), \
        "upgraded before the burst cleared"
    assert res.final_rung == "full", "did not recover the fastest rung"

    # (c) never restarts: one continuous state, every step trained once
    assert len(res.losses) == steps
    assert int(res.state["step"]) == steps
    assert all(m.kind == "in-place" for m in tl.migrations)
    assert [s.step for s in tl.steps] == list(range(steps))

    # (d) final loss within tolerance of the uninterfered run
    res_clean = _session(_ladder_with_estimates(), None).run(steps)
    assert not res_clean.timeline.migrations
    assert res.losses[-1] == pytest.approx(res_clean.losses[-1], rel=0.05)
    # training still works end to end
    assert res.losses[-1] < res.losses[0]


def test_session_thermal_burst_downgrade_recover():
    """Closed-loop thermal throttling: the full rung heats the die until the
    throttle engages (the burst), the controller downgrades, the cheaper
    rung's lower power draw lets the die cool below the release threshold,
    and the clear streak upgrades back — the relinquish-and-recover dynamic
    with the event source's own hysteresis constants."""
    trace = ThermalTrace(heat_rate=0.5, cool_rate=0.3, slowdown=4.0,
                         trigger_temp=1.0, release_temp=0.4)
    res = _session(_ladder_with_estimates(), trace).run(40)
    tl = res.timeline

    downs = [m for m in tl.migrations if m.reason == "interference"]
    assert downs, "no downgrade under a 4x thermal throttle"
    # heating 0.2/step net at full power: throttle engages at step 4;
    # detection follows within the monitor's window
    assert downs[0].step >= 4, "downgraded before the throttle engaged"
    ups = [m for m in tl.migrations if m.reason == "clear"]
    assert ups, "never recovered after cooling below the release threshold"
    assert ups[0].step > downs[0].step
    assert all(m.kind == "in-place" for m in tl.migrations)
    assert len(res.losses) == 40 and int(res.state["step"]) == 40


def test_train_cli_adaptive_with_thermal_trace(tmp_path):
    from repro.launch import train as T
    out = str(tmp_path / "tl.json")
    losses = T.main(["--arch", "granite-3-2b", "--reduced", "--steps", "16",
                     "--batch", "8", "--seq", "32", "--optimizer", "adam",
                     "--lr", "1e-3", "--log-every", "100", "--adaptive",
                     "--thermal-trace", "0.6:0.3:6.0",
                     "--timeline-out", out])
    assert len(losses) == 16
    with open(out) as f:
        tl = Timeline.from_json(json.load(f))
    assert any(m.reason == "interference" for m in tl.migrations), \
        "a 6x thermal throttle must trigger at least one downgrade"


def test_train_cli_rejects_both_traces():
    from repro.launch import train as T
    with pytest.raises(SystemExit):
        T.main(["--arch", "llama3.2-1b", "--reduced", "--steps", "2",
                "--interference-trace", "1:2:2.0",
                "--thermal-trace", "0.5:0.2:2.0"])


def test_session_resume_casts_params_to_active_rung_dtype():
    import jax.numpy as jnp
    from repro.launch.steps import cast_params

    res = _session(_ladder_with_estimates(), None).run(2)
    # simulate a checkpoint written while downgraded to the bf16 rung
    stale = dict(res.state)
    stale["params"] = cast_params(res.state["params"], jnp.bfloat16)
    res2 = _session(_ladder_with_estimates(), None).run(4, start=2, state=stale)
    assert res2.final_rung == "full"
    for leaf in jax.tree_util.tree_leaves(res2.state["params"]):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert leaf.dtype == jnp.float32


def test_session_static_ignores_burst():
    trace = InterferenceTrace.parse("4:10:5.0")
    rungs = [dataclasses.replace(_ladder_with_estimates()[0], name="static")]
    res = _session(rungs, trace).run(14)
    assert not res.timeline.migrations  # single rung: nothing to migrate to
    assert {s.rung for s in res.timeline.steps} == {"static"}


def test_train_cli_adaptive_with_trace(tmp_path):
    from repro.launch import train as T
    out = str(tmp_path / "tl.json")
    losses = T.main(["--arch", "granite-3-2b", "--reduced", "--steps", "14",
                     "--batch", "8", "--seq", "32", "--optimizer", "adam",
                     "--lr", "1e-3", "--log-every", "100", "--adaptive",
                     "--interference-trace", "4:10:8.0",
                     "--timeline-out", out])
    assert len(losses) == 14
    with open(out) as f:
        tl = Timeline.from_json(json.load(f))
    assert any(m.reason == "interference" for m in tl.migrations), \
        "an 8x burst must trigger at least one downgrade"
    assert len(tl.steps) == 14


def test_train_cli_resume_past_end_exits_cleanly(tmp_path):
    from repro.launch import train as T
    ckpt = str(tmp_path / "ck")
    T.main(["--arch", "llama3.2-1b", "--reduced", "--steps", "4",
            "--batch", "2", "--seq", "16", "--ckpt-dir", ckpt,
            "--ckpt-every", "2", "--log-every", "100"])
    # resumed step (4) >= --steps (3): no IndexError, empty loss list
    losses = T.main(["--arch", "llama3.2-1b", "--reduced", "--steps", "3",
                     "--batch", "2", "--seq", "16", "--ckpt-dir", ckpt,
                     "--resume", "--log-every", "100"])
    assert losses == []
