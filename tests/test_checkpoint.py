"""Checkpoint store/manager: roundtrip, bf16, retention, restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.store import load_pytree, save_pytree


def test_roundtrip_mixed_tree(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
              "d": (jnp.zeros((3,), jnp.int32), "meta", 7)},
        "scalar": 3.5,
        "none": None,
    }
    p = str(tmp_path / "t.ckpt")
    save_pytree(tree, p)
    back = load_pytree(p)
    np.testing.assert_array_equal(np.asarray(tree["a"]), back["a"])
    np.testing.assert_array_equal(np.asarray(tree["b"]["c"], np.float32),
                                  np.asarray(back["b"]["c"], np.float32))
    assert back["b"]["d"][1] == "meta" and back["b"]["d"][2] == 7
    assert str(back["b"]["c"].dtype) == "bfloat16"
    assert back["none"] is None


def test_manager_rolling_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30):
        mgr.save(step, {"w": jnp.full((4,), float(step))})
    assert mgr.steps() == [20, 30]
    step, state = mgr.restore_latest()
    assert step == 30
    np.testing.assert_array_equal(state["w"], np.full((4,), 30.0))


def test_restart_resumes_training(tmp_path):
    """Kill/restart: the train driver resumes from the saved step."""
    from repro.launch import train as T
    ckpt = str(tmp_path / "ck")
    losses1 = T.main(["--arch", "llama3.2-1b", "--reduced", "--steps", "6",
                      "--batch", "2", "--seq", "16", "--ckpt-dir", ckpt,
                      "--ckpt-every", "3", "--log-every", "100"])
    mgr = CheckpointManager(ckpt)
    assert mgr.steps(), "no checkpoint written"
    losses2 = T.main(["--arch", "llama3.2-1b", "--reduced", "--steps", "9",
                      "--batch", "2", "--seq", "16", "--ckpt-dir", ckpt,
                      "--resume", "--ckpt-every", "3", "--log-every", "100"])
    assert len(losses2) == 3, "resume should continue from step 6, not restart"


def test_atomicity_no_tmp_left(tmp_path):
    p = str(tmp_path / "x.ckpt")
    save_pytree({"a": jnp.ones((2,))}, p)
    leftovers = [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert not leftovers
