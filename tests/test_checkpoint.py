"""Checkpoint store/manager: roundtrip, bf16, retention, restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.checkpoint.store import load_pytree, save_pytree


def test_roundtrip_mixed_tree(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16) * 1.5,
              "d": (jnp.zeros((3,), jnp.int32), "meta", 7)},
        "scalar": 3.5,
        "none": None,
    }
    p = str(tmp_path / "t.ckpt")
    save_pytree(tree, p)
    back = load_pytree(p)
    np.testing.assert_array_equal(np.asarray(tree["a"]), back["a"])
    np.testing.assert_array_equal(np.asarray(tree["b"]["c"], np.float32),
                                  np.asarray(back["b"]["c"], np.float32))
    assert back["b"]["d"][1] == "meta" and back["b"]["d"][2] == 7
    assert str(back["b"]["c"].dtype) == "bfloat16"
    assert back["none"] is None


def test_manager_rolling_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (10, 20, 30):
        mgr.save(step, {"w": jnp.full((4,), float(step))})
    assert mgr.steps() == [20, 30]
    step, state = mgr.restore_latest()
    assert step == 30
    np.testing.assert_array_equal(state["w"], np.full((4,), 30.0))


def test_restart_resumes_training(tmp_path):
    """Kill/restart: the train driver resumes from the saved step."""
    from repro.launch import train as T
    ckpt = str(tmp_path / "ck")
    losses1 = T.main(["--arch", "llama3.2-1b", "--reduced", "--steps", "6",
                      "--batch", "2", "--seq", "16", "--ckpt-dir", ckpt,
                      "--ckpt-every", "3", "--log-every", "100"])
    mgr = CheckpointManager(ckpt)
    assert mgr.steps(), "no checkpoint written"
    losses2 = T.main(["--arch", "llama3.2-1b", "--reduced", "--steps", "9",
                      "--batch", "2", "--seq", "16", "--ckpt-dir", ckpt,
                      "--resume", "--ckpt-every", "3", "--log-every", "100"])
    assert len(losses2) == 3, "resume should continue from step 6, not restart"


def test_atomicity_no_tmp_left(tmp_path):
    p = str(tmp_path / "x.ckpt")
    save_pytree({"a": jnp.ones((2,))}, p)
    leftovers = [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert not leftovers


# ---------------------------------------------------------------------------
# crash consistency: checksums, torn writes, fallback restore
# ---------------------------------------------------------------------------


def test_corrupt_payload_detected(tmp_path):
    from repro.checkpoint.store import CheckpointCorrupt
    p = str(tmp_path / "c.ckpt")
    save_pytree({"w": jnp.arange(64, dtype=jnp.float32)}, p)
    blob = bytearray(open(p, "rb").read())
    blob[len(blob) // 2] ^= 0xFF  # flip one payload byte
    with open(p, "wb") as f:
        f.write(blob)
    with pytest.raises(CheckpointCorrupt):
        load_pytree(p)


def test_truncated_file_detected(tmp_path):
    from repro.checkpoint.store import CheckpointCorrupt
    p = str(tmp_path / "t.ckpt")
    save_pytree({"w": jnp.arange(64, dtype=jnp.float32)}, p)
    blob = open(p, "rb").read()
    with open(p, "wb") as f:
        f.write(blob[:len(blob) // 2])  # torn mid-write
    with pytest.raises(CheckpointCorrupt):
        load_pytree(p)


def test_legacy_headerless_checkpoint_still_loads(tmp_path):
    """Pre-checksum checkpoints (raw compressed msgpack, no magic) load
    through the legacy fallback path."""
    from repro.checkpoint.store import _HEADER, serialize_pytree
    p = str(tmp_path / "legacy.ckpt")
    blob = serialize_pytree({"w": jnp.full((3,), 2.0)})
    payload = blob[_HEADER.size:]  # strip magic+crc -> legacy layout
    with open(p, "wb") as f:
        f.write(payload)
    back = load_pytree(p)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.full((3,), 2.0))


def test_restore_latest_falls_back_past_corrupt_newest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, {"w": jnp.full((2,), 1.0)})
    mgr.save(2, {"w": jnp.full((2,), 2.0)})
    # step 3 is torn mid-write; an orphan .tmp also survives the "crash"
    blob = open(mgr._path(2), "rb").read()
    with open(mgr._path(3), "wb") as f:
        f.write(blob[:10])
    with open(mgr._path(3) + ".tmp", "wb") as f:
        f.write(b"\x00" * 8)
    with pytest.warns(RuntimeWarning, match="unreadable"):
        step, state = mgr.restore_latest()
    assert step == 2
    np.testing.assert_array_equal(np.asarray(state["w"]), np.full((2,), 2.0))


def test_restore_latest_none_when_everything_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    for s in (1, 2):
        with open(mgr._path(s), "wb") as f:
            f.write(b"garbage")
    with pytest.warns(RuntimeWarning):
        assert mgr.restore_latest() is None


def test_retention_never_prunes_just_written(tmp_path):
    """keep=0 is a misconfiguration; save() must still leave the checkpoint
    it just wrote on disk."""
    mgr = CheckpointManager(str(tmp_path), keep=0)
    mgr.save(1, {"w": jnp.ones((2,))})
    mgr.save(2, {"w": jnp.ones((2,))})
    assert mgr.steps() == [2]
    step, _ = mgr.restore_latest()
    assert step == 2


def test_retention_tolerates_concurrent_unlink(tmp_path):
    """A pruner racing with another process: the file it wants to unlink is
    already gone. save() must treat that as success."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, {"w": jnp.ones((2,))})
    mgr.save(2, {"w": jnp.ones((2,))})
    # simulate the race: step 2 is pruned out from under the manager just
    # before save(3) runs its retention pass over a stale steps() listing
    real_steps = CheckpointManager.steps

    def stale_steps(self):
        out = real_steps(self)
        if 2 in out:
            os.unlink(self._path(2))  # racer wins
        return out

    CheckpointManager.steps = stale_steps
    try:
        mgr.save(3, {"w": jnp.ones((2,))})
    finally:
        CheckpointManager.steps = real_steps
    assert 3 in mgr.steps()
