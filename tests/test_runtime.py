"""Elastic mesh ladder + checkpoint restore across a mesh-shape change.

The multi-device cases run in subprocesses because the host device count must
be forced before jax initializes (see conftest note).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.runtime.elastic import ElasticController, default_mesh_ladder

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.parametrize("total", list(range(1, 17)))
def test_default_mesh_ladder_shapes_positive_and_fit(total):
    lad = default_mesh_ladder(total)
    assert lad, f"empty ladder for total={total}"
    for shape in lad:
        assert all(dim > 0 for dim in shape), \
            f"zero-size shape {shape} for total={total}"
        assert int(np.prod(shape)) <= total, \
            f"shape {shape} does not fit pool of {total}"
    # fastest first: sizes never increase down the ladder
    sizes = [int(np.prod(s)) for s in lad]
    assert sizes == sorted(sizes, reverse=True)


def test_elastic_controller_single_device_pool():
    ctl = ElasticController(total_devices=1)
    assert ctl.current_shape() == (1, 1)
    mesh = ctl.make_mesh()
    assert mesh.devices.size == 1


def test_elastic_controller_downgrades_on_failure():
    ctl = ElasticController(total_devices=8)
    assert ctl.current_shape() == (2, 4)
    ctl.mark_failed([6, 7])
    assert ctl.n_healthy == 6
    assert ctl.current_shape() == (1, 4)
    ctl.mark_recovered([6, 7])
    assert ctl.current_shape() == (2, 4)
    assert ctl.healthy_ids() == list(range(8))


def test_elastic_make_mesh_shape_override_must_fit():
    ctl = ElasticController(total_devices=1)
    with pytest.raises(ValueError):
        ctl.make_mesh(shape=(2, 2))


# ---------------------------------------------------------------------------
# checkpoint restore across a mesh-shape change (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

MESH_CHANGE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.engine.events import ScriptedFaults
from repro.engine.rungs import default_rung_ladder
from repro.engine.session import TrainSession
from repro.launch.train import make_batch_fn
from repro.optim.optimizers import sgd
from repro.runtime.elastic import ElasticController

cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                  tie_embeddings=True, source="test")
batch_fn = make_batch_fn(cfg, 8, 16)
out = {}

# --- part 1: manager-level save under full mesh, restore under downgraded ---
elastic = ElasticController(total_devices=8)
mesh_full = elastic.make_mesh()
mgr = CheckpointManager(%r)

rungs = default_rung_ladder(batch=8, microbatch=1, attn_impl="naive",
                            include_bf16=False)
ses = TrainSession(cfg, [rungs[0]], optimizer=sgd(), lr=0.05,
                   batch_fn=batch_fn, elastic=elastic, adaptive=False,
                   verbose=False)
res = ses.run(4)
mgr.save(4, res.state)

elastic.mark_failed([4, 5, 6, 7])
assert elastic.current_shape() != (2, 4)
mesh_small = elastic.make_mesh()
step, restored = mgr.restore_latest(mesh=mesh_small)
host_a = jax.tree_util.tree_map(lambda a: np.asarray(jax.device_get(a)),
                                res.state["params"])
host_b = jax.tree_util.tree_map(lambda a: np.asarray(jax.device_get(a)),
                                restored["params"])
diffs = jax.tree_util.tree_leaves(jax.tree_util.tree_map(
    lambda a, b: float(np.max(np.abs(a.astype(np.float64) - b.astype(np.float64)))),
    host_a, host_b))
out["restore_step"] = int(step)
out["param_max_diff"] = max(diffs)
out["restored_mesh_devices"] = int(mesh_small.devices.size)

# continue training from the restored state under the downgraded mesh, and
# compare the loss trajectory with a run that never migrated
ses2 = TrainSession(cfg, [rungs[0]], optimizer=sgd(), lr=0.05,
                    batch_fn=batch_fn, elastic=elastic, adaptive=False,
                    verbose=False)
res2 = ses2.run(8, start=4, state=restored)

ref_elastic = ElasticController(total_devices=8)
ref = TrainSession(cfg, [default_rung_ladder(batch=8, microbatch=1,
                                             attn_impl="naive",
                                             include_bf16=False)[0]],
                   optimizer=sgd(), lr=0.05, batch_fn=batch_fn,
                   elastic=ref_elastic, adaptive=False, verbose=False)
res_ref = ref.run(8)
out["migrated_losses"] = res2.losses
out["ref_losses"] = res_ref.losses[4:]

# --- part 2: the session's own device-loss remesh (one ckpt round-trip) ---
elastic3 = ElasticController(total_devices=8)
rungs3 = default_rung_ladder(batch=8, microbatch=1, attn_impl="naive",
                             include_bf16=False)
for r in rungs3:
    r.latency_estimate_s = 0.1 * r.rel_latency
ses3 = TrainSession(cfg, rungs3, optimizer=sgd(), lr=0.05, batch_fn=batch_fn,
                    elastic=elastic3, fault_events=ScriptedFaults({3: (6, 7)}),
                    latency_fn=lambda step, rung, dt: rung.latency_estimate_s,
                    adaptive=True, verbose=False)
res3 = ses3.run(8)
out["session_losses"] = res3.losses
out["session_migrations"] = [
    {"step": m.step, "reason": m.reason, "kind": m.kind,
     "from": m.from_rung, "to": m.to_rung}
    for m in res3.timeline.migrations]
out["session_final_step"] = int(res3.state["step"])
print("RESULT:" + json.dumps(out))
"""


def test_checkpoint_restore_across_mesh_change(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC)
    script = MESH_CHANGE_SCRIPT % str(tmp_path / "ck")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-4000:]}"
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT:")][-1]
    rec = json.loads(line[len("RESULT:"):])

    # values survive the re-shard bit-exactly; the mesh genuinely shrank
    assert rec["restore_step"] == 4
    assert rec["param_max_diff"] == 0.0
    assert rec["restored_mesh_devices"] == 4

    # loss trajectory after the migration matches the no-migration run
    mig = np.asarray(rec["migrated_losses"])
    ref = np.asarray(rec["ref_losses"])
    np.testing.assert_allclose(mig, ref, rtol=1e-3, atol=1e-4)

    # the session's device-loss path: downgrade routed through
    # force_downgrade, state carried through one remesh round-trip
    mig3 = rec["session_migrations"]
    assert any(m["reason"] == "device-loss" for m in mig3)
    assert any(m["kind"] == "remesh" for m in mig3)
    assert rec["session_final_step"] == 8
    assert all(np.isfinite(rec["session_losses"]))


# ---------------------------------------------------------------------------
# fault schedules: seeded determinism
# ---------------------------------------------------------------------------


def test_fault_model_events_seeded_determinism():
    """The FaultModel -> runtime event adapter produces the identical
    device-loss schedule for the same seed — chaos runs and their fault-free
    controls must disagree only where a fault was injected, never because
    the fault source itself drifted."""
    from repro.engine.events import FaultModelEvents
    from repro.runtime.fault import FaultModel

    def schedule(seed):
        ev = FaultModelEvents(FaultModel(mtbf_steps=4.0, seed=seed))
        return [ev(step, range(8)) for step in range(32)]

    assert schedule(11) == schedule(11)
    assert schedule(11) != schedule(12)


def test_scripted_faults_ignore_already_failed():
    from repro.engine.events import ScriptedFaults
    ev = ScriptedFaults({3: (1, 5)})
    assert ev(3, [0, 1, 2, 3]) == (1,)  # device 5 already gone
    assert ev(4, [0, 1, 2, 3]) == ()
