"""Trip-count-aware HLO cost analysis: validated against XLA's own model on
loop-free graphs and against exact analytics on scans."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_cost import analyze
from repro.core.profiler import parse_collective_bytes


def _compile(f, *sds):
    return jax.jit(f).lower(*sds).compile()


def test_matches_xla_on_loop_free():
    def g(x, w1, w2):
        return ((x @ w1) @ w2).sum()

    sds = lambda s: jax.ShapeDtypeStruct(s, jnp.float32)
    comp = _compile(g, sds((64, 128)), sds((128, 256)), sds((256, 64)))
    mine = analyze(comp.as_text())
    xc = comp.cost_analysis()
    if isinstance(xc, list):
        xc = xc[0]
    assert abs(mine.flops - xc["flops"]) / xc["flops"] < 0.01
    assert abs(mine.bytes - xc["bytes accessed"]) / xc["bytes accessed"] < 0.2


@pytest.mark.parametrize("length", [3, 7, 16])
def test_scan_flops_weighted_by_trip_count(length):
    def f(x):
        def body(c, _):
            return c @ x, None
        c, _ = jax.lax.scan(body, jnp.ones((32, 32)), None, length=length)
        return c.sum()

    comp = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    mine = analyze(comp.as_text())
    expected = length * 2 * 32 ** 3
    assert abs(mine.flops - expected) / expected < 0.05


def test_nested_scan_multiplies():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ x, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        c, _ = jax.lax.scan(outer, jnp.ones((16, 16)), None, length=3)
        return c.sum()

    comp = _compile(f, jax.ShapeDtypeStruct((16, 16), jnp.float32))
    mine = analyze(comp.as_text())
    expected = 3 * 4 * 2 * 16 ** 3
    assert abs(mine.flops - expected) / expected < 0.05


def test_train_step_flops_close_to_analytic():
    """HLO flops of a tiny dense-LM train step within band of 6*N*D."""
    from repro.configs import REGISTRY
    from repro.models import build_model
    from repro.launch.steps import build_train_step, init_train_state
    from repro.optim.optimizers import sgd

    cfg = REGISTRY["llama3.2-1b"].reduced()
    model = build_model(cfg, impl="naive")
    opt = sgd()
    step = build_train_step(model, opt)
    state = jax.eval_shape(lambda: init_train_state(model, opt, jax.random.PRNGKey(0)))
    B, S = 4, 32
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    comp = jax.jit(step).lower(state, batch).compile()
    mine = analyze(comp.as_text())
    n = cfg.param_count()
    analytic = 6 * n * B * S
    # naive attention adds quadratic terms; reduced config keeps them small
    assert 0.6 * analytic < mine.flops < 3.0 * analytic


def test_collective_parse_kinds():
    hlo = """
ENTRY %main (p: f32[8,8]) -> f32[8,8] {
  %p = f32[8,8] parameter(0)
  %ar = f32[8,8] all-reduce(%p), to_apply=%add
  %ag = f32[16,8] all-gather(%ar), dimensions={0}
  ROOT %cp = f32[8,8] collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    got = parse_collective_bytes(hlo)
    assert got["all-reduce"] == 8 * 8 * 4
    assert got["all-gather"] == 16 * 8 * 4
    assert got["collective-permute"] == 8 * 8 * 4
