"""Small-mesh dry-run in a subprocess (device count must be set pre-jax-init).

Proves the lower+compile path works for a reduced config on a (2,2,2)
pod/data/model mesh — the CI-scale version of the 2x16x16 production dry-run.
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.core.choices import MeshChoice
from repro.core.profiler import roofline_from_compiled
from repro.launch.specs import batch_shardings, batch_specs, param_shardings, replicated
from repro.launch.steps import build_train_step, init_train_state
from repro.models.registry import build_model
from repro.models.sharding import axis_rules
from repro.optim.optimizers import sgd

arch = %r
cfg = REGISTRY[arch].reduced()
choice = MeshChoice((2, 2, 2), ("pod", "data", "model"), microbatch=2, remat="dots")
from repro.compat import make_mesh, set_mesh
mesh = make_mesh(choice.mesh_shape, choice.axis_names)
rules = choice.rules()
model = build_model(cfg, impl="chunked", chunk=8, remat=choice.remat)
params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
opt = sgd()
step = build_train_step(model, opt, microbatch=choice.microbatch)
state_sds = {"params": params_sds, "opt": (), "err": (),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}

class Shape:
    global_batch, seq_len, mode = 8, 16, "train"
    name = "tiny"

with set_mesh(mesh):
    with axis_rules(rules):
        p_shard = param_shardings(params_sds, mesh, rules)
        state_shard = {"params": p_shard, "opt": (), "err": (), "step": replicated(mesh)}
        batch_sds = batch_specs(cfg, Shape)
        b_shard = batch_shardings(batch_sds, mesh, rules)
        lowered = jax.jit(step, in_shardings=(state_shard, b_shard),
                          out_shardings=(state_shard, {"loss": replicated(mesh),
                                                       "grad_norm": replicated(mesh)}),
                          donate_argnums=(0,)).lower(state_sds, batch_sds)
        compiled = lowered.compile()
        terms = roofline_from_compiled(compiled, compiled.as_text(), choice.n_chips)
print(json.dumps({"ok": True, "flops": terms.flops, "coll": terms.collective_bytes,
                  "mem": terms.per_device_memory}))
"""


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-moe-16b", "rwkv6-7b"])
def test_small_mesh_dryrun(arch):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT % arch], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    last = [l for l in out.stdout.splitlines() if l.startswith("{")][-1]
    rec = json.loads(last)
    assert rec["ok"] and rec["flops"] > 0
