"""Speculative decoding: verify kernels, verifiers, draft sources, engine.

The invariant everything here defends: speculation changes *how fast*
tokens come out, never *which* tokens. Greedy speculative decode must be
token-identical to one-token greedy decode — per layout (contig/paged),
per attention impl (naive/pallas), and across draft-depth changes
mid-stream (the serving rung the arbiter walks).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.kernels.flash_attention import flash_decode_spec, flash_decode_spec_paged
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.models.registry import build_model
from repro.spec.draft import ModelDraft, NGramDraft, build_draft_source
from repro.spec.verify import greedy_verify, rejection_verify

TINY = ModelConfig(name="spec-tiny", family="dense", n_layers=2, d_model=64,
                   n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                   tie_embeddings=True, source="tests/test_spec.py")
KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# multi-token verify kernels vs a per-row naive reference
# ---------------------------------------------------------------------------


def _reference(q, k, v, lengths):
    """Per-(batch, draft-row) softmax attention over the causal window:
    row qi of sequence b attends to kv[:lengths[b] + qi + 1]."""
    B, K, S, G, hd = q.shape
    out = np.zeros(q.shape[:4] + (v.shape[-1],), np.float32)
    for b in range(B):
        for kh in range(K):
            for qi in range(S):
                n = int(lengths[b]) + qi + 1
                kk, vv = k[b, :n, kh], v[b, :n, kh]
                s = (q[b, kh, qi] / np.sqrt(hd)) @ kk.T
                p = np.exp(s - s.max(-1, keepdims=True))
                p /= p.sum(-1, keepdims=True)
                out[b, kh, qi] = p @ vv
    return out


def _spec_inputs(seed=0, B=3, K=2, S=3, G=2, hd=64, Smax=160):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal((B, K, S, G, hd)).astype(np.float32)
    k = rng.standard_normal((Smax, B, K, hd)).astype(np.float32)
    k = np.ascontiguousarray(np.moveaxis(k, 1, 0))  # (B, Smax, K, hd)
    v = rng.standard_normal((B, Smax, K, hd)).astype(np.float32)
    lengths = np.array([5, 63, Smax - S], np.int32)  # edge: last tile full
    return q, k, v, lengths


def test_flash_decode_spec_matches_reference():
    q, k, v, lengths = _spec_inputs()
    got = np.asarray(flash_decode_spec(jnp.asarray(q), jnp.asarray(k),
                                       jnp.asarray(v), jnp.asarray(lengths),
                                       block_k=32))
    want = _reference(q, k, v, lengths)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_flash_decode_spec_paged_matches_reference():
    q, k, v, lengths = _spec_inputs()
    B, Smax, K, hd = k.shape
    bs = 32
    T = Smax // bs
    # scatter each sequence's blocks into a shuffled physical pool
    rng = np.random.default_rng(1)
    phys = rng.permutation(B * T)
    table = phys.reshape(B, T).astype(np.int32)
    k_pool = np.zeros((B * T, bs, K, hd), np.float32)
    v_pool = np.zeros((B * T, bs, K, hd), np.float32)
    for b in range(B):
        for t in range(T):
            k_pool[table[b, t]] = k[b, t * bs:(t + 1) * bs]
            v_pool[table[b, t]] = v[b, t * bs:(t + 1) * bs]
    got = np.asarray(flash_decode_spec_paged(
        jnp.asarray(q), jnp.asarray(k_pool), jnp.asarray(v_pool),
        jnp.asarray(table), jnp.asarray(lengths)))
    want = _reference(q, k, v, lengths)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# verifiers
# ---------------------------------------------------------------------------


def test_greedy_verify_equals_sequential_argmax():
    rng = np.random.default_rng(3)
    for _ in range(20):
        S, V = int(rng.integers(1, 5)), 16
        logits = rng.standard_normal((2, S, V)).astype(np.float32)
        drafts = rng.integers(0, V, (2, S - 1)).astype(np.int32)
        toks, n_emit = jax.device_get(
            greedy_verify(jnp.asarray(logits), jnp.asarray(drafts)))
        for b in range(2):
            best = logits[b].argmax(-1)
            want = []
            for i in range(S):
                want.append(int(best[i]))
                if i < S - 1 and drafts[b, i] != best[i]:
                    break
            assert list(toks[b, :n_emit[b]]) == want


def test_greedy_verify_full_acceptance_and_bonus():
    logits = np.full((1, 3, 8), -5.0, np.float32)
    logits[0, 0, 2] = logits[0, 1, 4] = logits[0, 2, 7] = 5.0
    toks, n = jax.device_get(greedy_verify(
        jnp.asarray(logits), jnp.asarray([[2, 4]], np.int32)))
    assert int(n[0]) == 3 and list(toks[0]) == [2, 4, 7]


def test_rejection_verify_accepts_certain_drafts():
    """One-hot proposals whose tokens carry ~all target mass: every draft
    accepted, bonus appended, emission count is the full window."""
    V, S = 8, 4
    logits = np.full((1, S, V), -20.0, np.float32)
    want = [1, 5, 3, 6]
    for i, t in enumerate(want):
        logits[0, i, t] = 20.0
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(9), i))(
        jnp.arange(S))[None]
    toks, n = jax.device_get(rejection_verify(
        jnp.asarray(logits), jnp.asarray([want[:-1]], np.int32), None, keys,
        temperature=0.7))
    assert int(n[0]) == S and list(toks[0]) == want


def test_rejection_verify_rejects_impossible_drafts():
    """A draft with zero target mass must be rejected and resampled from
    the (renormalized) residual = target distribution."""
    V = 8
    logits = np.full((1, 2, V), -jnp.inf, np.float32)
    logits[0, :, 3] = 0.0  # target mass entirely on token 3
    keys = jax.vmap(lambda i: jax.random.fold_in(jax.random.PRNGKey(4), i))(
        jnp.arange(2))[None]
    toks, n = jax.device_get(rejection_verify(
        jnp.asarray(logits), jnp.asarray([[5]], np.int32), None, keys,
        temperature=1.0))
    assert int(n[0]) == 1 and int(toks[0, 0]) == 3


# ---------------------------------------------------------------------------
# draft sources
# ---------------------------------------------------------------------------


def test_ngram_draft_rides_cycles():
    d = NGramDraft(max_n=3)
    d.admit(0, [7, 8, 9, 7, 8])
    drafts, probs = d.propose([0], 5)
    assert probs is None
    assert list(drafts[0]) == [9, 7, 8, 9, 7]  # chains through the window


def test_ngram_draft_most_recent_wins_and_release():
    d = NGramDraft(max_n=2)
    d.admit(1, [1, 2, 5, 1, 2, 9])  # context (1,2) -> 5 then -> 9
    drafts, _ = d.propose([1], 1)
    assert int(drafts[0, 0]) == 9
    d.release(1)
    drafts, _ = d.propose([1], 2)  # unknown slot: cold-start fallback
    assert drafts.shape == (1, 2)


def test_model_draft_rollback_bookkeeping():
    model = build_model(TINY, impl="naive")
    params = model.init(KEY)
    d = ModelDraft(model, params, max_batch=2, max_seq=32)
    d.admit(0, [3, 4, 5])
    drafts, probs = d.propose([0], 3)
    assert probs is None and drafts.shape == (1, 3)
    assert int(d.cache_len[0]) == 5  # 3 prompt + 2 ingested proposals
    d.commit(0, [int(drafts[0, 0])], 99)  # 1 accepted, rollback the rest
    assert int(d.cache_len[0]) == 4  # base 3 + 1 accepted
    assert d._pending[0] == [99]
    d2, _ = d.propose([0], 2)
    assert int(d.cache_len[0]) == 6  # caught up to 5, ingested 1 proposal


def test_build_draft_source_rejects_unknown():
    with pytest.raises(ValueError, match="unknown draft source"):
        build_draft_source("no-such-arch")


# ---------------------------------------------------------------------------
# engine: greedy speculative decode is token-identical
# ---------------------------------------------------------------------------


def _requests(n=8, seed=0, gen=12):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, TINY.vocab_size,
                                        int(rng.integers(3, 14))
                                        ).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, gen + 1)))
            for i in range(n)]


def _run(model, params, reqs, **kw):
    engine = ContinuousBatchingEngine(model, params, max_batch=3, max_seq=64,
                                      **kw)
    fin = engine.run([Request(r.uid, r.prompt.copy(), r.max_new_tokens)
                      for r in reqs])
    return {u: f.tokens for u, f in fin.items()}, engine


@pytest.fixture(scope="module")
def tiny_model():
    model = build_model(TINY, impl="naive")
    return model, model.init(KEY)


@pytest.fixture(scope="module")
def greedy_baseline(tiny_model):
    model, params = tiny_model
    return _run(model, params, _requests())[0]


@pytest.mark.parametrize("layout", ["contig", "paged"])
@pytest.mark.parametrize("depth", [1, 3])
def test_engine_greedy_token_identity(tiny_model, greedy_baseline, layout,
                                      depth):
    model, params = tiny_model
    got, engine = _run(model, params, _requests(), kv_layout=layout,
                       draft_depth=depth)
    assert got == greedy_baseline
    assert engine.spec_rounds > 0 and engine.spec_accepted >= 0
    assert engine.decode_steps <= \
        sum(len(t) for t in greedy_baseline.values())


@pytest.mark.parametrize("layout", ["contig", "paged"])
def test_engine_greedy_token_identity_pallas(greedy_baseline, layout):
    model = build_model(TINY, impl="pallas")
    params = model.init(KEY)
    got, _ = _run(model, params, _requests(), kv_layout=layout,
                  draft_depth=2)
    assert got == greedy_baseline


def test_engine_model_draft_token_identity(tiny_model, greedy_baseline):
    model, params = tiny_model
    draft_model = build_model(
        dataclasses.replace(TINY, name="spec-draft", n_layers=1, d_ff=64),
        impl="naive")
    draft = ModelDraft(draft_model, draft_model.init(jax.random.PRNGKey(5)),
                       max_batch=3, max_seq=64)
    got, engine = _run(model, params, _requests(), draft_depth=2,
                       draft_source=draft)
    assert got == greedy_baseline


def test_engine_spec_sampled_respects_budgets(tiny_model):
    """Sampled speculative serving: right token counts per request and a
    live acceptance counter (distribution faithfulness is the hypothesis
    property in test_property.py)."""
    model, params = tiny_model
    reqs = _requests(6, seed=2)
    got, engine = _run(model, params, reqs, draft_depth=3, temperature=0.8,
                       top_k=32)
    assert {u: len(t) for u, t in got.items()} == \
        {r.uid: r.max_new_tokens for r in reqs}
    assert engine.spec_drafted > 0


def test_set_draft_depth_mid_stream_keeps_identity(tiny_model,
                                                   greedy_baseline):
    """Walking the draft-depth rung mid-stream (the arbiter's move) never
    changes emitted tokens — only how many verify rounds they take."""
    model, params = tiny_model
    engine = ContinuousBatchingEngine(model, params, max_batch=3, max_seq=64,
                                      draft_depth=4)
    for r in _requests():
        engine.submit(Request(r.uid, r.prompt.copy(), r.max_new_tokens))
    depths = [4, 2, 0, 3, 1]
    i = 0
    while engine.has_work:
        engine.set_draft_depth(depths[i % len(depths)])
        engine.step()
        i += 1
    assert {u: f.tokens for u, f in engine.finished.items()} == \
        greedy_baseline
    engine.set_draft_depth(None)  # rung restore: back to as-built depth
    assert engine.draft_depth == 4


def test_late_enable_draft_depth_builds_ngram_source(tiny_model):
    model, params = tiny_model
    engine = ContinuousBatchingEngine(model, params, max_batch=2, max_seq=64)
    assert engine.draft is None
    for r in _requests(4, seed=3):
        engine.submit(Request(r.uid, r.prompt.copy(), r.max_new_tokens))
    for _ in range(3):
        engine.step()
    engine.set_draft_depth(3)  # arbiter walks speculation *up* later
    assert engine.draft is not None
    while engine.has_work:
        engine.step()
    assert engine.spec_rounds > 0


def test_spec_stats_surface(tiny_model):
    model, params = tiny_model
    _, engine = _run(model, params, _requests(4, seed=4), draft_depth=2)
    st = engine.stats()
    assert st["draft_depth"] == 2
    assert st["spec_drafted"] >= st["spec_accepted"] >= 0
    assert 0.0 <= st["spec_acceptance"] <= 1.0
