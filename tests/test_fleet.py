"""Fleet runtime: FLTrainJob device sims + crash-consistent coordination."""
import dataclasses

import numpy as np
import pytest

from repro.engine.chaos import FLEET_KINDS, FleetChaos
from repro.engine.jobs import ForegroundAppJob
from repro.engine.runtime import SwanRuntime
from repro.fl.traces import make_client_traces
from repro.fleet import (CoordinatorCrash, FleetConfig, FleetCoordinator,
                         FLTrainJob, FleetClient, build_fleet_clients,
                         run_client_round)


@pytest.fixture(scope="module")
def traces():
    return make_client_traces(2, seed=3, tz_shifts=24)  # 48 clients


def _cfg(**kw):
    base = dict(n_clients=48, clients_per_round=5, rounds=3, local_steps=8,
                dim=16, seed=3, fg_prob=0.0)
    base.update(kw)
    return FleetConfig(**base)


def _client(traces, cid=0, device="s10e", policy="swan"):
    return FleetClient(cid, device, traces[cid], "shufflenet-v2",
                       policy=policy)


# ---------------------------------------------------------------------------
# the device half: FLTrainJob under SwanRuntime
# ---------------------------------------------------------------------------


def test_pause_exact_resume_bitwise(traces, tmp_path):
    """A foreground burst pauses the job (checkpoint + release); the resumed
    job's finished update is bitwise identical to an uninterrupted run."""
    def run_round(with_fg, sub):
        client = _client(traces)
        job = FLTrainJob(client, rnd=0, local_steps=8, dim=16, seed=3,
                         ckpt_dir=str(tmp_path / sub))
        jobs = [job]
        if with_fg:
            jobs.append(ForegroundAppJob([(2, 5)], latency_s=0.5, power=1.2))
        SwanRuntime(jobs).run(24)
        assert job.done
        return job

    plain = run_round(False, "plain")
    paused = run_round(True, "paused")
    assert plain.pauses == 0 and paused.pauses >= 1
    d0, crc0 = plain.update_payload()
    d1, crc1 = paused.update_payload()
    np.testing.assert_array_equal(d0, d1)
    assert crc0 == crc1


def test_client_round_deterministic(traces, tmp_path):
    cfg = _cfg(fg_prob=0.3)
    outs = [run_client_round(_client(traces, cid=7, device="pixel3"), 0,
                             300.0, cfg, ckpt_root=str(tmp_path / f"r{i}"))
            for i in range(2)]
    assert outs[0].status == outs[1].status
    assert outs[0].latency_s == outs[1].latency_s
    if outs[0].status == "ok":
        np.testing.assert_array_equal(outs[0].delta, outs[1].delta)
        assert outs[0].checksum == outs[1].checksum


def test_baseline_client_has_single_rung(traces, tmp_path):
    client = _client(traces, policy="baseline")
    assert len(client.rungs) == 1
    job = FLTrainJob(client, rnd=0, local_steps=4, dim=8, seed=0,
                     ckpt_dir=str(tmp_path / "b"))
    assert not job.adaptive


# ---------------------------------------------------------------------------
# the coordinator half: acceptance, dedup, checksum, stale window
# ---------------------------------------------------------------------------


def _arrival(cid, arrival_s, dim=16, n=10, corrupt=False):
    import zlib
    rng = np.random.default_rng((99, cid))
    delta = rng.standard_normal(dim).astype(np.float32)
    crc = zlib.crc32(delta.tobytes())
    if corrupt:
        delta = delta.copy()
        delta[0] += 1.0  # checksum now stale
    return {"cid": cid, "arrival_s": float(arrival_s), "delta": delta,
            "n_samples": n, "checksum": crc, "device": "s10e", "charging": 0}


def _hand_coordinator(traces, tmp_path, arrivals, k=4, deadline=10.0,
                      stale=2.5):
    cfg = _cfg()
    clients = build_fleet_clients(cfg, traces=traces)
    co = FleetCoordinator(clients, cfg, state_dir=str(tmp_path))
    counters = {c: 0 for c in ("churned", "offline", "preempted", "straggled",
                               "dropped", "duplicated", "dup_rejected",
                               "corrupt_rejected", "late_rejected",
                               "preemptions")}
    co.state["inflight"] = {
        "rnd": 0, "t_start": 0.0, "online": len(clients),
        "invited": len(arrivals), "k": k, "deadline_s": deadline,
        "stale_s": stale, "arrivals": arrivals, "next_idx": 0,
        "accepted_cids": [], "accepted_on_time": 0, "stale_accepted": 0,
        "last_accept_s": 0.0, "agg": np.zeros(cfg.dim, np.float64),
        "weight": 0.0, "useful_samples": 0.0, "counters": counters,
        "by_class": {}, "by_class_energy": {}, "charging_accepted": 0,
        "retries": 0, "energy_j": 0.0,
    }
    co._save()
    co._finish_round()
    return co.result().rounds[0]


def test_acceptance_dedup_checksum_stale_window(traces, tmp_path):
    arrivals = [
        _arrival(1, 2.0),
        _arrival(1, 3.0),            # duplicate delivery -> dedup reject
        _arrival(2, 4.0, corrupt=True),  # checksum mismatch -> reject
        _arrival(3, 11.0),           # past deadline, inside stale window
        _arrival(4, 13.0),           # past deadline + stale window -> late
    ]
    r = _hand_coordinator(traces, tmp_path, arrivals)
    assert r.accepted == 2 and r.accepted_cids == [1, 3]
    assert r.accepted_on_time == 1 and r.stale_accepted == 1
    assert r.dup_rejected == 1
    assert r.corrupt_rejected == 1
    assert r.late_rejected == 1
    assert r.shortfall == 2  # k=4, only 2 accepted


def test_acceptance_stops_at_capacity(traces, tmp_path):
    arrivals = [_arrival(c, 1.0 + c) for c in range(6)]
    r = _hand_coordinator(traces, tmp_path, arrivals, k=3)
    assert r.accepted == 3 and r.accepted_cids == [0, 1, 2]
    assert r.round_s == 3.0  # last accepted arrival, not the full window


# ---------------------------------------------------------------------------
# end to end: crash parity, churn degradation, determinism
# ---------------------------------------------------------------------------


def _run_fleet(traces, tmp_path, sub, chaos=None, crash=False, **kw):
    cfg = _cfg(**kw)
    clients = build_fleet_clients(cfg, traces=traces)
    d = str(tmp_path / sub)
    co = FleetCoordinator(clients, cfg, state_dir=d, chaos=chaos)
    if not crash:
        return co.run()
    with pytest.raises(CoordinatorCrash):
        co.run()
    return FleetCoordinator.resume(clients, cfg, state_dir=d,
                                   chaos=chaos).run()


def test_crash_resume_bitwise_parity(traces, tmp_path):
    probs = dict(churn_prob=0.1, drop_prob=0.05, dup_prob=0.05,
                 corrupt_prob=0.05)
    clean = _run_fleet(traces, tmp_path, "clean", FleetChaos(seed=5, **probs))
    crashed = _run_fleet(traces, tmp_path, "crash",
                         FleetChaos(seed=5, crash_at=(1, 2), **probs),
                         crash=True)
    assert [r.agg_crc for r in clean.rounds] == \
        [r.agg_crc for r in crashed.rounds]
    assert [r.accepted_cids for r in clean.rounds] == \
        [r.accepted_cids for r in crashed.rounds]


def test_heavy_churn_round_degrades_gracefully(traces, tmp_path):
    ch = FleetChaos(seed=1, churn_rounds={1: 0.5})
    res = _run_fleet(traces, tmp_path, "churn", ch)
    r = res.rounds[1]
    assert r.churned > 0
    assert r.accepted > 0  # retry wave + over-provisioning keep the round alive
    assert r.round_s <= r.deadline_s * 1.25 + 1e-9
    assert "client_churn" in ch.applied


def test_fleet_determinism(traces, tmp_path):
    logs = []
    for i in range(2):
        res = _run_fleet(traces, tmp_path, f"det{i}",
                         FleetChaos(seed=2, churn_prob=0.1, drop_prob=0.05))
        logs.append([dataclasses.asdict(r) for r in res.rounds])
    assert logs[0] == logs[1]


def test_swan_beats_baseline_goodput(traces, tmp_path):
    swan = _run_fleet(traces, tmp_path, "sw", FleetChaos(seed=4,
                                                         drop_prob=0.05))
    base = _run_fleet(traces, tmp_path, "bl",
                      FleetChaos(seed=4, drop_prob=0.05), policy="baseline")
    assert swan.goodput_samples_per_h >= base.goodput_samples_per_h
    assert swan.total_energy_j < base.total_energy_j


def test_fleet_chaos_delivery_is_seeded():
    a = FleetChaos(seed=9, drop_prob=0.3, dup_prob=0.3, corrupt_prob=0.3)
    b = FleetChaos(seed=9, drop_prob=0.3, dup_prob=0.3, corrupt_prob=0.3)
    fates = [a.delivery(0, cid) for cid in range(40)]
    assert fates == [b.delivery(0, cid) for cid in range(40)]
    assert set(fates) >= {"ok", "dropped"}
    for kind in a.applied:
        assert kind in FLEET_KINDS
