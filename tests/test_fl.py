"""FL substrate: traces, selection, aggregation, simulator end-to-end."""
import numpy as np
import pytest

from repro.fl.selection import OortSelector, random_selection
from repro.fl.simulator import FLConfig, compare_policies, run_fl
from repro.fl.traces import (BatteryTrace, generate_raw_trace, make_client_traces,
                             passes_quality_filters, resample_trace)


def test_generated_traces_pass_paper_filters():
    rng = np.random.default_rng(7)
    for _ in range(5):
        ts, lv = generate_raw_trace(rng, days=29)
        assert passes_quality_filters(ts)
        tr = resample_trace(ts, lv)
        assert tr.days >= 28
        assert set(np.unique(tr.state)).issubset({-1, 0, 1})
        assert 0.0 <= tr.level.min() and tr.level.max() <= 1.0


def test_timezone_augmentation_counts():
    traces = make_client_traces(2, seed=1, tz_shifts=24)
    assert len(traces) == 48  # 2 base x 24 shifts (paper §A.2: 100 x 24 = 2400)
    offsets = {t.start_offset_min for t in traces}
    assert len(offsets) == 24


def test_oort_prefers_high_utility():
    sel = OortSelector(epsilon=0.0)
    rng = np.random.default_rng(0)
    for c in range(10):
        sel.report(c, loss=2.0 if c < 5 else 0.1, n_samples=100, latency_s=1.0)
    chosen = sel.select(rng, list(range(10)), 5, deadline_s=10.0)
    assert set(chosen) == {0, 1, 2, 3, 4}


def test_fl_swan_beats_baseline():
    res = compare_policies("shufflenet-v2", rounds=60, n_clients=96,
                           clients_per_round=16, seed=3)
    tgt = min(res["baseline"].final_accuracy, res["swan"].final_accuracy)
    tb = res["baseline"].time_to_accuracy(tgt)
    ts = res["swan"].time_to_accuracy(tgt)
    assert ts is not None and tb is not None and ts <= tb
    assert res["swan"].total_energy_j < res["baseline"].total_energy_j


def test_fl_sim_determinism():
    cfg = FLConfig(workload="resnet34", n_clients=48, rounds=20,
                   clients_per_round=8, seed=11)
    a = run_fl(cfg)
    b = run_fl(cfg)
    assert [r.accuracy for r in a.rounds] == [r.accuracy for r in b.rounds]


def test_make_client_traces_rejects_impossible_days_min():
    # regression: days=5 raw traces can never span the 28-day filter; the
    # old code passed `lv.size and 28.0` positionally as days_min, silently
    # relaxing the filter instead of failing
    with pytest.raises(ValueError, match="days_min"):
        make_client_traces(1, seed=0, days=5, tz_shifts=1,
                           max_attempts_per_trace=3)


def test_pchip_monotone_and_shape_preserving():
    from repro.fl.traces import pchip_interpolate
    rng = np.random.default_rng(5)
    x = np.cumsum(rng.uniform(0.5, 3.0, 40))
    y = np.cumsum(rng.uniform(0.0, 1.0, 40))  # non-decreasing data
    xq = np.linspace(x[0], x[-1] - 1e-9, 500)
    yq = pchip_interpolate(x, y, xq)
    assert np.all(np.diff(yq) >= -1e-9)  # monotone data -> monotone interp
    assert yq.min() >= y.min() - 1e-9 and yq.max() <= y.max() + 1e-9
    # interpolation, not approximation: knots are reproduced
    np.testing.assert_allclose(pchip_interpolate(x, y, x[1:-1]), y[1:-1],
                               atol=1e-9)


def test_quality_filter_rejections():
    day = 1440.0
    dense = np.arange(0.0, 29 * day, 10.0)
    assert passes_quality_filters(dense)
    assert not passes_quality_filters(np.arange(0.0, 10 * day, 10.0))  # short
    sparse = np.arange(0.0, 29 * day, 20.0 * 60.0)  # 72/day < 100/day
    assert not passes_quality_filters(sparse)
    gapped = np.concatenate([dense[dense < 5 * day],
                             dense[dense > 5 * day + 25 * 60.0]])  # 25h gap
    assert not passes_quality_filters(gapped)
    assert not passes_quality_filters(np.array([0.0]))  # degenerate
