"""Property-based tests (hypothesis) for system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis is an optional test dep")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.cost import ChoiceProfile, pareto_prune, total_order
from repro.fl.aggregation import fedavg
from repro.fl.traces import pchip_interpolate
from repro.optim.compression import Compressor


class _C:
    def __init__(self, i):
        self.name = f"c{i}"


profiles_strategy = st.lists(
    st.tuples(st.floats(0.01, 100.0), st.integers(0, 5), st.integers(0, 5)),
    min_size=1, max_size=20,
).map(lambda items: [
    ChoiceProfile(choice=_C(i), latency_s=lat, energy_j=1.0, power_w=1.0,
                  cost_key=(c1, c2))
    for i, (lat, c1, c2) in enumerate(items)])


@given(profiles_strategy)
@settings(max_examples=100, deadline=None)
def test_prune_never_removes_pareto_optimal(profs):
    kept = pareto_prune(profs)
    kept_ids = {p.name for p in kept}
    for p in profs:
        dominated = any(
            (q.latency_s, q.cost_key) != (p.latency_s, p.cost_key)
            and q.latency_s <= p.latency_s and q.cost_key <= p.cost_key
            for q in profs)
        if not dominated:
            assert p.name in kept_ids or any(
                q.latency_s == p.latency_s and q.cost_key == p.cost_key
                for q in kept), f"pareto-optimal {p.name} pruned"


@given(profiles_strategy)
@settings(max_examples=100, deadline=None)
def test_prune_ladder_strictly_cheaper_down(profs):
    """Each successive survivor must strictly relinquish resources."""
    kept = pareto_prune(profs)
    for a, b in zip(kept, kept[1:]):
        assert a.latency_s <= b.latency_s
        assert b.cost_key < a.cost_key


@given(profiles_strategy)
@settings(max_examples=50, deadline=None)
def test_total_order_sorted(profs):
    ordered = total_order(profs)
    lats = [p.latency_s for p in ordered]
    assert lats == sorted(lats)


@given(st.lists(st.floats(-5, 5), min_size=2, max_size=30),
       st.integers(0, 1000))
@settings(max_examples=50, deadline=None)
def test_pchip_interpolates_knots_and_stays_in_range(ys, seed):
    x = np.arange(len(ys), dtype=float)
    y = np.asarray(ys)
    got = pchip_interpolate(x, y, x)
    np.testing.assert_allclose(got, y, rtol=1e-9, atol=1e-9)
    rng = np.random.default_rng(seed)
    xq = rng.uniform(0, len(ys) - 1, 50)
    gq = pchip_interpolate(x, y, xq)
    # shape-preserving: never overshoots the global data range
    assert gq.min() >= y.min() - 1e-9 and gq.max() <= y.max() + 1e-9


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["int8", "topk:0.1", "int8+topk:0.25"]))
@settings(max_examples=25, deadline=None)
def test_compression_error_feedback_conserves_signal(seed, scheme):
    """decompressed + error == original (+ carried error) exactly."""
    comp = Compressor(scheme)
    g = {"w": jax.random.normal(jax.random.PRNGKey(seed), (64,))}
    err = comp.init_error(g)
    dec, new_err = comp.roundtrip(g, err)
    total = dec["w"].astype(jnp.float32) + new_err["w"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(g["w"]),
                               rtol=2e-2, atol=2e-2)


@given(st.integers(2, 6), st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_fedavg_equal_weights_is_mean(n, seed):
    key = jax.random.PRNGKey(seed)
    base = {"w": jnp.zeros((8,))}
    deltas = [{"w": jax.random.normal(jax.random.fold_in(key, i), (8,))}
              for i in range(n)]
    out = fedavg(base, deltas)
    want = jnp.mean(jnp.stack([d["w"] for d in deltas]), 0)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@given(st.floats(0.01, 1.0), st.booleans())
@settings(max_examples=25, deadline=None)
def test_compression_ratio_bounds(frac, use_int8):
    scheme = (("int8+" if use_int8 else "") + f"topk:{frac}")
    r = Compressor(scheme).ratio()
    assert 0 < r <= 1.0


# ---------------------------------------------------------------------------
# paged KV block pool: refcount / COW / prefix-cache / swap interleavings
# ---------------------------------------------------------------------------

# the machine (random op schedule + shadow value model + invariant checks)
# lives next to the deterministic paging tests; hypothesis drives it over a
# much wider seed/length space and shrinks failures
from test_paging import _drive_pool_machine  # noqa: E402


@given(st.integers(0, 2 ** 32 - 1), st.integers(20, 250))
@settings(max_examples=40, deadline=None)
def test_block_pool_interleavings_no_leak_no_corruption(seed, steps):
    """Random admit/share/COW/free/swap schedules: no block is leaked or
    double-freed, the null block is never freed or mapped, every sequence
    reads back exactly the values it wrote, and host swap round-trips are
    value-identical."""
    _drive_pool_machine(seed, steps=steps)


@given(st.integers(0, 2 ** 32 - 1))
@settings(max_examples=15, deadline=None)
def test_block_pool_interleavings_tiny_pool(seed):
    """Same machine under heavy pressure (4 usable blocks): allocation
    failures must be atomic and the cached tier must still balance."""
    _drive_pool_machine(seed, steps=80, num_blocks=5, block_size=2)


# ---------------------------------------------------------------------------
# trace pipeline: PCHIP interpolation + paper §A.2 quality filters
# ---------------------------------------------------------------------------

knots_strategy = st.lists(
    st.tuples(st.floats(0.1, 5.0), st.floats(0.0, 1.0)),
    min_size=3, max_size=30,
).map(lambda items: (
    np.cumsum(np.array([dx for dx, _ in items])),
    np.array([y for _, y in items])))


@given(knots_strategy)
@settings(max_examples=100, deadline=None)
def test_pchip_never_overshoots(knots):
    # shape preservation: PCHIP cannot overshoot the data envelope, for ANY
    # knot placement (this is what keeps interpolated battery levels in [0,1])
    x, y = knots
    xq = np.linspace(x[0], x[-1] - 1e-9, 300)
    yq = pchip_interpolate(x, y, xq)
    assert yq.min() >= y.min() - 1e-7
    assert yq.max() <= y.max() + 1e-7


@given(knots_strategy)
@settings(max_examples=100, deadline=None)
def test_pchip_preserves_monotonicity(knots):
    x, y = knots
    y = np.sort(y)  # force non-decreasing data
    xq = np.linspace(x[0], x[-1] - 1e-9, 300)
    yq = pchip_interpolate(x, y, xq)
    assert np.all(np.diff(yq) >= -1e-7)


@given(st.floats(0.5, 27.0), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=25, deadline=None)
def test_quality_filter_rejects_short_spans(span_days, seed):
    from repro.fl.traces import passes_quality_filters
    rng = np.random.default_rng(seed)
    n = max(2, int(span_days * 150))  # densely sampled, still too short
    ts = np.sort(rng.uniform(0.0, span_days * 1440.0, n))
    assert not passes_quality_filters(ts)


@given(st.integers(1, 2), st.integers(1, 24), st.integers(0, 2 ** 16))
@settings(max_examples=10, deadline=None)
def test_timezone_augmentation_multiplies_exactly(n_base, tz_shifts, seed):
    from repro.fl.traces import make_client_traces
    traces = make_client_traces(n_base, seed=seed, tz_shifts=tz_shifts)
    assert len(traces) == n_base * tz_shifts
    assert len({t.start_offset_min for t in traces}) == tz_shifts


# ---------------------------------------------------------------------------
# speculative decoding: the rejection verifier is distribution-faithful
# ---------------------------------------------------------------------------

from repro.launch.sampling import sample_probs  # noqa: E402
from repro.spec.verify import greedy_verify, rejection_verify  # noqa: E402


def _spec_keys(seed, n, s):
    """(n, s) grid of fold_in(fold_in(base, row), index) keys — the same
    per-(request, emission-index) stream shape the engine uses."""
    base = jax.random.PRNGKey(seed)
    return jax.vmap(lambda i: jax.vmap(
        lambda j: jax.random.fold_in(jax.random.fold_in(base, i), j))(
            jnp.arange(s)))(jnp.arange(n))


@given(st.integers(0, 2 ** 16), st.integers(2, 3),
       st.sampled_from([0.7, 1.0, 1.4]))
@settings(max_examples=8, deadline=None)
def test_speculative_sampling_matches_target_distribution(seed, S, temp):
    """When drafts are sampled from the proposal p, the emitted token's
    marginal equals the target sampling distribution q exactly (the
    accept-w.p.-min(1, q/p) + residual-resample identity). Checked as a
    total-variation bound over many iid verify rows."""
    V, N = 12, 4000
    rng = np.random.default_rng(seed)
    logits = (rng.standard_normal((S, V)) * 1.5).astype(np.float32)
    p = rng.dirichlet(np.full(V, 0.6), size=S - 1).astype(np.float32)
    p /= p.sum(-1, keepdims=True)
    q0 = np.asarray(sample_probs(jnp.asarray(logits)[None], temp, 0))[0, 0]
    drafts = np.stack([rng.choice(V, size=N, p=p[i].astype(np.float64)
                                  / p[i].sum(dtype=np.float64))
                       for i in range(S - 1)], axis=1).astype(np.int32)
    toks, _ = rejection_verify(
        jnp.broadcast_to(jnp.asarray(logits), (N, S, V)),
        jnp.asarray(drafts),
        jnp.broadcast_to(jnp.asarray(p), (N, S - 1, V)),
        _spec_keys(seed, N, S), temperature=temp)
    emp = np.bincount(np.asarray(toks[:, 0]), minlength=V) / N
    assert 0.5 * np.abs(emp - q0).sum() < 0.07


@given(st.integers(0, 2 ** 16))
@settings(max_examples=6, deadline=None)
def test_speculative_sampling_one_hot_proposals_faithful(seed):
    """Deterministic proposals (the n-gram head, draft_probs=None): accept
    w.p. q(d), residual = q with d zeroed out — the emitted marginal must
    still be exactly q."""
    V, N, S = 10, 4000, 2
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((S, V)).astype(np.float32)
    d = int(rng.integers(V))
    q0 = np.asarray(sample_probs(jnp.asarray(logits)[None], 1.0, 0))[0, 0]
    toks, _ = rejection_verify(
        jnp.broadcast_to(jnp.asarray(logits), (N, S, V)),
        jnp.full((N, S - 1), d, jnp.int32), None,
        _spec_keys(seed + 1, N, S), temperature=1.0)
    emp = np.bincount(np.asarray(toks[:, 0]), minlength=V) / N
    assert 0.5 * np.abs(emp - q0).sum() < 0.07


@given(st.integers(0, 2 ** 16), st.integers(1, 5))
@settings(max_examples=25, deadline=None)
def test_greedy_verify_is_sequential_argmax_chain(seed, S):
    """greedy_verify emits exactly the prefix a one-token-at-a-time argmax
    decode would produce: accepted drafts match the chain, the first
    mismatch (or bonus) is that position's argmax."""
    rng = np.random.default_rng(seed)
    logits = rng.standard_normal((3, S, 16)).astype(np.float32)
    drafts = rng.integers(0, 16, (3, S - 1)).astype(np.int32)
    toks, n = jax.device_get(
        greedy_verify(jnp.asarray(logits), jnp.asarray(drafts)))
    for b in range(3):
        best = logits[b].argmax(-1)
        m = 1
        while m < S and drafts[b, m - 1] == best[m - 1]:
            m += 1
        assert int(n[b]) == m
        assert list(toks[b, :m]) == [int(t) for t in best[:m]]
