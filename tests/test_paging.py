"""Paged KV cache: BlockPool edge cases, block-table kernel parity against
the contiguous decode path, and the serve engine under the paged layout.

The invariant throughout: paging is *bookkeeping*, never math — every paged
result must match the contiguous cache the block table describes, token for
token, including ragged per-slot lengths and idle (retired) slots."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED
from repro.kernels.flash_attention import flash_decode, flash_decode_paged
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.models import build_model
from repro.paging import (BlockPool, BlockPoolExhausted, PagedKVCache,
                          gather_paged_kv)

KEY = jax.random.PRNGKey(0)


def _make(arch="llama3.2-1b", impl="naive"):
    cfg = ASSIGNED[arch].reduced()
    kw = {"moe_cf": 100.0} if arch == "deepseek-v3-671b" else {}
    model = build_model(cfg, impl=impl, **kw)
    params = model.init(KEY)
    return cfg, model, params


# ---------------------------------------------------------------------------
# BlockPool
# ---------------------------------------------------------------------------


def test_block_pool_exhaustion_raises():
    pool = BlockPool(num_blocks=4, block_size=8)  # 3 usable (block 0 null)
    pool.allocate("a", 16)  # 2 blocks
    assert pool.num_free == 1 and pool.can_allocate(8)
    assert not pool.can_allocate(9)
    with pytest.raises(BlockPoolExhausted):
        pool.allocate("b", 9)
    # the failed allocation corrupted nothing
    assert pool.num_free == 1 and pool.block_table("a") != []
    pool.allocate("b", 8)
    with pytest.raises(BlockPoolExhausted):
        pool.append_token("b", 8)  # boundary append with an empty pool


def test_block_pool_never_hands_out_null_block():
    pool = BlockPool(num_blocks=5, block_size=4)
    blocks = pool.allocate("a", 16)
    assert len(blocks) == 4 and BlockPool.NULL_BLOCK not in blocks


def test_block_pool_free_then_realloc_reuses_blocks():
    pool = BlockPool(num_blocks=6, block_size=4)
    a = pool.allocate("a", 12)
    pool.allocate("b", 8)
    freed = pool.free("a")
    assert freed == len(a) and pool.num_free == 3
    c = pool.allocate("c", 12)
    assert sorted(c) == sorted(a)  # freed blocks are the ones reused
    with pytest.raises(ValueError):
        pool.allocate("c", 4)  # double-allocate a live sequence id


def test_block_pool_append_on_boundary_only():
    pool = BlockPool(num_blocks=8, block_size=4)
    pool.allocate("a", 6)  # blocks for positions 0..7
    assert pool.append_token("a", 6) is None  # inside an owned block
    assert pool.append_token("a", 7) is None
    blk = pool.append_token("a", 8)  # first position of block 2
    assert blk is not None and pool.owned_blocks("a") == 3
    with pytest.raises(ValueError):
        pool.append_token("a", 20)  # skipping blocks is a bug, not an alloc


def test_block_pool_fragmentation_and_utilization_stats():
    pool = BlockPool(num_blocks=9, block_size=4)  # 8 usable
    pool.allocate("a", 5)  # 2 blocks, 8 slots
    pool.allocate("b", 4)  # 1 block, 4 slots
    assert pool.utilization() == pytest.approx(3 / 8)
    # live: a=5 of 8, b=4 of 4 -> 9 of 12 slots live
    assert pool.fragmentation({"a": 5, "b": 4}) == pytest.approx(1 - 9 / 12)
    assert pool.fragmentation({"a": 8, "b": 4}) == 0.0
    st = pool.stats({"a": 5, "b": 4})
    assert st["blocks_in_use"] == 3 and st["peak_blocks_in_use"] == 3
    pool.free("b")
    assert pool.stats()["peak_blocks_in_use"] == 3  # high-water mark sticks
    assert pool.fragmentation({"a": 5}) == pytest.approx(1 - 5 / 8)
    assert pool.fragmentation({}) == 1.0  # nothing live: all slots wasted


def test_paged_kv_cache_slot_rows_reset_to_null():
    kv = PagedKVCache(num_blocks=9, block_size=4, max_batch=2,
                      max_blocks_per_seq=3)
    blocks = kv.admit(0, "a", 6)
    assert list(kv.tables[0, :2]) == blocks and kv.tables[1].sum() == 0
    kv.append(0, 8)  # boundary: position 8 opens logical block 2
    assert kv.tables[0, 2] != 0 and kv.pool.owned_blocks("a") == 3
    with pytest.raises(ValueError, match="table width"):
        kv.append(0, 12)
    kv.release(0)
    assert kv.tables[0].sum() == 0  # idle slot writes land in the null block
    assert kv.pool.num_free == 8


# ---------------------------------------------------------------------------
# kernel parity: flash_decode_paged == flash_decode on the described cache
# ---------------------------------------------------------------------------


def _paged_from_contig(rng, k, v, bs):
    """Scatter a contiguous (B, Smax, K, hd) cache into a block pool with a
    random (non-identity) block assignment; returns (k_pool, v_pool, table)."""
    B, Smax = k.shape[:2]
    T = Smax // bs
    NB = B * T + 1
    table = rng.permutation(np.arange(1, NB))[:B * T].reshape(B, T).astype(np.int32)
    k_pool = np.zeros((NB, bs) + k.shape[2:], k.dtype)
    v_pool = np.zeros((NB, bs) + v.shape[2:], v.dtype)
    for b in range(B):
        for t in range(T):
            k_pool[table[b, t]] = k[b, t * bs:(t + 1) * bs]
            v_pool[table[b, t]] = v[b, t * bs:(t + 1) * bs]
    return k_pool, v_pool, table


@pytest.mark.parametrize("geom", ["gqa", "mla"])
@pytest.mark.parametrize("ragged", [False, True])
def test_flash_decode_paged_matches_contiguous(geom, ragged):
    """Same math through the block table, GQA (K>1) and MLA-shaped (K=1,
    G=H, hdv != hd) geometries, scalar and ragged lengths incl. an idle
    (length 0) slot."""
    rng = np.random.default_rng(0)
    B, bs, T = 3, 8, 4
    Smax = bs * T
    if geom == "gqa":
        K, G, hd, hdv = 2, 3, 32, 32
        scale = None
    else:  # MLA decodes in latent space: one shared head, asymmetric dims
        K, G, hd, hdv = 1, 4, 48, 40
        scale = 0.125
    q = rng.standard_normal((B, K, G, hd)).astype(np.float32)
    k = rng.standard_normal((B, Smax, K, hd)).astype(np.float32)
    v = rng.standard_normal((B, Smax, K, hdv)).astype(np.float32)
    k_pool, v_pool, table = _paged_from_contig(rng, k, v, bs)
    lengths = np.asarray([Smax, 13, 0], np.int32) if ragged \
        else np.full((B,), 21, np.int32)

    kw = {} if scale is None else {"scale": scale}
    ref = flash_decode(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                       jnp.asarray(lengths), **kw)
    out = flash_decode_paged(jnp.asarray(q), jnp.asarray(k_pool),
                             jnp.asarray(v_pool), jnp.asarray(table),
                             jnp.asarray(lengths), **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)
    if ragged:  # the idle slot must produce exact zeros, not NaNs
        assert np.all(np.asarray(out)[2] == 0.0)


def test_gather_paged_kv_reconstructs_contiguous():
    rng = np.random.default_rng(1)
    k = rng.standard_normal((2, 16, 2, 8)).astype(np.float32)
    k_pool, _, table = _paged_from_contig(rng, k, k, 4)
    back = gather_paged_kv(jnp.asarray(k_pool), jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(back), k)


# ---------------------------------------------------------------------------
# engine: paged layout is bookkeeping, never math
# ---------------------------------------------------------------------------


def _run_stream(model, params, *, layout, impl_reqs, max_batch=2, max_seq=32,
                **kw):
    engine = ContinuousBatchingEngine(model, params, max_batch=max_batch,
                                      max_seq=max_seq, kv_layout=layout, **kw)
    finished = engine.run([Request(r.uid, r.prompt.copy(), r.max_new_tokens)
                           for r in impl_reqs])
    return engine, {u: f.tokens for u, f in finished.items()}


def _ragged_reqs(seed=1, n=3):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, 64, 4 + 3 * i).astype(np.int32),
                    max_new_tokens=3 + i) for i in range(n)]


def test_engine_paged_token_identical_to_contig():
    """Queueing, mid-stream retirement, freed-slot admission and ragged
    lengths through the paged cache == the contiguous slabs, token for
    token. block_size 4 forces mid-decode boundary allocations."""
    _, model, params = _make()
    reqs = _ragged_reqs()
    _, contig = _run_stream(model, params, layout="contig", impl_reqs=reqs)
    engine, paged = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                                block_size=4)
    assert paged == contig
    st = engine.stats()
    assert st["pool"]["blocks_in_use"] == 0  # all retired -> all freed
    assert st["pool"]["peak_blocks_in_use"] > 0
    assert st["peak_kv_bytes"] < st["kv_bytes"] or st["kv_bytes"] == 0


def test_engine_paged_pallas_token_identical():
    """The paged flash-decode kernel serves the same stream as the paged
    naive gather oracle."""
    outs = {}
    for impl in ("naive", "pallas"):
        _, model, params = _make(impl=impl)
        _, outs[impl] = _run_stream(model, params, layout="paged",
                                    impl_reqs=_ragged_reqs(3), block_size=4)
    assert outs["naive"] == outs["pallas"]


def test_engine_paged_mla_token_identical_to_contig():
    """MLA (latent-space) paged decode parity on the deepseek geometry."""
    _, model, params = _make("deepseek-v3-671b")
    reqs = _ragged_reqs(5, n=3)
    _, contig = _run_stream(model, params, layout="contig", impl_reqs=reqs,
                            max_seq=24)
    _, paged = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                           max_seq=24, block_size=4)
    assert paged == contig


def test_engine_paged_admission_waits_for_pool_capacity():
    """With a pool too small for two residents, the second request queues
    (admission rejects, nothing corrupts) and is served after the first
    retires — both streams still match the roomy-pool run."""
    _, model, params = _make()
    reqs = [Request(uid=i, prompt=np.arange(2, 10, dtype=np.int32),
                    max_new_tokens=4) for i in range(2)]
    _, roomy = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                           block_size=4, max_batch=2)
    # 8-token prompt + 4 generated -> 3 blocks of 4; 5 usable blocks fit one
    # resident sequence but never two
    engine, tight = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                                block_size=4, max_batch=2, num_blocks=6)
    assert tight == roomy
    assert engine.stats()["pool"]["peak_blocks_in_use"] <= 5
    # batching never happened: the two requests were serialized
    assert engine.occupancy <= 0.5


def test_engine_paged_rejects_impossible_prompt():
    _, model, params = _make()
    engine = ContinuousBatchingEngine(model, params, max_batch=1, max_seq=32,
                                      kv_layout="paged", block_size=4,
                                      num_blocks=3)
    with pytest.raises(ValueError, match="never be resident"):
        engine.submit(Request(uid=0, prompt=np.arange(12, dtype=np.int32),
                              max_new_tokens=2))


# ---------------------------------------------------------------------------
# satellites: sampling + prompt bucketing
# ---------------------------------------------------------------------------


def test_engine_sampling_deterministic_and_batch_independent():
    """Seeded sampling: identical streams across runs, and a request's
    stream does not depend on what it was batched with."""
    _, model, params = _make()
    reqs = [Request(uid=i, prompt=np.arange(4 + i, dtype=np.int32),
                    max_new_tokens=4) for i in range(3)]
    kw = dict(temperature=1.0, top_k=8, sample_seed=7)
    _, a = _run_stream(model, params, layout="contig", impl_reqs=reqs, **kw)
    _, b = _run_stream(model, params, layout="contig", impl_reqs=reqs, **kw)
    assert a == b
    # uid 0 alone in a 1-slot engine: same stream as when batched
    _, solo = _run_stream(model, params, layout="contig", impl_reqs=reqs[:1],
                          max_batch=1, **kw)
    assert solo[0] == a[0]
    # different seed moves the stream (overwhelmingly likely)
    _, c = _run_stream(model, params, layout="contig", impl_reqs=reqs,
                       temperature=1.0, top_k=8, sample_seed=8)
    assert c != a


def test_engine_sampling_respects_top_k():
    """top_k=1 must reduce to greedy regardless of temperature."""
    _, model, params = _make()
    reqs = _ragged_reqs(9)
    _, greedy = _run_stream(model, params, layout="contig", impl_reqs=reqs)
    _, topk1 = _run_stream(model, params, layout="contig", impl_reqs=reqs,
                           temperature=5.0, top_k=1, sample_seed=3)
    assert topk1 == greedy


def test_engine_bucketing_bounds_prefill_compiles_token_identical():
    rng = np.random.default_rng(11)
    _, model, params = _make()
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 3 + i).astype(np.int32),
                    max_new_tokens=3) for i in range(6)]
    plain_engine, plain = _run_stream(model, params, layout="contig",
                                      impl_reqs=reqs)
    bucket_engine, bucketed = _run_stream(model, params, layout="contig",
                                          impl_reqs=reqs, bucket_prompts=True)
    assert bucketed == plain  # padding is invisible to causal prefill
    assert plain_engine.stats()["prefill_compiles"] == 6
    # 6 distinct lengths (3..8) collapse onto power-of-two buckets {4, 8}
    assert bucket_engine.stats()["prefill_compiles"] == 2
    assert set(bucket_engine.stats()["prefill_buckets"]) == {"4", "8"}
