"""Paged KV cache: BlockPool edge cases, block-table kernel parity against
the contiguous decode path, and the serve engine under the paged layout.

The invariant throughout: paging is *bookkeeping*, never math — every paged
result must match the contiguous cache the block table describes, token for
token, including ragged per-slot lengths and idle (retired) slots."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED
from repro.kernels.flash_attention import flash_decode, flash_decode_paged
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.models import build_model
from repro.paging import (BlockPool, BlockPoolExhausted, PagedKVCache,
                          gather_paged_kv)

KEY = jax.random.PRNGKey(0)


def _make(arch="llama3.2-1b", impl="naive"):
    cfg = ASSIGNED[arch].reduced()
    kw = {"moe_cf": 100.0} if arch == "deepseek-v3-671b" else {}
    model = build_model(cfg, impl=impl, **kw)
    params = model.init(KEY)
    return cfg, model, params


# ---------------------------------------------------------------------------
# BlockPool
# ---------------------------------------------------------------------------


def test_block_pool_exhaustion_raises():
    pool = BlockPool(num_blocks=4, block_size=8)  # 3 usable (block 0 null)
    pool.allocate("a", 16)  # 2 blocks
    assert pool.num_free == 1 and pool.can_allocate(8)
    assert not pool.can_allocate(9)
    with pytest.raises(BlockPoolExhausted):
        pool.allocate("b", 9)
    # the failed allocation corrupted nothing
    assert pool.num_free == 1 and pool.block_table("a") != []
    pool.allocate("b", 8)
    with pytest.raises(BlockPoolExhausted):
        pool.append_token("b", 8)  # boundary append with an empty pool


def test_block_pool_never_hands_out_null_block():
    pool = BlockPool(num_blocks=5, block_size=4)
    blocks = pool.allocate("a", 16)
    assert len(blocks) == 4 and BlockPool.NULL_BLOCK not in blocks


def test_block_pool_free_then_realloc_reuses_blocks():
    pool = BlockPool(num_blocks=6, block_size=4)
    a = pool.allocate("a", 12)
    pool.allocate("b", 8)
    freed = pool.free("a")
    assert freed == len(a) and pool.num_free == 3
    c = pool.allocate("c", 12)
    assert sorted(c) == sorted(a)  # freed blocks are the ones reused
    with pytest.raises(ValueError):
        pool.allocate("c", 4)  # double-allocate a live sequence id


def test_block_pool_append_on_boundary_only():
    pool = BlockPool(num_blocks=8, block_size=4)
    pool.allocate("a", 6)  # blocks for positions 0..7
    assert pool.append_token("a", 6) is None  # inside an owned block
    assert pool.append_token("a", 7) is None
    blk = pool.append_token("a", 8)  # first position of block 2
    assert blk is not None and pool.owned_blocks("a") == 3
    with pytest.raises(ValueError):
        pool.append_token("a", 20)  # skipping blocks is a bug, not an alloc


def test_block_pool_fragmentation_and_utilization_stats():
    pool = BlockPool(num_blocks=9, block_size=4)  # 8 usable
    pool.allocate("a", 5)  # 2 blocks, 8 slots
    pool.allocate("b", 4)  # 1 block, 4 slots
    assert pool.utilization() == pytest.approx(3 / 8)
    # live: a=5 of 8, b=4 of 4 -> 9 of 12 slots live
    assert pool.fragmentation({"a": 5, "b": 4}) == pytest.approx(1 - 9 / 12)
    assert pool.fragmentation({"a": 8, "b": 4}) == 0.0
    st = pool.stats({"a": 5, "b": 4})
    assert st["blocks_in_use"] == 3 and st["peak_blocks_in_use"] == 3
    pool.free("b")
    assert pool.stats()["peak_blocks_in_use"] == 3  # high-water mark sticks
    assert pool.fragmentation({"a": 5}) == pytest.approx(1 - 5 / 8)
    assert pool.fragmentation({}) == 1.0  # nothing live: all slots wasted


def test_paged_kv_cache_slot_rows_reset_to_null():
    kv = PagedKVCache(num_blocks=9, block_size=4, max_batch=2,
                      max_blocks_per_seq=3)
    blocks = kv.admit(0, "a", 6)
    assert list(kv.tables[0, :2]) == blocks and kv.tables[1].sum() == 0
    kv.append(0, 8)  # boundary: position 8 opens logical block 2
    assert kv.tables[0, 2] != 0 and kv.pool.owned_blocks("a") == 3
    with pytest.raises(ValueError, match="table width"):
        kv.append(0, 12)
    kv.release(0)
    assert kv.tables[0].sum() == 0  # idle slot writes land in the null block
    assert kv.pool.num_free == 8


# ---------------------------------------------------------------------------
# kernel parity: flash_decode_paged == flash_decode on the described cache
# ---------------------------------------------------------------------------


def _paged_from_contig(rng, k, v, bs):
    """Scatter a contiguous (B, Smax, K, hd) cache into a block pool with a
    random (non-identity) block assignment; returns (k_pool, v_pool, table)."""
    B, Smax = k.shape[:2]
    T = Smax // bs
    NB = B * T + 1
    table = rng.permutation(np.arange(1, NB))[:B * T].reshape(B, T).astype(np.int32)
    k_pool = np.zeros((NB, bs) + k.shape[2:], k.dtype)
    v_pool = np.zeros((NB, bs) + v.shape[2:], v.dtype)
    for b in range(B):
        for t in range(T):
            k_pool[table[b, t]] = k[b, t * bs:(t + 1) * bs]
            v_pool[table[b, t]] = v[b, t * bs:(t + 1) * bs]
    return k_pool, v_pool, table


@pytest.mark.parametrize("geom", ["gqa", "mla"])
@pytest.mark.parametrize("ragged", [False, True])
def test_flash_decode_paged_matches_contiguous(geom, ragged):
    """Same math through the block table, GQA (K>1) and MLA-shaped (K=1,
    G=H, hdv != hd) geometries, scalar and ragged lengths incl. an idle
    (length 0) slot."""
    rng = np.random.default_rng(0)
    B, bs, T = 3, 8, 4
    Smax = bs * T
    if geom == "gqa":
        K, G, hd, hdv = 2, 3, 32, 32
        scale = None
    else:  # MLA decodes in latent space: one shared head, asymmetric dims
        K, G, hd, hdv = 1, 4, 48, 40
        scale = 0.125
    q = rng.standard_normal((B, K, G, hd)).astype(np.float32)
    k = rng.standard_normal((B, Smax, K, hd)).astype(np.float32)
    v = rng.standard_normal((B, Smax, K, hdv)).astype(np.float32)
    k_pool, v_pool, table = _paged_from_contig(rng, k, v, bs)
    lengths = np.asarray([Smax, 13, 0], np.int32) if ragged \
        else np.full((B,), 21, np.int32)

    kw = {} if scale is None else {"scale": scale}
    ref = flash_decode(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                       jnp.asarray(lengths), **kw)
    out = flash_decode_paged(jnp.asarray(q), jnp.asarray(k_pool),
                             jnp.asarray(v_pool), jnp.asarray(table),
                             jnp.asarray(lengths), **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-5)
    if ragged:  # the idle slot must produce exact zeros, not NaNs
        assert np.all(np.asarray(out)[2] == 0.0)


def test_gather_paged_kv_reconstructs_contiguous():
    rng = np.random.default_rng(1)
    k = rng.standard_normal((2, 16, 2, 8)).astype(np.float32)
    k_pool, _, table = _paged_from_contig(rng, k, k, 4)
    back = gather_paged_kv(jnp.asarray(k_pool), jnp.asarray(table))
    np.testing.assert_array_equal(np.asarray(back), k)


# ---------------------------------------------------------------------------
# engine: paged layout is bookkeeping, never math
# ---------------------------------------------------------------------------


def _run_stream(model, params, *, layout, impl_reqs, max_batch=2, max_seq=32,
                **kw):
    engine = ContinuousBatchingEngine(model, params, max_batch=max_batch,
                                      max_seq=max_seq, kv_layout=layout, **kw)
    finished = engine.run([Request(r.uid, r.prompt.copy(), r.max_new_tokens)
                           for r in impl_reqs])
    return engine, {u: f.tokens for u, f in finished.items()}


def _ragged_reqs(seed=1, n=3):
    rng = np.random.default_rng(seed)
    return [Request(uid=i, prompt=rng.integers(0, 64, 4 + 3 * i).astype(np.int32),
                    max_new_tokens=3 + i) for i in range(n)]


def test_engine_paged_token_identical_to_contig():
    """Queueing, mid-stream retirement, freed-slot admission and ragged
    lengths through the paged cache == the contiguous slabs, token for
    token. block_size 4 forces mid-decode boundary allocations."""
    _, model, params = _make()
    reqs = _ragged_reqs()
    _, contig = _run_stream(model, params, layout="contig", impl_reqs=reqs)
    engine, paged = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                                block_size=4)
    assert paged == contig
    st = engine.stats()
    assert st["pool"]["blocks_in_use"] == 0  # all retired -> all freed
    assert st["pool"]["peak_blocks_in_use"] > 0
    assert st["peak_kv_bytes"] < st["kv_bytes"] or st["kv_bytes"] == 0


def test_engine_paged_pallas_token_identical():
    """The paged flash-decode kernel serves the same stream as the paged
    naive gather oracle."""
    outs = {}
    for impl in ("naive", "pallas"):
        _, model, params = _make(impl=impl)
        _, outs[impl] = _run_stream(model, params, layout="paged",
                                    impl_reqs=_ragged_reqs(3), block_size=4)
    assert outs["naive"] == outs["pallas"]


def test_engine_paged_mla_token_identical_to_contig():
    """MLA (latent-space) paged decode parity on the deepseek geometry."""
    _, model, params = _make("deepseek-v3-671b")
    reqs = _ragged_reqs(5, n=3)
    _, contig = _run_stream(model, params, layout="contig", impl_reqs=reqs,
                            max_seq=24)
    _, paged = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                           max_seq=24, block_size=4)
    assert paged == contig


def test_engine_paged_admission_waits_for_pool_capacity():
    """With a pool too small for two residents, the second request queues
    (admission rejects, nothing corrupts) and is served after the first
    retires — both streams still match the roomy-pool run."""
    _, model, params = _make()
    reqs = [Request(uid=i, prompt=np.arange(2, 10, dtype=np.int32),
                    max_new_tokens=4) for i in range(2)]
    _, roomy = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                           block_size=4, max_batch=2)
    # 8-token prompt + 4 generated -> 3 blocks of 4; 5 usable blocks fit one
    # resident sequence but never two
    engine, tight = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                                block_size=4, max_batch=2, num_blocks=6)
    assert tight == roomy
    assert engine.stats()["pool"]["peak_blocks_in_use"] <= 5
    # batching never happened: the two requests were serialized
    assert engine.occupancy <= 0.5


def test_engine_paged_rejects_impossible_prompt():
    _, model, params = _make()
    engine = ContinuousBatchingEngine(model, params, max_batch=1, max_seq=32,
                                      kv_layout="paged", block_size=4,
                                      num_blocks=3)
    with pytest.raises(ValueError, match="never be resident"):
        engine.submit(Request(uid=0, prompt=np.arange(12, dtype=np.int32),
                              max_new_tokens=2))


# ---------------------------------------------------------------------------
# satellites: sampling + prompt bucketing
# ---------------------------------------------------------------------------


def test_engine_sampling_deterministic_and_batch_independent():
    """Seeded sampling: identical streams across runs, and a request's
    stream does not depend on what it was batched with."""
    _, model, params = _make()
    reqs = [Request(uid=i, prompt=np.arange(4 + i, dtype=np.int32),
                    max_new_tokens=4) for i in range(3)]
    kw = dict(temperature=1.0, top_k=8, sample_seed=7)
    _, a = _run_stream(model, params, layout="contig", impl_reqs=reqs, **kw)
    _, b = _run_stream(model, params, layout="contig", impl_reqs=reqs, **kw)
    assert a == b
    # uid 0 alone in a 1-slot engine: same stream as when batched
    _, solo = _run_stream(model, params, layout="contig", impl_reqs=reqs[:1],
                          max_batch=1, **kw)
    assert solo[0] == a[0]
    # different seed moves the stream (overwhelmingly likely)
    _, c = _run_stream(model, params, layout="contig", impl_reqs=reqs,
                       temperature=1.0, top_k=8, sample_seed=8)
    assert c != a


def test_engine_sampling_respects_top_k():
    """top_k=1 must reduce to greedy regardless of temperature."""
    _, model, params = _make()
    reqs = _ragged_reqs(9)
    _, greedy = _run_stream(model, params, layout="contig", impl_reqs=reqs)
    _, topk1 = _run_stream(model, params, layout="contig", impl_reqs=reqs,
                           temperature=5.0, top_k=1, sample_seed=3)
    assert topk1 == greedy


def test_engine_bucketing_bounds_prefill_compiles_token_identical():
    rng = np.random.default_rng(11)
    _, model, params = _make()
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 3 + i).astype(np.int32),
                    max_new_tokens=3) for i in range(6)]
    plain_engine, plain = _run_stream(model, params, layout="contig",
                                      impl_reqs=reqs)
    bucket_engine, bucketed = _run_stream(model, params, layout="contig",
                                          impl_reqs=reqs, bucket_prompts=True)
    assert bucketed == plain  # padding is invisible to causal prefill
    assert plain_engine.stats()["prefill_compiles"] == 6
    # 6 distinct lengths (3..8) collapse onto power-of-two buckets {4, 8}
    assert bucket_engine.stats()["prefill_compiles"] == 2
    assert set(bucket_engine.stats()["prefill_buckets"]) == {"4", "8"}


# ---------------------------------------------------------------------------
# refcounts, COW, cached-free tier, prefix index
# ---------------------------------------------------------------------------


def test_block_pool_cow_on_shared_block():
    pool = BlockPool(num_blocks=8, block_size=4)
    a = pool.allocate("a", 8)
    b = pool.allocate("b", 8, shared=a)  # full prefix hit: same physicals
    assert pool.block_table("b") == a
    assert pool.refcount(a[0]) == 2 and pool.shared_blocks == 2
    ev = pool.append_token("b", 7)  # write inside shared block 1 -> COW
    assert ev is not None and ev.kind == "cow" and ev.src == a[1]
    assert pool.block_table("b")[1] == ev.block != a[1]
    assert pool.block_table("a") == a  # the donor's table is untouched
    assert pool.refcount(a[1]) == 1 and pool.refcount(ev.block) == 1
    assert pool.append_token("b", 7) is None  # private now: no second copy
    pool.free("a")
    pool.free("b")
    pool.check_invariants()
    assert pool.blocks_in_use == 0 and pool.stats()["total_cow"] == 1


def test_block_pool_cached_free_resurrection_and_eviction():
    pool = BlockPool(num_blocks=6, block_size=4)
    evicted = []
    indexed = set()
    pool.cache_filter = lambda blk: blk in indexed
    pool.on_evict = lambda blk: (indexed.discard(blk), evicted.append(blk))
    a = pool.allocate("a", 12)
    indexed.update(a)
    pool.free("a")
    # indexed blocks park on the cached tier: evictable, hence still "free"
    assert pool.num_cached == 3 and pool.num_free == 5
    b = pool.allocate("b", 12, shared=a[:2])  # prefix hit resurrects two
    assert pool.block_table("b")[:2] == a[:2] and pool.num_cached == 1
    pool.allocate("c", 8)  # overflows the free list: evicts the cached LRU
    # the eviction callback purged the index, so the prefix layer can never
    # offer the recycled block as a hit again
    assert evicted == [a[2]] and a[2] not in indexed
    pool.check_invariants()


def test_prefix_index_full_partial_and_chained_semantics():
    from repro.paging import PrefixIndex
    idx = PrefixIndex(block_size=4)
    toks = np.arange(10, dtype=np.int32)  # 2 full blocks + 2-token tail
    idx.insert(toks, [5, 6, 7])
    assert idx.match(toks) == ([5, 6, 7], 10)  # full-prompt hit incl. tail
    # same full prefix, different/longer tail: full blocks only — the
    # partial block must never be mapped into a prompt that extends it
    longer = np.concatenate([toks[:8], np.array([1, 2, 3], np.int32)])
    assert idx.match(longer) == ([5, 6], 8)
    # diverging first block: nothing matches
    assert idx.match(np.arange(1, 11, dtype=np.int32)) == ([], 0)
    # identical block *content* at a different position must not hit
    # (keys are chained digests, not per-block content hashes)
    shifted = np.concatenate([np.full(4, 9, np.int32), toks[:4]])
    assert idx.match(shifted) == ([], 0)
    idx.forget_block(6)  # pool recycled it: the chain stops before it
    assert idx.match(toks) == ([5], 4)
    assert idx.stats()["hit_rate"] > 0


# ---------------------------------------------------------------------------
# engine: prefix sharing, COW, swap tier, dirty-row shipping
# ---------------------------------------------------------------------------


def test_engine_prefix_sharing_token_identical_and_skips_chunks():
    """Shared-prefix traffic: sharing on == sharing off, token for token,
    while skipping the hit chunks' prefill compute entirely."""
    _, model, params = _make()
    rng = np.random.default_rng(2)
    prefix = rng.integers(0, 64, 12).astype(np.int32)  # 3 full blocks of 4
    reqs = [Request(uid=i, prompt=np.concatenate(
                [prefix, rng.integers(0, 64, 2 + i).astype(np.int32)]),
                    max_new_tokens=3) for i in range(4)]
    eng_off, off = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                               block_size=4, prefix_cache=False)
    eng_on, on = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                             block_size=4)
    assert on == off
    st = eng_on.stats()
    assert st["prefill_chunks_skipped"] > 0
    assert st["prefill_chunks"] < eng_off.stats()["prefill_chunks"]
    assert eng_off.stats()["prefill_chunks_skipped"] == 0
    assert st["pool"]["prefix"]["hit_rate"] > 0
    assert st["pool"]["total_shares"] > 0


def test_engine_prefix_cow_on_identical_prompts():
    """Concurrent identical prompts share every block including the partial
    tail; each follower's first decode append pays exactly one COW copy and
    all streams stay identical to the unshared run."""
    _, model, params = _make()
    prompt = np.arange(3, 13, dtype=np.int32)  # 10 tokens: partial tail
    reqs = [Request(uid=i, prompt=prompt.copy(), max_new_tokens=4)
            for i in range(3)]
    _, off = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                         block_size=4, max_batch=3, prefix_cache=False)
    eng_on, on = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                             block_size=4, max_batch=3)
    assert on == off
    assert all(on[0] == on[u] for u in on)
    st = eng_on.stats()
    # 3 sequences share the tail block; the last owner standing appends in
    # place, so exactly two divergences pay a copy
    assert st["cow_copies"] == 2
    assert st["pool"]["total_cow"] == st["cow_copies"]
    assert st["pool"]["blocks_in_use"] == 0


def test_engine_swap_token_identical_over_committed_pool():
    """A pool too small for two residents' worst case: the swap policy
    parks cold residents on the host instead of serializing, and the
    resumed streams are token-identical to the roomy-pool run."""
    _, model, params = _make()
    reqs = [Request(uid=i, prompt=np.arange(2, 10, dtype=np.int32),
                    max_new_tokens=4) for i in range(3)]
    _, roomy = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                           block_size=4, max_batch=2)
    engine, tight = _run_stream(model, params, layout="paged", impl_reqs=reqs,
                                block_size=4, max_batch=2, num_blocks=6,
                                admission_policy="swap", prefix_cache=False)
    assert tight == roomy
    st = engine.stats()
    assert st["swap_outs"] >= 1 and st["swap_outs"] == st["swap_ins"]
    assert st["swapped"] == 0  # everyone came back and finished
    assert st["pool"]["blocks_in_use"] == 0
    assert len(engine.finished) == len(reqs)


def test_engine_swap_policy_requires_paged_layout():
    _, model, params = _make()
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatchingEngine(model, params, max_batch=2, max_seq=32,
                                 kv_layout="contig",
                                 admission_policy="swap")


def test_engine_dirty_rows_ship_only_changes():
    """Block-table rows reach the device only when they change; the
    device-resident table stays consistent with the host table."""
    _, model, params = _make()
    engine, _ = _run_stream(model, params, layout="paged",
                            impl_reqs=_ragged_reqs(), block_size=4)
    st = engine.stats()
    assert st["table_rows_shipped"] > 0
    # re-uploading every row every step would have moved far more rows
    assert st["table_rows_shipped"] < engine.decode_steps * engine.max_batch
    pending = set(engine.kv.take_dirty())  # releases after the last decode
    dev = np.asarray(engine._dev_tables)
    for row in range(engine.max_batch):
        if row not in pending:
            np.testing.assert_array_equal(dev[row], engine.kv.tables[row])


# ---------------------------------------------------------------------------
# random interleaving machine (shared with tests/test_property.py)
# ---------------------------------------------------------------------------


def _drive_pool_machine(seed: int, steps: int = 150, num_blocks: int = 10,
                        block_size: int = 4) -> None:
    """Random admit/share/append(+COW)/publish/free/swap interleaving
    against a shadow value model. After every op: pool conservation holds
    (no leaked or double-freed block, the null block never freed or
    mapped), every live sequence still reads the values it wrote (COW
    isolation), and host swap round-trips are value-identical."""
    rng = np.random.default_rng(seed)
    pool = BlockPool(num_blocks, block_size)
    indexed = set()
    published = {}  # pid -> tuple of blocks (a fake prefix-cache entry)

    def on_evict(blk):
        indexed.discard(blk)
        for pid in [p for p, blks in published.items() if blk in blks]:
            del published[pid]

    pool.cache_filter = lambda blk: blk in indexed
    pool.on_evict = on_evict

    content = {}  # physical block -> last stamped value ("device data")
    live = {}     # seq -> {"tokens": int, "vals": [value per logical block]}
    parked = {}   # seq -> (tokens, host values) — swapped to "host memory"
    counters = {"seq": 0, "stamp": 0, "pid": 0}

    def stamp():
        counters["stamp"] += 1
        return counters["stamp"]

    def pick(d):
        return list(d)[int(rng.integers(0, len(d)))]

    for _ in range(steps):
        op = int(rng.integers(0, 12))
        if op <= 2:  # admit a fresh sequence
            n = int(rng.integers(1, 3 * block_size + 1))
            sid = counters["seq"]
            counters["seq"] += 1
            try:
                blocks = pool.allocate(sid, n)
            except BlockPoolExhausted:
                assert pool.owned_blocks(sid) == 0  # failed atomically
            else:
                for blk in blocks:
                    content[blk] = stamp()
                live[sid] = {"tokens": n,
                             "vals": [content[b] for b in blocks]}
        elif op <= 4 and published:  # admit sharing a published prefix
            shared = list(published[pick(published)])
            k = int(rng.integers(1, len(shared) + 1))
            shared = shared[:k]
            n = (k - 1) * block_size + int(rng.integers(1, block_size + 1))
            sid = counters["seq"]
            counters["seq"] += 1
            try:
                blocks = pool.allocate(sid, n, shared=shared)
            except BlockPoolExhausted:
                assert pool.owned_blocks(sid) == 0
            else:
                assert blocks[:k] == shared
                live[sid] = {"tokens": n,
                             "vals": [content[b] for b in blocks]}
        elif op <= 7 and live:  # append one token: alloc / COW / in place
            sid = pick(live)
            st = live[sid]
            if st["tokens"] >= 4 * block_size:
                continue  # cap one sequence's appetite
            pos = st["tokens"]
            idx = pos // block_size
            try:
                ev = pool.append_token(sid, pos)
            except BlockPoolExhausted:
                continue  # boundary alloc failed; table untouched
            st["tokens"] = pos + 1
            if ev is None:
                blk = pool.block_table(sid)[idx]
                # in-place writes are only legal into private blocks
                assert pool.refcount(blk) == 1
                content[blk] = stamp()
                st["vals"][idx] = content[blk]
            elif ev.kind == "cow":
                content[ev.block] = stamp()  # device copy + the new write
                st["vals"][idx] = content[ev.block]
                assert pool.refcount(ev.block) == 1
            else:  # boundary alloc
                content[ev.block] = stamp()
                st["vals"].append(content[ev.block])
                assert len(st["vals"]) == idx + 1
        elif op == 8 and live:  # publish (prefix-index) a live table
            blocks = pool.block_table(pick(live))
            indexed.update(blocks)
            published[counters["pid"]] = tuple(blocks)
            counters["pid"] += 1
        elif op == 9 and live:  # retire
            sid = pick(live)
            table = pool.block_table(sid)
            pool.free(sid)
            del live[sid]
            for blk in table:
                if pool.refcount(blk) == 0 and blk in indexed:
                    assert pool.is_cached(blk)  # parked for reuse, not lost
        elif op == 10 and live:  # swap out: host copy, then free the blocks
            sid = pick(live)
            st = live.pop(sid)
            parked[sid] = (st["tokens"],
                           [content[b] for b in pool.block_table(sid)])
            pool.free(sid)
        elif parked:  # swap in: fresh blocks, restored values
            sid = pick(parked)
            tokens, host = parked[sid]
            try:
                blocks = pool.allocate(sid, tokens)
            except BlockPoolExhausted:
                assert pool.owned_blocks(sid) == 0
            else:
                del parked[sid]
                for blk, val in zip(blocks, host):
                    content[blk] = val
                live[sid] = {"tokens": tokens, "vals": list(host)}
                # the host round trip restored every value exactly
                assert [content[b]
                        for b in pool.block_table(sid)] == host
        pool.check_invariants()
        for sid, st in live.items():
            table = pool.block_table(sid)
            assert len(table) == len(st["vals"])
            for blk, want in zip(table, st["vals"]):
                assert content[blk] == want, \
                    f"seed {seed}: seq {sid} block {blk} corrupted"
            assert BlockPool.NULL_BLOCK not in table

    for sid in list(live):
        pool.free(sid)
    pool.check_invariants()
    assert pool.blocks_in_use == 0  # nothing leaked (cached is reclaimable)
    assert pool.refcount(BlockPool.NULL_BLOCK) == 1


def test_block_pool_random_interleaving_invariants():
    """Deterministic sweep of the machine (tests/test_property.py drives the
    same machine under hypothesis when it is installed)."""
    for seed in range(20):
        _drive_pool_machine(seed)
