"""End-to-end behaviour tests for the paper's system (O1-O5)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy as E
from repro.core.choices import CoreChoice
from repro.core.planner import explore_soc
from repro.core.profiler import greedy_baseline_profile, profile_soc_choice


def test_O1_power_energy_inversion():
    """Low power does not mean low energy (paper Fig. 2a)."""
    m = E.SOC_MODELS["pixel3"]
    little = profile_soc_choice(CoreChoice((0, 1, 2, 3), "pixel3"), m, "resnet34")
    big = profile_soc_choice(CoreChoice((4,), "pixel3"), m, "resnet34")
    assert little.power_w < big.power_w
    assert little.energy_j > big.energy_j


def test_O2_depthwise_scaling_inversion():
    """ShuffleNet: 4 big cores slower than 1 big core (paper Fig. 2b)."""
    m = E.SOC_MODELS["pixel3"]
    one = profile_soc_choice(CoreChoice((4,), "pixel3"), m, "shufflenet-v2")
    four = profile_soc_choice(CoreChoice((4, 5, 6, 7), "pixel3"), m, "shufflenet-v2")
    assert one.latency_s < four.latency_s
    one_r = profile_soc_choice(CoreChoice((4,), "pixel3"), m, "resnet34")
    four_r = profile_soc_choice(CoreChoice((4, 5, 6, 7), "pixel3"), m, "resnet34")
    assert four_r.latency_s < one_r.latency_s


def test_O3_table2_speedups_in_band():
    """Swan vs greedy baseline speedups land within the paper's Table 2 band."""
    paper = {("shufflenet-v2", "s10e"): 39, ("shufflenet-v2", "oneplus8"): 17,
             ("mobilenet-v2", "mi10"): 14, ("resnet34", "pixel3"): 1.0}
    for (wl, dev), target in paper.items():
        plan = explore_soc(dev, wl)
        base = greedy_baseline_profile(E.SOC_MODELS[dev], wl)
        sp = base.latency_s / plan.selected.latency_s
        assert 0.7 * target <= sp <= 1.4 * target, \
            f"{wl}/{dev}: {sp:.1f}x vs paper {target}x"


def test_O4_controller_reduces_interference():
    """Migration relinquishes contended cores (paper Table 3 direction)."""
    import benchmarks.table3_interference as t3
    base, swan, ctl = t3.score_impact("pixel3")
    assert swan > base  # less negative impact
    assert len(ctl.migrations) >= 1


def test_O5_fl_macro_direction():
    """Swan >= baseline on time-to-accuracy and energy at FL scale."""
    from repro.fl.simulator import compare_policies
    res = compare_policies("mobilenet-v2", rounds=50, n_clients=96,
                           clients_per_round=16, seed=5)
    assert res["swan"].total_energy_j < res["baseline"].total_energy_j
    tgt = min(res["baseline"].final_accuracy, res["swan"].final_accuracy)
    assert res["swan"].time_to_accuracy(tgt) <= res["baseline"].time_to_accuracy(tgt)


def test_training_reduces_loss_end_to_end():
    from repro.launch import train as T
    losses = T.main(["--arch", "granite-3-2b", "--reduced", "--steps", "15",
                     "--batch", "4", "--seq", "32", "--optimizer", "adam",
                     "--lr", "1e-3", "--log-every", "100"])
    assert losses[-1] < losses[0]
