"""Data pipeline: determinism, host sharding, learnable structure."""
import numpy as np

from repro.data.pipeline import cnn_batches, lm_batches, synthetic_lm_batch


def test_lm_determinism_and_host_disjointness():
    it0 = lm_batches(seed=1, batch=8, seq=32, vocab=100, host=0, n_hosts=2)
    it0b = lm_batches(seed=1, batch=8, seq=32, vocab=100, host=0, n_hosts=2)
    it1 = lm_batches(seed=1, batch=8, seq=32, vocab=100, host=1, n_hosts=2)
    a, b, c = next(it0)["tokens"], next(it0b)["tokens"], next(it1)["tokens"]
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)
    assert a.shape == (4, 32)


def test_lm_resume_from_step():
    it = lm_batches(seed=2, batch=4, seq=16, vocab=50)
    batches = [next(it)["tokens"] for _ in range(5)]
    it_resume = lm_batches(seed=2, batch=4, seq=16, vocab=50, start_step=3)
    np.testing.assert_array_equal(batches[3], next(it_resume)["tokens"])


def test_copy_structure_present():
    rng = np.random.default_rng(0)
    b = synthetic_lm_batch(rng, 2, 64, 1000)["tokens"]
    w = 16
    np.testing.assert_array_equal(b[:, :w], b[:, 32:32 + w])


def test_cnn_labels_in_range():
    it = cnn_batches(seed=0, batch=8, image=16, channels=3, n_classes=10)
    b = next(it)
    assert b["images"].shape == (8, 16, 16, 3)
    assert b["labels"].min() >= 0 and b["labels"].max() < 10
