"""Gradient parity of the custom_vjp Pallas kernels vs the jnp oracles.

``jax.grad`` through ``attention_impl(..., impl="pallas")`` must match the
naive oracle — across causal/non-causal, GQA (K < H, including MQA), and
sequence lengths that are not multiples of the 128 default block (which force
the ragged-divisor block path). rmsnorm grads check against kernels/ref.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_mha
from repro.models.attention import attention_impl, naive_attention

KEY = jax.random.PRNGKey(42)

TOL = dict(rtol=2e-2, atol=2e-2)


def _qkv(B, Sq, Sk, H, K, hd):
    q = jax.random.normal(KEY, (B, Sq, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Sk, K, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Sk, K, hd))
    return q, k, v


def _grads(impl, q, k, v, causal, w):
    def loss(q, k, v):
        out = attention_impl(q, k, v, causal=causal, impl=impl)
        return (out.astype(jnp.float32) * w).sum()
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


@pytest.mark.parametrize("B,Sq,Sk,H,K,hd", [
    (1, 16, 16, 4, 4, 32),     # MHA
    (2, 37, 37, 8, 4, 16),     # GQA, ragged (block != divisor of 128)
    (1, 128, 128, 8, 2, 64),   # GQA at exactly one default block
    (1, 256, 256, 4, 1, 32),   # MQA, multi-block (causal tile skipping live)
    (1, 48, 112, 4, 2, 32),    # Sq != Sk (cross-attention shape)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_grad_parity(B, Sq, Sk, H, K, hd, causal):
    q, k, v = _qkv(B, Sq, Sk, H, K, hd)
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Sq, H, hd))
    gp = _grads("pallas", q, k, v, causal, w)
    gn = _grads("naive", q, k, v, causal, w)
    for name, a, b in zip("qkv", gp, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL,
                                   err_msg=f"d{name} mismatch")


def test_flash_attention_grad_forced_small_blocks():
    """Multi-tile path in both grid dims, with causal tile skipping."""
    B, H, Sq, Sk, hd = 1, 3, 64, 96, 32
    q = jax.random.normal(KEY, (B, H, Sq, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, H, Sk, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, H, Sk, hd))
    w = jax.random.normal(jax.random.fold_in(KEY, 3), (B, H, Sq, hd))

    def f(q, k, v):
        out = flash_attention_mha(q, k, v, causal=True, block_q=16, block_k=16)
        return (out * w).sum()

    def f_ref(q, k, v):
        out = naive_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True)
        return (out.transpose(0, 2, 1, 3) * w).sum()

    gp = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for name, a, b in zip("qkv", gp, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL,
                                   err_msg=f"d{name} mismatch")


def test_flash_attention_lse_residual_matches_logsumexp():
    """flash_attention_fwd_lse's residual rows are the masked score LSE."""
    from repro.kernels.flash_attention import flash_attention_fwd_lse
    B, H, Sq, Sk, hd = 1, 3, 64, 96, 32
    q = jax.random.normal(KEY, (B, H, Sq, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, H, Sk, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, H, Sk, hd))
    o, lse = flash_attention_fwd_lse(q, k, v, causal=True, block_q=16, block_k=16)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd)
    mask = jnp.arange(Sk)[None, :] <= jnp.arange(Sq)[:, None]
    s = jnp.where(mask[None, None], s, -1e30)
    want_lse = jax.scipy.special.logsumexp(s, axis=-1)
    want_o = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, axis=-1), v)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want_lse),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want_o),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_fwd_value_through_vjp_wrapper():
    """The custom_vjp primal (not just the fwd rule) must match the oracle."""
    q, k, v = _qkv(2, 64, 64, 8, 4, 32)
    got = attention_impl(q, k, v, causal=True, impl="pallas")
    want = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(1, 7, 64), (4, 3, 96), (3, 17, 256)])
def test_rmsnorm_grad_parity(shape):
    x = jax.random.normal(KEY, shape)
    s = jax.random.normal(jax.random.fold_in(KEY, 1), shape[-1:])
    w = jax.random.normal(jax.random.fold_in(KEY, 2), shape)

    def loss(fn):
        return lambda x, s: (fn(x, s).astype(jnp.float32) * w).sum()

    gx, gs = jax.grad(loss(ops.rmsnorm), argnums=(0, 1))(x, s)
    rx, rs = jax.grad(loss(ref.ref_rmsnorm), argnums=(0, 1))(x, s)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gs), np.asarray(rs), rtol=1e-4, atol=1e-4)


def test_rmsnorm_grad_bf16_inputs():
    x = jax.random.normal(KEY, (4, 96), jnp.bfloat16)
    s = jax.random.normal(jax.random.fold_in(KEY, 1), (96,), jnp.bfloat16)
    gx, gs = jax.grad(lambda x, s: ops.rmsnorm(x, s).astype(jnp.float32).sum(),
                      argnums=(0, 1))(x, s)
    rx, rs = jax.grad(lambda x, s: ref.ref_rmsnorm(x, s).astype(jnp.float32).sum(),
                      argnums=(0, 1))(x, s)
    np.testing.assert_allclose(np.asarray(gx, np.float32), np.asarray(rx, np.float32),
                               rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(gs, np.float32), np.asarray(rs, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_train_step_through_pallas_attention():
    """End-to-end: loss + grad of a tiny GQA block with impl='pallas'."""
    from repro.configs import get_config
    from repro.models.attention import attn_params, gqa_forward
    cfg = get_config("llama3.2-1b").reduced()
    p = attn_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(KEY, (2, 32, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))

    def loss(p, impl):
        y = gqa_forward(p, x, cfg, positions=pos, impl=impl)
        return (y ** 2).mean()

    gp = jax.grad(lambda p: loss(p, "pallas"))(p)
    gn = jax.grad(lambda p: loss(p, "naive"))(p)
    flat_p = jax.tree_util.tree_leaves(gp)
    flat_n = jax.tree_util.tree_leaves(gn)
    for a, b in zip(flat_p, flat_n):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)
