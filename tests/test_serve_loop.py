"""Continuous-batching serve loop: ragged batching must be *exactly* the
single-request decode — admission, retirement and slot reuse are pure
bookkeeping, never math. Plus EOS mid-stream retirement and freed-slot
admission mechanics."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED
from repro.launch.serve import ContinuousBatchingEngine, Request
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _make(arch="llama3.2-1b", impl="naive"):
    cfg = ASSIGNED[arch].reduced()
    model = build_model(cfg, impl=impl)
    params = model.init(KEY)
    return cfg, model, params


def _reference_generate(model, params, prompt, n_new, max_seq):
    """Single-request lockstep oracle: prefill + scalar-cache_len decode."""
    logits, pcache = model.prefill(
        params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]})
    cache = model.init_cache(1, max_seq, jnp.float32)

    def splice(buf, pc):
        start = (0, 0) + (0,) * (buf.ndim - 2)
        return jax.lax.dynamic_update_slice(buf, pc.astype(buf.dtype), start)

    cache = jax.tree_util.tree_map(splice, cache, pcache)
    toks = [int(jnp.argmax(logits[0, -1]))]
    P = len(prompt)
    for t in range(n_new - 1):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32), jnp.int32(P + t))
        toks.append(int(jnp.argmax(lg[0, -1])))
    return toks


def test_engine_matches_single_request_reference():
    """3 ragged requests through 2 slots == each served alone, token for token.

    max_batch < n_requests forces a queue: request 2 is admitted mid-stream
    into whichever slot retires first, with the other slot's cache_len ahead
    of it — exactly the ragged state the per-sequence kv_len masking and the
    one-hot cache scatter must keep independent per slot.
    """
    _, model, params = _make()
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, 64, p).astype(np.int32) for p in (5, 9, 3)]
    budgets = [6, 3, 5]
    max_seq = 32

    refs = [_reference_generate(model, params, pr, n, max_seq)
            for pr, n in zip(prompts, budgets)]

    engine = ContinuousBatchingEngine(model, params, max_batch=2, max_seq=max_seq)
    finished = engine.run([Request(uid=i, prompt=pr, max_new_tokens=n)
                           for i, (pr, n) in enumerate(zip(prompts, budgets))])

    assert sorted(finished) == [0, 1, 2]
    for uid, ref in enumerate(refs):
        assert finished[uid].tokens == ref, f"uid {uid} diverged from oracle"
        assert finished[uid].reason == "length"
        assert finished[uid].prompt_len == len(prompts[uid])
    # batching actually happened: fewer decode steps than serial generation
    assert engine.decode_steps < sum(b - 1 for b in budgets)
    assert 0.0 < engine.occupancy <= 1.0


def test_engine_retires_on_eos_and_admits_into_freed_slot():
    _, model, params = _make()
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, 64, 6).astype(np.int32) for _ in range(2)]
    max_seq = 32

    # oracle for request 0 tells us which token it will emit third; serving
    # with that as eos_id must truncate request 0 there, mid-stream
    ref0 = _reference_generate(model, params, prompts[0], 8, max_seq)
    eos = ref0[2]
    cut = ref0.index(eos) + 1  # first occurrence (may precede position 2)

    engine = ContinuousBatchingEngine(model, params, max_batch=1, max_seq=max_seq,
                                      eos_id=eos)
    finished = engine.run([Request(uid=0, prompt=prompts[0], max_new_tokens=8),
                           Request(uid=1, prompt=prompts[1], max_new_tokens=2)])

    assert finished[0].reason == "eos"
    assert finished[0].tokens == ref0[:cut]
    # the freed slot served request 1 afterwards (single slot => queued)
    assert 1 in finished
    assert len(finished[1].tokens) <= 2


def test_engine_pallas_impl_token_identical():
    """The pallas decode path serves the same stream with identical tokens."""
    outs = {}
    for impl in ("naive", "pallas"):
        _, model, params = _make(impl=impl)
        rng = np.random.default_rng(3)
        reqs = [Request(uid=i, prompt=rng.integers(0, 64, 4 + i).astype(np.int32),
                        max_new_tokens=3 + i) for i in range(3)]
        engine = ContinuousBatchingEngine(model, params, max_batch=2, max_seq=24)
        finished = engine.run(reqs)
        outs[impl] = {u: f.tokens for u, f in finished.items()}
    assert outs["naive"] == outs["pallas"]


def test_engine_rejects_stateful_families():
    import pytest
    cfg = ASSIGNED["rwkv6-7b"].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(KEY)
    with pytest.raises(ValueError, match="lockstep"):
        ContinuousBatchingEngine(model, params, max_batch=2, max_seq=16)


def test_engine_serves_up_to_cache_capacity():
    """A sequence may decode until the next write would fall off the cache:
    prompt P with an unbounded budget yields exactly max_seq - P + 1 tokens
    (the prefill token plus one per remaining cache position)."""
    _, model, params = _make()
    rng = np.random.default_rng(4)
    max_seq, P = 12, 7
    prompt = rng.integers(0, 64, P).astype(np.int32)
    engine = ContinuousBatchingEngine(model, params, max_batch=1, max_seq=max_seq)
    finished = engine.run([Request(uid=0, prompt=prompt, max_new_tokens=99)])
    assert finished[0].reason == "length"
    assert len(finished[0].tokens) == max_seq - P + 1
