"""Per-kernel shape/dtype sweeps vs the ref.py jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", [(1, 7, 64), (4, 3, 96), (2, 1, 128), (3, 17, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jax.random.normal(jax.random.fold_in(KEY, 1), shape[-1:], dtype)
    got = ops.rmsnorm(x, s)
    want = ref.ref_rmsnorm(x, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape,k", [((2, 8, 8, 16), 3), ((1, 13, 11, 24), 3),
                                     ((2, 16, 16, 8), 5), ((1, 32, 32, 32), 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_depthwise_sweep(shape, k, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 2), (k, k, shape[-1]), dtype)
    got = ops.depthwise_conv(x, w)
    want = ref.ref_depthwise_conv(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,K,hd", [(1, 16, 4, 4, 32), (2, 37, 8, 4, 16),
                                        (1, 128, 8, 2, 64), (2, 64, 4, 1, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, K, hd, causal):
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, K, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, K, hd))
    from repro.models.attention import naive_attention
    got = ops.flash_attention(q, k, v, causal=causal)
    want = naive_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    q = jax.random.normal(KEY, (1, 64, 4, 32), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 5), (1, 64, 2, 32), jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (1, 64, 2, 32), jnp.bfloat16)
    from repro.models.attention import naive_attention
    got = ops.flash_attention(q, k, v, causal=True)
    want = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


def test_flash_attention_bf16_env_toggle(monkeypatch):
    """REPRO_ATTN_BF16 reaches the Pallas kernels: bf16 dot inputs, f32
    statistics — close to the exact path, resolved per call (no stale jit)."""
    B, S, H, hd = 1, 32, 2, 16
    q = jax.random.normal(KEY, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, H, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 8), (B, S, H, hd))
    monkeypatch.delenv("REPRO_ATTN_BF16", raising=False)
    exact = np.asarray(ops.flash_attention(q, k, v, causal=True))
    monkeypatch.setenv("REPRO_ATTN_BF16", "1")
    lowp = np.asarray(ops.flash_attention(q, k, v, causal=True))
    assert np.all(np.isfinite(lowp))
    np.testing.assert_allclose(lowp, exact, rtol=3e-2, atol=3e-2)
    assert np.abs(lowp - exact).max() > 0.0

    # grads flow through the lowp backward kernels too
    g = jax.grad(lambda q: ops.flash_attention(q, k, v, causal=True).sum())(q)
    assert np.all(np.isfinite(np.asarray(g)))
