"""Optimizers, schedules, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compression import Compressor, quantize_int8, dequantize_int8, \
    topk_densify, topk_sparsify
from repro.optim.optimizers import adam, apply_updates, sgd
from repro.optim.schedule import cosine_schedule, warmup_linear


@pytest.mark.parametrize("make_opt,lr", [(lambda: sgd(), 0.1),
                                         (lambda: sgd(momentum=0.9), 0.05),
                                         (lambda: adam(), 0.05)])
def test_optimizer_descends_quadratic(make_opt, lr):
    opt = make_opt()
    params = {"w": jnp.array([3.0, -2.0, 1.0])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(120):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, lr)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-2


def test_schedules():
    s = warmup_linear(1.0, 10)
    assert float(s(0)) == pytest.approx(0.1)
    assert float(s(9)) == pytest.approx(1.0)
    c = cosine_schedule(1.0, 100, warmup_steps=10, min_ratio=0.1)
    assert float(c(5)) < 1.0
    assert float(c(99)) == pytest.approx(0.1, abs=0.02)


def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    assert float(jnp.abs(back - x).max()) <= float(s) * 0.5 + 1e-6


def test_topk_keeps_largest():
    x = jnp.array([0.1, -5.0, 0.2, 3.0, -0.05])
    vals, idx = topk_sparsify(x, 0.4)
    dense = topk_densify(vals, idx, x.shape)
    np.testing.assert_allclose(np.asarray(dense), [0, -5.0, 0, 3.0, 0])


def test_compressor_ratio_and_none():
    assert Compressor("none").ratio() == 1.0
    assert Compressor("int8").ratio() == 0.25
    assert Compressor("topk:0.1").ratio() == pytest.approx(0.2)
    g = {"w": jnp.ones((8,))}
    dec, err = Compressor("none").roundtrip(g, ())
    assert dec is g
