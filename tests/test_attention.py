"""Attention equivalences: chunked vs naive, MLA forward vs absorbed decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models.attention import (chunked_attention, init_mla_cache,
                                    mla_decode, mla_forward, mla_params,
                                    naive_attention)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("chunk", [4, 7, 16, 33])
@pytest.mark.parametrize("causal", [True, False])
def test_chunked_matches_naive(chunk, causal):
    q = jax.random.normal(KEY, (2, 33, 8, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 33, 4, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 33, 4, 16))
    a = naive_attention(q, k, v, causal=causal)
    b = chunked_attention(q, k, v, causal=causal, chunk=chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_chunked_mla_value_dim():
    """vd != qk head dim (MLA) must round-trip correctly."""
    q = jax.random.normal(KEY, (2, 17, 4, 24))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 17, 4, 24))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 17, 4, 16))
    a = naive_attention(q, k, v, causal=True)
    b = chunked_attention(q, k, v, causal=True, chunk=5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_mla_absorbed_decode_matches_forward():
    """Absorbed-matrix decode == full-materialization forward, token by token."""
    cfg = REGISTRY["deepseek-v3-671b"].reduced()
    p = mla_params(KEY, cfg)
    B, S = 2, 9
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    full = mla_forward(p, x, cfg, positions=pos, impl="naive")
    cache = init_mla_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = mla_decode(p, x[:, t:t + 1], cache, jnp.int32(t), cfg)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=2e-4, atol=2e-4)
