"""Mamba2 / RWKV6: chunked full-sequence pass == sequential decode steps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import ssm

KEY = jax.random.PRNGKey(1)


@pytest.fixture(scope="module")
def mamba_cfg():
    return ModelConfig(name="t", family="ssm", n_layers=1, d_model=32, n_heads=1,
                       n_kv_heads=1, d_ff=64, vocab_size=64, ssm_state=8)


@pytest.mark.parametrize("chunk", [3, 5, 19])
def test_mamba2_chunked_vs_step(mamba_cfg, chunk):
    p = ssm.mamba2_params(KEY, mamba_cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (2, 19, 32)) * 0.5
    out_c, st_c = ssm.mamba2_forward(p, x, mamba_cfg, chunk=chunk, return_state=True)
    st = ssm.init_mamba_state(mamba_cfg, 2)
    outs = []
    for t in range(19):
        o, st = ssm.mamba2_decode(p, x[:, t:t + 1], st, mamba_cfg)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(jnp.concatenate(outs, 1)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c["h"]), np.asarray(st["h"]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c["conv"]), np.asarray(st["conv"]),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("chunk", [4, 6, 17])
def test_rwkv6_chunked_vs_step(chunk):
    cfg = ModelConfig(name="r", family="ssm", n_layers=1, d_model=32, n_heads=2,
                      n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64)
    p = ssm.rwkv6_params(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 17, 32)) * 0.5
    out_c, S_c, _ = ssm.rwkv6_tmix(p["tmix"], x, cfg, chunk=chunk, return_state=True)
    st = ssm.init_rwkv_state(cfg, 2)
    S, prev = st["S"], st["prev_t"]
    outs = []
    for t in range(17):
        o, S, prev = ssm.rwkv6_tmix_step(p["tmix"], x[:, t:t + 1], S, prev, cfg)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(jnp.concatenate(outs, 1)),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(S_c), np.asarray(S), rtol=2e-4, atol=2e-4)


def test_rwkv6_cmix_shift():
    cfg = ModelConfig(name="r", family="ssm", n_layers=1, d_model=16, n_heads=1,
                      n_kv_heads=1, d_ff=32, vocab_size=64)
    p = ssm.rwkv6_params(KEY, cfg)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 5, 16))
    full, _ = ssm.rwkv6_cmix(p["cmix"], x)
    step0, _ = ssm.rwkv6_cmix(p["cmix"], x[:, :1], prev=jnp.zeros((2, 16)))
    np.testing.assert_allclose(np.asarray(full[:, :1]), np.asarray(step0), rtol=1e-6, atol=1e-6)
