"""Robustness: SLO arbitration, preemption lifecycle, graceful degradation,
chaos injection determinism, and property-style interleavings.

Everything here is deterministic — seeded RNGs, scripted schedules, virtual
latencies — so a failure is a real regression, never flake.
"""
import jax
import numpy as np
import pytest

from repro.engine.chaos import KINDS, ChaosEvent, ChaosInjector
from repro.engine.events import ChargingTrace
from repro.engine.jobs import PAUSED, RUNNING, ForegroundAppJob
from repro.engine.runtime import SwanRuntime
from repro.runtime.fault import FaultModel, StragglerPolicy


def _tiny_cfg(name):
    from repro.configs.base import ModelConfig
    return ModelConfig(name=name, family="dense", n_layers=2, d_model=32,
                       n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
                       tie_embeddings=True, source="tests/test_robustness.py")


def _engine(policy="serialize", *, slots=2, max_queue=None, num_blocks=10):
    from repro.launch.serve import ContinuousBatchingEngine
    from repro.models.registry import build_model
    cfg = _tiny_cfg("rb-serve")
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    return ContinuousBatchingEngine(
        model, params, max_batch=slots, max_seq=32, kv_layout="paged",
        block_size=4, num_blocks=num_blocks, admission_policy=policy,
        max_queue=max_queue)


def _req(uid, *, n_prompt=5, gen=4, deadline=None):
    from repro.launch.serve import Request
    rng = np.random.default_rng(uid)
    return Request(uid=uid, prompt=rng.integers(0, 64, n_prompt)
                   .astype(np.int32), max_new_tokens=gen,
                   deadline_steps=deadline)


# ---------------------------------------------------------------------------
# serve engine: graceful degradation
# ---------------------------------------------------------------------------


def test_shed_policy_bounds_queue_with_retry_after():
    eng = _engine("shed", max_queue=3)
    accepted = [eng.submit(_req(i)) for i in range(6)]
    assert accepted == [True] * 3 + [False] * 3
    assert eng.shed_count == 3
    assert all(r.reason == "shed" and r.retry_after >= 1
               for r in eng.rejected.values())
    while eng.queue or any(u is not None for u in eng.slot_uid):
        eng.step()
    # everything that was admitted finishes; nothing shed ever runs
    assert sorted(eng.finished) == [0, 1, 2]
    assert eng.stats()["shed"] == 3


def test_serialize_policy_never_refuses():
    eng = _engine("serialize")
    for i in range(6):
        assert eng.submit(_req(i))
    while eng.queue or any(u is not None for u in eng.slot_uid):
        eng.step()
    assert sorted(eng.finished) == list(range(6))
    assert eng.shed_count == 0 and not eng.rejected


def test_pool_pressure_shed_vs_serialize():
    # 9 usable blocks. The resident (5 prompt + 12 budget) reserves
    # ceil(17/4)=5; the hold takes the other 4; admitting uid 7 (worst case
    # 3 more) would over-commit the pool while slot 1 sits free — exactly
    # the state where the two policies diverge.
    shed, ser = _engine("shed", num_blocks=10), _engine("serialize",
                                                        num_blocks=10)
    for eng in (shed, ser):
        eng.submit(_req(0, gen=12))
        eng.step()  # resident admitted into slot 0
        assert eng.hold_blocks(100) == 4  # chaos co-tenant grabs the rest
        eng.submit(_req(7))
        eng.step()  # slot 1 is free, but admission sees pool pressure
    assert 7 in shed.rejected and shed.rejected[7].reason == "shed"
    assert 7 not in ser.rejected and [r.uid for r in ser.queue] == [7]
    ser.release_held()
    while ser.queue or any(u is not None for u in ser.slot_uid):
        ser.step()
    assert 7 in ser.finished  # serialize served it once pressure cleared


def test_hold_blocks_never_starves_residents():
    eng = _engine("shed", num_blocks=10)
    eng.submit(_req(0, gen=8))
    eng.step()
    # residents reserved their worst case; the hold can only take the rest
    reserved = sum(eng._reserved.values())
    held = eng.hold_blocks(100)
    assert held == eng.kv.pool.num_usable - reserved
    while any(u is not None for u in eng.slot_uid):
        eng.step()  # decode grows into reserved blocks; must never raise
    assert 0 in eng.finished
    assert len(eng.finished[0].tokens) == 8


def test_queued_deadline_times_out_waiting_not_resident():
    eng = _engine("serialize", slots=1)
    eng.submit(_req(0, gen=6, deadline=50))   # admitted immediately
    eng.submit(_req(1, gen=4, deadline=2))    # waits behind uid 0, expires
    for _ in range(10):
        eng.step()
    assert 1 in eng.rejected and eng.rejected[1].reason == "timeout"
    assert eng.timeout_count == 1
    assert 0 in eng.finished  # the resident was untouched by the deadline


def test_drain_sheds_queue_and_finishes_residents():
    eng = _engine("serialize", slots=1)
    for i in range(3):
        eng.submit(_req(i))
    eng.step()  # uid 0 resident
    eng.drain()
    assert not eng.accepting
    assert {r.reason for r in eng.rejected.values()} == {"draining"}
    assert not eng.submit(_req(9))  # refused while draining
    while any(u is not None for u in eng.slot_uid):
        eng.step()
    assert 0 in eng.finished and 9 in eng.rejected
    assert eng.stats()["accepting"] is False


# ---------------------------------------------------------------------------
# job lifecycle + foreground preemption + SLO arbitration
# ---------------------------------------------------------------------------


def _train_job(ticks, *, name="train"):
    from repro.engine.jobs import trace_latency_fn
    from repro.engine.rungs import default_rung_ladder
    from repro.engine.session import TrainSession
    from repro.launch.train import make_batch_fn
    from repro.optim.optimizers import sgd
    cfg = _tiny_cfg("rb-train")
    rungs = default_rung_ladder(batch=4, microbatch=1, attn_impl="naive",
                                include_bf16=False)
    for r in rungs:
        r.latency_estimate_s = 0.1 * r.rel_latency
    ses = TrainSession(cfg, rungs, optimizer=sgd(), lr=0.05,
                       batch_fn=make_batch_fn(cfg, 4, 8),
                       latency_fn=trace_latency_fn(None), adaptive=False,
                       verbose=False, name=name)
    return ses.bind(ticks)


def test_foreground_burst_pauses_and_resumes_exactly():
    ticks = 12
    train = _train_job(ticks)
    fg = ForegroundAppJob(bursts=[(4, 7)])
    rt = SwanRuntime([train, fg])
    res = rt.run(ticks + 6)  # paused ticks don't train; allow catch-up
    pauses = [m for m in train.timeline.migrations if m.reason == "pause"]
    resumes = [m for m in train.timeline.migrations if m.reason == "resume"]
    assert len(pauses) == 1 and len(resumes) == 1
    assert pauses[0].step == resumes[0].step  # exact pre-pause step
    assert res.preemptions == 1
    steps = [s.step for s in train.timeline.steps]
    assert steps == list(range(ticks))  # contiguous: nothing lost or redone
    assert train.state == RUNNING


def test_runtime_resumes_paused_jobs_at_horizon():
    train = _train_job(20)
    fg = ForegroundAppJob(bursts=[(2, 50)])  # burst outlives the horizon
    rt = SwanRuntime([train, fg])
    rt.run(6)
    assert train.state == RUNNING  # not stranded in PAUSED
    assert train._state is not None


def test_pause_is_idempotent_and_guards_state():
    train = _train_job(4)
    train.prepare()
    train.pause(0)
    assert train.paused and train.state == PAUSED
    train.pause(1)  # second pause: no double-checkpoint, no crash
    assert len([m for m in train.timeline.migrations
                if m.reason == "pause"]) == 1
    train.resume(2)
    assert train.state == RUNNING
    train.resume(3)  # idempotent
    assert len([m for m in train.timeline.migrations
                if m.reason == "resume"]) == 1


class _StubJob:
    """Minimal SocJob surface for arbitration unit tests."""

    def __init__(self, name, *, headroom=None, relinquish=1.0,
                 priority=1.0):
        self.name = name
        self.priority = priority
        self._headroom = headroom
        self._relinquish = relinquish
        self.migrations = []
        self.paused = False

    def slo_headroom(self):
        return self._headroom

    def can_downgrade(self):
        return True

    def relinquish_score(self):
        return self._relinquish

    def migrate(self, direction, reason, tick):
        self.migrations.append((direction, reason))
        return None


def _arbitrate(jobs, proposals, **kw):
    rt = SwanRuntime.__new__(SwanRuntime)
    rt.verbose = False
    rt._arbitrate(0, jobs, proposals, **kw)


def test_slo_violation_downgrades_cotenant_not_violator():
    violator = _StubJob("serve", headroom=-0.5, relinquish=10.0)
    cotenant = _StubJob("train", relinquish=1.0)
    _arbitrate([violator, cotenant], proposals=[])
    assert cotenant.migrations == [("down", "slo")]
    assert violator.migrations == []


def test_slo_violation_blocks_upgrades():
    violator = _StubJob("serve", headroom=-0.1)
    hopeful = _StubJob("train")
    _arbitrate([violator, hopeful], proposals=[(hopeful, "up")])
    assert hopeful.migrations == [("down", "slo")]  # shed, not lifted


def test_no_slo_reduces_to_relinquish_auction():
    a = _StubJob("a", relinquish=5.0)
    b = _StubJob("b", relinquish=1.0)
    _arbitrate([a, b], proposals=[(b, "down")])
    assert a.migrations == [("down", "interference")] or \
        a.migrations == [("down", "arbitration")]
    assert b.migrations == []


def test_upgrade_needs_positive_headroom():
    tight = _StubJob("serve", headroom=0.0)
    _arbitrate([tight], proposals=[(tight, "up")])
    assert tight.migrations == []


# ---------------------------------------------------------------------------
# chaos injector
# ---------------------------------------------------------------------------


def test_chaos_random_schedule_is_deterministic():
    a = ChaosInjector.random(3, 64)
    b = ChaosInjector.random(3, 64)
    assert a.events == b.events
    assert {e.kind for e in a.events} == set(KINDS)
    c = ChaosInjector.random(4, 64)
    assert c.events != a.events


def test_chaos_event_validation():
    with pytest.raises(ValueError):
        ChaosEvent(tick=0, kind="meteor_strike")
    with pytest.raises(ValueError):
        ChaosEvent(tick=0, kind="fg_burst", duration=0)


def test_chaos_latency_multiplier_windows():
    inj = ChaosInjector([ChaosEvent(tick=4, kind="latency_spike",
                                    duration=3, magnitude=2.0),
                         ChaosEvent(tick=5, kind="latency_spike",
                                    duration=1, magnitude=3.0)])
    assert inj.latency_multiplier(3) == 1.0
    assert inj.latency_multiplier(4) == 2.0
    assert inj.latency_multiplier(5) == 6.0  # overlapping spikes compound
    assert inj.latency_multiplier(7) == 1.0


def test_chaos_skips_absent_targets_loudly():
    inj = ChaosInjector([ChaosEvent(tick=0, kind="fg_burst"),
                         ChaosEvent(tick=0, kind="device_loss")])
    rt = SwanRuntime([_train_job(2)], chaos=inj)
    rt.run(2)
    assert inj.skipped_kinds() == {"fg_burst", "device_loss"}
    assert inj.applied == set()


# ---------------------------------------------------------------------------
# events + energy satellites
# ---------------------------------------------------------------------------


def test_charging_trace_parse_and_rate():
    tr = ChargingTrace.parse("4:8:5, 6:10:2")
    assert tr.rate(3) == 0.0
    assert tr.rate(4) == 5.0
    assert tr.rate(7) == 7.0  # overlapping chargers sum
    assert tr.rate(9) == 2.0 and not tr.active(10)
    with pytest.raises(ValueError):
        ChargingTrace.parse("5:5:1")


def test_energy_repay_floors_at_zero():
    from repro.core.energy import EnergyLoan
    loan = EnergyLoan(battery_j=100.0, daily_charge_j=10.0,
                      daily_usage_j=5.0)
    loan.borrow(8.0)
    loan.repay(3.0)
    assert loan.loan_j == 5.0
    loan.repay(100.0)
    assert loan.loan_j == 0.0
    loan.repay(-4.0)  # negative charger watts never borrow
    assert loan.loan_j == 0.0


# ---------------------------------------------------------------------------
# fault model hardening + seeded determinism
# ---------------------------------------------------------------------------


def test_fault_model_zero_mtbf_fails_all_deterministically():
    fm = FaultModel(mtbf_steps=0.0)
    assert fm.step_failures(4).all()
    fm2 = FaultModel(mtbf_steps=-1.0)
    assert fm2.step_failures(3).all()


def test_fault_model_empty_pool():
    fm = FaultModel(mtbf_steps=100.0)
    assert fm.step_failures(0).shape == (0,)


def test_fault_model_seeded_determinism():
    rolls_a = [FaultModel(mtbf_steps=5.0, seed=9).step_failures(16)
               for _ in range(1)][0]
    rolls_b = FaultModel(mtbf_steps=5.0, seed=9).step_failures(16)
    np.testing.assert_array_equal(rolls_a, rolls_b)
    rolls_c = FaultModel(mtbf_steps=5.0, seed=10).step_failures(16)
    assert not np.array_equal(rolls_a, rolls_c)


def test_straggler_accept_empty_round():
    pol = StragglerPolicy()
    out = pol.accept([], 4)
    assert out.indices.shape == (0,) and out.indices.dtype == np.int64
    assert len(out) == 0 and out.shortfall == 0
    assert pol.accept([1.0, 2.0], 0).indices.shape == (0,)


def test_straggler_deadline_drops_laggard():
    pol = StragglerPolicy(deadline_factor=1.5)
    out = pol.accept([1.0, 1.1, 50.0, 0.9], 3)
    assert len(out) == 3 and 2 not in out


def test_straggler_deadline_is_binding():
    # fewer than k finish inside the deadline: the deadline is binding — the
    # laggard is NOT silently accepted, and the shortfall is surfaced
    pol = StragglerPolicy(deadline_factor=1.5)
    out = pol.accept([1.0, 1.0, 50.0], 3)
    assert set(out.indices.tolist()) == {0, 1}
    assert out.shortfall == 1


def test_straggler_explicit_deadline_clamps():
    # an explicit wall-clock deadline can only tighten the derived one
    pol = StragglerPolicy(deadline_factor=10.0)
    out = pol.accept([1.0, 2.0, 3.0], 3, deadline_s=1.5)
    assert set(out.indices.tolist()) == {0}
    assert out.shortfall == 2 and out.deadline_s == 1.5


# ---------------------------------------------------------------------------
# property-style: seeded interleavings of pause/resume/migrate/tick
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_interleaved_lifecycle_keeps_steps_monotonic(seed, tmp_path):
    """Any seeded interleaving of tick / pause / resume / migrate leaves the
    training step counter monotonic (each executed step is the successor of
    the last) and the checkpoint restorable at the final step."""
    from repro.checkpoint.manager import CheckpointManager
    train = _train_job(10_000, name=f"prop-{seed}")
    train.ckpt = CheckpointManager(str(tmp_path / "ck"), keep=3)
    train.prepare()
    rng = np.random.default_rng(seed)
    executed = []
    tick = 0
    for _ in range(30):
        op = rng.choice(["tick", "pause", "resume", "down", "up"])
        if op == "tick" and not train.paused:
            report = train.step(tick)
            train.observe(tick, report, 1.0)
            executed.append(train._step_idx)
            train.end_tick(tick)
        elif op == "pause" and not train.paused:
            train.pause(tick)
        elif op == "resume" and train.paused:
            train.resume(tick)
        elif op in ("down", "up") and not train.paused:
            train.migrate(op, "test", tick)
        tick += 1
    if train.paused:
        train.resume(tick)
    # monotonic, contiguous: no step lost, none executed twice
    assert executed == list(range(len(executed)))
    assert train._step_idx == len(executed)
    # the session's state survives a final checkpoint round-trip
    train.ckpt.save(train._step_idx, train._state)
    step, state = train.ckpt.restore_latest()
    assert step == train._step_idx
    leaves = [np.asarray(x) for x in
              jax.tree_util.tree_leaves(state["params"])]
    assert all(np.isfinite(leaf).all() for leaf in leaves)


def test_interleaving_survives_torn_checkpoint_between_pause_resume(
        tmp_path):
    """A torn file appearing after the pause save (chaos: crash mid-write of
    a NEWER checkpoint) must not derail resume — it falls back to the intact
    pause checkpoint and the step counter is unchanged."""
    from repro.checkpoint.manager import CheckpointManager
    train = _train_job(100)
    train.ckpt = CheckpointManager(str(tmp_path / "ck"), keep=5)
    train.prepare()
    for tick in range(3):
        report = train.step(tick)
        train.observe(tick, report, 1.0)
        train.end_tick(tick)
    train.pause(3)
    pre = train._step_idx
    torn = train.ckpt._path(pre + 1)
    with open(torn, "wb") as f:
        f.write(b"SWCK\x01\x00garbage")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        train.resume(4)
    assert train._step_idx == pre
    report = train.step(5)  # training continues from the exact step
    train.observe(5, report, 1.0)
    train.end_tick(5)
    assert train._step_idx == pre + 1
