"""KV-cache decode == teacher-forced forward, token by token, for every
decoder arch (high MoE capacity so no tokens drop); plus pallas-vs-naive
decode parity (GQA/MLA, ragged per-sequence cache_lens, bf16 caches)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED
from repro.models import build_model

KEY = jax.random.PRNGKey(0)

TOL = {"zamba2-2.7b": 5e-3, "rwkv6-7b": 5e-3}


@pytest.mark.parametrize("arch", sorted(a for a in ASSIGNED if a != "whisper-small"))
def test_decode_matches_forward(arch):
    cfg = ASSIGNED[arch].reduced()
    model = build_model(cfg, impl="naive", moe_cf=100.0)
    params = model.init(KEY)
    B, S = 2, 8
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embed"] = jax.random.normal(KEY, (B, cfg.n_image_tokens,
                                                       cfg.d_model)) * 0.02
    full = model.forward(params, batch)
    cache = model.init_cache(B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, batch["tokens"][:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    tol = TOL.get(arch, 2e-3)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=tol, atol=tol)


def test_whisper_decode_matches_forward():
    from repro.models import encdec as E
    cfg = ASSIGNED["whisper-small"].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(KEY)
    B, S = 2, 8
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "audio_embed": jax.random.normal(KEY, (B, cfg.n_audio_frames,
                                                    cfg.d_model)) * 0.02}
    full = model.forward(params, batch)
    cache = model.init_cache(B, S, jnp.float32)
    enc_h = E.encode(params, cfg, batch["audio_embed"])
    ks, vs = [], []
    for i in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params["dec_layers"])
        hd = cfg.head_dim
        ks.append((enc_h @ lp["cross_attn"]["wk"]).reshape(B, -1, cfg.n_kv_heads, hd))
        vs.append((enc_h @ lp["cross_attn"]["wv"]).reshape(B, -1, cfg.n_kv_heads, hd))
    cache["cross"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, batch["tokens"][:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=2e-3, atol=2e-3)


def test_prefill_matches_forward_logits():
    cfg = ASSIGNED["llama3.2-1b"].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)}
    full = model.forward(params, batch)
    pl, cache = model.prefill(params, batch)
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(pl),
                               rtol=1e-5, atol=1e-5)
    assert cache["k"].shape[0] == cfg.n_layers


# ---------------------------------------------------------------------------
# pallas single-query decode kernel vs the naive oracle
# ---------------------------------------------------------------------------


def _flash_decode_ref(q, k, v, lengths, scale=None):
    """float64 numpy oracle for the grouped single-query kernel."""
    B, K, G, hd = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    outs = []
    for b in range(B):
        L = int(lengths[b])
        if L == 0:
            outs.append(np.zeros((K, G, v.shape[-1])))
            continue
        s = np.einsum("kgh,skh->kgs", np.asarray(q[b], np.float64),
                      np.asarray(k[b, :L], np.float64)) * scale
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        outs.append(np.einsum("kgs,skv->kgv", p, np.asarray(v[b, :L], np.float64)))
    return np.stack(outs).astype(np.float32)


@pytest.mark.parametrize("cache_dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_ragged_vs_ref(cache_dtype):
    from repro.kernels.flash_attention import flash_decode
    B, Smax, K, G, hd = 3, 40, 2, 4, 16
    q = jax.random.normal(KEY, (B, K, G, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Smax, K, hd)).astype(cache_dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Smax, K, hd)).astype(cache_dtype)
    lengths = jnp.array([7, 40, 0], jnp.int32)  # ragged, incl. an idle slot
    out = np.asarray(flash_decode(q, k, v, lengths, block_k=16))
    ref = _flash_decode_ref(q, k.astype(jnp.float32), v.astype(jnp.float32),
                            np.asarray(lengths))
    tol = 2e-6 if cache_dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)
    assert np.all(out[2] == 0.0)  # length-0 rows are zeros, not NaN


def test_flash_decode_bf16_accumulation_toggle():
    from repro.kernels.flash_attention import flash_decode
    B, Smax, K, G, hd = 2, 32, 2, 2, 16
    q = jax.random.normal(KEY, (B, K, G, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Smax, K, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Smax, K, hd))
    lengths = jnp.array([32, 17], jnp.int32)
    exact = np.asarray(flash_decode(q, k, v, lengths, block_k=16, lowp=False))
    lowp = np.asarray(flash_decode(q, k, v, lengths, block_k=16, lowp=True))
    assert np.all(np.isfinite(lowp))
    # bf16 dot inputs: close to f32 but not bit-identical
    np.testing.assert_allclose(lowp, exact, rtol=3e-2, atol=3e-2)
    assert np.abs(lowp - exact).max() > 0.0


@pytest.mark.parametrize("cache_dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ragged", [False, True])
def test_gqa_decode_pallas_matches_naive(cache_dtype, ragged):
    from repro.models.attention import gqa_decode, gqa_params
    cfg = ASSIGNED["llama3.2-1b"].reduced()
    p = gqa_params(KEY, cfg)
    B, Smax = 3, 24
    x = jax.random.normal(KEY, (B, 1, cfg.d_model)) * 0.1
    pre = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Smax, cfg.n_kv_heads,
                                                         cfg.head_dim)) * 0.3
    cache = {"k": pre.astype(cache_dtype), "v": (pre * 0.7).astype(cache_dtype)}
    cl = jnp.array([5, 23, 1], jnp.int32) if ragged else jnp.int32(6)
    y_n, c_n = gqa_decode(p, x, cache, cl, cfg, impl="naive")
    y_p, c_p = gqa_decode(p, x, cache, cl, cfg, impl="pallas")
    tol = 1e-5 if cache_dtype == jnp.float32 else 5e-3
    np.testing.assert_allclose(np.asarray(y_n), np.asarray(y_p), rtol=tol, atol=tol)
    # the cache update is impl-independent
    np.testing.assert_allclose(np.asarray(c_n["k"]), np.asarray(c_p["k"]))


@pytest.mark.parametrize("ragged", [False, True])
def test_mla_decode_pallas_matches_naive(ragged):
    from repro.models.attention import init_mla_cache, mla_decode, mla_params
    cfg = ASSIGNED["deepseek-v3-671b"].reduced()
    p = mla_params(KEY, cfg)
    B, Smax = 3, 24
    x = jax.random.normal(KEY, (B, 1, cfg.d_model)) * 0.1
    cache = init_mla_cache(cfg, B, Smax, jnp.float32)
    cache = {"latent": jax.random.normal(jax.random.fold_in(KEY, 4),
                                         cache["latent"].shape) * 0.3,
             "k_rope": jax.random.normal(jax.random.fold_in(KEY, 5),
                                         cache["k_rope"].shape) * 0.3}
    cl = jnp.array([4, 23, 2], jnp.int32) if ragged else jnp.int32(7)
    y_n, c_n = mla_decode(p, x, cache, cl, cfg, impl="naive")
    y_p, c_p = mla_decode(p, x, cache, cl, cfg, impl="pallas")
    np.testing.assert_allclose(np.asarray(y_n), np.asarray(y_p),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(c_n["latent"]), np.asarray(c_p["latent"]))


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v3-671b"])
def test_model_decode_pallas_token_identical(arch):
    """Greedy decode through the full stack: pallas == naive, token for token."""
    from repro.launch.steps import greedy_decode_tokens
    cfg = ASSIGNED[arch].reduced()
    toks = jax.random.randint(KEY, (2, 1), 0, cfg.vocab_size)
    streams = {}
    for impl in ("naive", "pallas"):
        model = build_model(cfg, impl=impl, moe_cf=100.0)
        params = model.init(KEY)
        streams[impl] = greedy_decode_tokens(model, params, toks, steps=4,
                                             max_len=8)
    np.testing.assert_array_equal(streams["naive"], streams["pallas"])


def test_auto_decode_impl_policy():
    from repro.kernels.backend import auto_decode_impl
    assert auto_decode_impl(128, interpret=False) == "naive"
    assert auto_decode_impl(512, interpret=False) == "pallas"  # gate regime
    assert auto_decode_impl(2048, interpret=False) == "pallas"
    assert auto_decode_impl(2048, interpret=True) == "naive"
