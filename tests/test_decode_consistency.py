"""KV-cache decode == teacher-forced forward, token by token, for every
decoder arch (high MoE capacity so no tokens drop)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED
from repro.models import build_model

KEY = jax.random.PRNGKey(0)

TOL = {"zamba2-2.7b": 5e-3, "rwkv6-7b": 5e-3}


@pytest.mark.parametrize("arch", sorted(a for a in ASSIGNED if a != "whisper-small"))
def test_decode_matches_forward(arch):
    cfg = ASSIGNED[arch].reduced()
    model = build_model(cfg, impl="naive", moe_cf=100.0)
    params = model.init(KEY)
    B, S = 2, 8
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["image_embed"] = jax.random.normal(KEY, (B, cfg.n_image_tokens,
                                                       cfg.d_model)) * 0.02
    full = model.forward(params, batch)
    cache = model.init_cache(B, S, jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, batch["tokens"][:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    tol = TOL.get(arch, 2e-3)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=tol, atol=tol)


def test_whisper_decode_matches_forward():
    from repro.models import encdec as E
    cfg = ASSIGNED["whisper-small"].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(KEY)
    B, S = 2, 8
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "audio_embed": jax.random.normal(KEY, (B, cfg.n_audio_frames,
                                                    cfg.d_model)) * 0.02}
    full = model.forward(params, batch)
    cache = model.init_cache(B, S, jnp.float32)
    enc_h = E.encode(params, cfg, batch["audio_embed"])
    ks, vs = [], []
    for i in range(cfg.n_layers):
        lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params["dec_layers"])
        hd = cfg.head_dim
        ks.append((enc_h @ lp["cross_attn"]["wk"]).reshape(B, -1, cfg.n_kv_heads, hd))
        vs.append((enc_h @ lp["cross_attn"]["wv"]).reshape(B, -1, cfg.n_kv_heads, hd))
    cache["cross"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    outs = []
    for t in range(S):
        lg, cache = model.decode_step(params, cache, batch["tokens"][:, t:t + 1],
                                      jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=2e-3, atol=2e-3)


def test_prefill_matches_forward_logits():
    cfg = ASSIGNED["llama3.2-1b"].reduced()
    model = build_model(cfg, impl="naive")
    params = model.init(KEY)
    batch = {"tokens": jax.random.randint(KEY, (2, 8), 0, cfg.vocab_size)}
    full = model.forward(params, batch)
    pl, cache = model.prefill(params, batch)
    np.testing.assert_allclose(np.asarray(full[:, -1:]), np.asarray(pl),
                               rtol=1e-5, atol=1e-5)
    assert cache["k"].shape[0] == cfg.n_layers
