"""Paper Table 4 + Figs 5-7: large-scale FL time-to-accuracy & energy.

Two modes:
  - statistical cohort (default): 480-2400 clients on GreenHub-like traces,
    energy loans, straggler deadline; reports TTA speedup, energy efficiency
    and online-device counts for ShuffleNet/MobileNet/ResNet34.
  - real-train cohort (table4/real_*): a reduced ResNet on synthetic
    GoogleSpeech-shaped data,真 FedAvg over 8 clients, proving the actual
    aggregation/optimization path converges.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.fl.simulator import compare_policies

PAPER = {"mobilenet-v2": (23.3, 7.0), "shufflenet-v2": (6.5, 5.8),
         "resnet34": (1.2, 1.6)}


def run(fast: bool = True):
    rows = []
    rounds = 200 if fast else 600
    n_clients = 480 if fast else 2400
    for wl, (psp, pee) in PAPER.items():
        t0 = time.perf_counter()
        res = compare_policies(wl, rounds=rounds, n_clients=n_clients,
                               clients_per_round=50)
        us = (time.perf_counter() - t0) * 1e6
        tgt = min(res["baseline"].final_accuracy, res["swan"].final_accuracy)
        tb = res["baseline"].time_to_accuracy(tgt) or float("inf")
        ts = res["swan"].time_to_accuracy(tgt) or float("inf")
        sp = tb / ts
        ee = res["baseline"].total_energy_j / max(res["swan"].total_energy_j, 1e-9)
        online_b = np.mean([r.online for r in res["baseline"].rounds[-20:]])
        online_s = np.mean([r.online for r in res["swan"].rounds[-20:]])
        rows.append((f"table4/{wl}/tta_speedup", us, f"{sp:.2f}x(paper {psp}x)"))
        rows.append((f"table4/{wl}/energy_eff", us, f"{ee:.2f}x(paper {pee}x)"))
        rows.append((f"table4/{wl}/online_last20", us,
                     f"swan={online_s:.0f};baseline={online_b:.0f}"))
    rows += run_real()
    return rows


def run_real():
    """Real FedAvg on a reduced ResNet: proves the optimization path."""
    from repro.configs import get_config
    from repro.data.pipeline import synthetic_cnn_batch
    from repro.fl.aggregation import fedavg
    from repro.models import build_model
    from repro.optim.optimizers import sgd, apply_updates

    cfg = get_config("resnet34").reduced()
    model = build_model(cfg)
    opt = sgd()
    params = model.init(jax.random.PRNGKey(0))
    n_clients, rounds, local_steps = 8, 6, 4

    @jax.jit
    def local_update(p, batch):
        loss, g = jax.value_and_grad(model.loss)(p, batch)
        upd, _ = opt.update(g, (), p, 0.05)
        return apply_updates(p, upd), loss

    t0 = time.perf_counter()
    first_loss = last_loss = None
    for rnd in range(rounds):
        deltas, losses = [], []
        for c in range(n_clients):
            rng = np.random.default_rng([rnd, c])
            local = params
            for s in range(local_steps):
                batch = synthetic_cnn_batch(rng, 16, cfg.image_size,
                                            cfg.in_channels, cfg.n_classes)
                local, loss = local_update(local, batch)
            losses.append(float(loss))
            deltas.append(jax.tree_util.tree_map(
                lambda a, b: a - b, local, params))
        params = fedavg(params, deltas)
        if first_loss is None:
            first_loss = float(np.mean(losses))
        last_loss = float(np.mean(losses))
    us = (time.perf_counter() - t0) * 1e6 / rounds
    assert last_loss < first_loss, "real FedAvg failed to reduce loss"
    return [("table4/real_fedavg_resnet", us,
             f"loss {first_loss:.3f}->{last_loss:.3f} over {rounds} rounds")]
