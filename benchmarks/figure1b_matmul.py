"""Paper Fig. 1b: per-core 512x512 matmul latency across SoC core classes.

Rows: modeled per-core-class matmul latency for each device (the SoC model
that drives all Swan decisions) + one real host-CPU matmul timing as the
physical anchor.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import energy as E

MATMUL_GFLOPS = 2 * 512 ** 3 / 1e9


def run():
    rows = []
    t = None
    # real host anchor
    x = jnp.ones((512, 512), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    f(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        f(x).block_until_ready()
    t = (time.perf_counter() - t0) / 20
    rows.append(("fig1b/host_cpu_matmul512", t * 1e6, f"{MATMUL_GFLOPS / t:.1f}GFLOPs"))
    for dev, model in E.SOC_MODELS.items():
        seen = set()
        for core in model.cores:
            if core.name in seen:
                continue
            seen.add(core.name)
            lat = MATMUL_GFLOPS / core.gflops
            rows.append((f"fig1b/{dev}/{core.name}", lat * 1e6,
                         f"{core.gflops:.1f}GFLOPs"))
    return rows
