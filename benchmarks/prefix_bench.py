"""Prefix-sharing + host-swap benchmark over the paged serving engine.

Runs a shared-prefix request trace (the system-prompt regime: every request
carries the same long prefix plus a short unique suffix) through the paged
continuous-batching engine three ways:

  - ``share``: prefix cache on — admission hash-conses the common prefix
    blocks, so only the unique-suffix chunks run prefill compute;
  - ``noshare``: prefix cache off — every request prefills its full prompt
    (the PR 6 baseline);
  - ``swap`` / ``serialize``: the same trace on an over-committed pool
    (too small for all residents' worst case), once with the host-memory
    swap tier and once with the PR 6 serialize policy, to show swap admits
    earlier instead of stalling the queue.

Engines are warmed (jit compiles paid on a throwaway prefix of the trace)
before timing, so the ratio measures steady-state serving, not compilation.

Gates (CI fails the job otherwise; results land in ``BENCH_prefix.json``):

  - token parity: every variant emits byte-identical greedy streams per uid;
  - hit rate: >= 50% of prompt blocks served from the prefix cache on the
    timed trace;
  - throughput: sharing reaches >= 1.3x the no-sharing tokens/sec;
  - swap: the over-committed pool admits via swap-out (>= 1) with mean
    admission wait no worse than the serialize baseline's.

Usage:
  PYTHONPATH=src python benchmarks/prefix_bench.py [--out BENCH_prefix.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _shared_prefix_requests(rng, n: int, *, prefix_len: int, suffix_len: int,
                            gen: int, vocab: int, uid0: int = 0):
    """``n`` requests sharing one ``prefix_len``-token prefix, each with a
    distinct suffix — the shared-system-prompt traffic prefix caching is
    built for."""
    from repro.launch.serve import Request
    prefix = rng.integers(0, vocab, prefix_len).astype(np.int32)
    reqs = []
    for i in range(n):
        sfx = rng.integers(0, vocab, suffix_len).astype(np.int32)
        reqs.append(Request(uid=uid0 + i,
                            prompt=np.concatenate([prefix, sfx]),
                            max_new_tokens=gen))
    return reqs


def _run_trace(engine, reqs):
    """Warm-started timed run; returns (tokens-by-uid, wall seconds).
    ``engine.finished`` accumulates across runs, so results are filtered
    to this trace's uids (the warmup slice used a disjoint uid range)."""
    uids = {r.uid for r in reqs}
    t0 = time.perf_counter()
    finished = engine.run(reqs)
    dt = time.perf_counter() - t0
    return {u: f.tokens for u, f in finished.items() if u in uids}, dt


def bench_prefix(arch: str = "llama3.2-1b", *, batch: int = 4,
                 block_size: int = 16, prefix_blocks: int = 6,
                 suffix_len: int = 8, gen: int = 4, requests: int = 12,
                 impl: str = "naive", seed: int = 0):
    """One cell: share vs noshare on a roomy pool, swap vs serialize on an
    over-committed one. Returns (records, gates)."""
    from repro.configs import get_config
    from repro.launch.serve import ContinuousBatchingEngine
    from repro.models import build_model

    cfg = get_config(arch).reduced()
    model = build_model(cfg, impl=impl)
    params = model.init(jax.random.PRNGKey(0))

    prefix_len = prefix_blocks * block_size
    prompt_len = prefix_len + suffix_len
    max_seq = 2 * (prompt_len + gen)

    def make_reqs(uid0=0):
        rng = np.random.default_rng(seed)
        return _shared_prefix_requests(
            rng, requests, prefix_len=prefix_len, suffix_len=suffix_len,
            gen=gen, vocab=cfg.vocab_size, uid0=uid0)

    def make_engine(**kw):
        return ContinuousBatchingEngine(
            model, params, max_batch=batch, max_seq=max_seq,
            kv_layout="paged", block_size=block_size, **kw)

    records, tokens, waits = [], {}, {}
    # over-committed pool: room for ~half the slots' worst case, so the
    # trace cannot keep every slot resident without swap or serialization
    worst = -(-(prompt_len + gen) // block_size)
    tight_blocks = (batch // 2) * worst + 2

    variants = [
        ("share", dict(prefix_cache=True)),
        ("noshare", dict(prefix_cache=False)),
        ("swap", dict(prefix_cache=False, admission_policy="swap",
                      num_blocks=tight_blocks)),
        ("serialize", dict(prefix_cache=False, admission_policy="serialize",
                           num_blocks=tight_blocks)),
    ]
    hit_rate = 0.0
    for name, kw in variants:
        engine = make_engine(**kw)
        # pay every jit compile (prefill chunks, decode, table/COW/swap
        # helpers) on a warmup slice so the timed run is steady-state; the
        # slice runs twice so the second pass hits the full-prompt prefix
        # path and compiles the read-only last-chunk recompute too
        engine.run(make_reqs(uid0=10_000)[:batch])
        engine.run(make_reqs(uid0=20_000)[:batch])
        pre = engine.kv.prefix.stats() if engine.kv.prefix is not None else None
        toks, dt = _run_trace(engine, make_reqs())
        tokens[name] = toks
        stats = engine.stats()
        timed_tokens = sum(len(t) for t in toks.values())
        rec = {
            "bench": "prefix_serve", "shape": arch, "impl": impl,
            "variant": name, "slots": batch, "block_size": block_size,
            "prompt_len": prompt_len, "prefix_len": prefix_len,
            "requests": requests, "tokens": timed_tokens,
            "wall_s": round(dt, 4),
            "tok_s": round(timed_tokens / max(dt, 1e-9), 1),
            "prefill_chunks": stats["prefill_chunks"],
            "prefill_chunks_skipped": stats["prefill_chunks_skipped"],
            "cow_copies": stats["cow_copies"],
            "table_rows_shipped": stats["table_rows_shipped"],
            "table_uploads": stats["table_uploads"],
            "swap_outs": stats["swap_outs"],
            "swap_ins": stats["swap_ins"],
            "admission_wait_mean": stats["admission_wait_mean"],
            "peak_blocks": stats["pool"]["peak_blocks_in_use"],
            "pool_blocks": stats["pool"]["num_blocks"],
            "status": "ok",
        }
        if pre is not None:
            # hit rate over the timed trace only (warmup seeded the index)
            post = engine.kv.prefix.stats()
            lk = post["lookups"] - pre["lookups"]
            rec["prefix_hit_rate"] = round(
                (post["hits"] - pre["hits"]) / max(lk, 1), 4)
            hit_rate = rec["prefix_hit_rate"]
        records.append(rec)
        waits[name] = stats["admission_wait_mean"]

    parity = all(tokens[v] == tokens["noshare"]
                 for v in ("share", "swap", "serialize"))
    share, noshare = records[0], records[1]
    speedup = share["tok_s"] / max(noshare["tok_s"], 1e-9)
    swap_rec = records[2]
    gates = {
        "token_parity": parity,
        "prefix_hit_rate": hit_rate,
        "hit_rate_gate_50pct": bool(hit_rate >= 0.5),
        "share_speedup": round(speedup, 2),
        "speedup_gate_1p3x": bool(speedup >= 1.3),
        "swap_outs": swap_rec["swap_outs"],
        "swap_admits_over_committed": bool(
            swap_rec["swap_outs"] >= 1 and
            swap_rec["admission_wait_mean"] <= waits["serialize"]),
    }
    ok = parity and gates["hit_rate_gate_50pct"] and \
        gates["speedup_gate_1p3x"] and gates["swap_admits_over_committed"]
    if not ok:
        for rec in records:
            rec["status"] = "error: prefix gates failed " + json.dumps(gates)
    return records, gates


def run(fast: bool = True):
    """Harness entry (benchmarks/run.py): yields (name, us, derived) rows;
    raises after the good rows when a gate fails so the failure lands in
    the harness accounting."""
    del fast
    records, gates = bench_prefix()
    for rec in records:
        yield (f"prefix_{rec['shape']}_{rec['variant']}",
               rec["wall_s"] * 1e6,
               f"tok_s={rec['tok_s']} chunks={rec['prefill_chunks']} "
               f"skipped={rec['prefill_chunks_skipped']} "
               f"swap={rec['swap_outs']}/{rec['swap_ins']}")
    if records[0]["status"] != "ok":
        raise RuntimeError(f"prefix bench gates failed: {gates}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--prefix-blocks", type=int, default=6)
    ap.add_argument("--suffix-len", type=int, default=8)
    ap.add_argument("--gen", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--impl", default="naive", choices=("naive", "pallas"))
    ap.add_argument("--out", default="BENCH_prefix.json")
    args = ap.parse_args()

    records, gates = bench_prefix(
        args.arch, batch=args.batch, block_size=args.block_size,
        prefix_blocks=args.prefix_blocks, suffix_len=args.suffix_len,
        gen=args.gen, requests=args.requests, impl=args.impl)
    print("name,us_per_call,derived")
    for rec in records:
        print(f"prefix_{rec['shape']}_{rec['variant']},"
              f"{rec['wall_s'] * 1e6:.0f},"
              f"tok_s={rec['tok_s']} hit={rec.get('prefix_hit_rate', '-')} "
              f"swap={rec['swap_outs']}/{rec['swap_ins']}")

    payload = {
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        **gates,
        "results": records,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    ok = records[0]["status"] == "ok"
    print(f"# wrote {args.out} (hit={gates['prefix_hit_rate']} "
          f"speedup={gates['share_speedup']}x parity="
          f"{gates['token_parity']} swap_outs={gates['swap_outs']})",
          file=sys.stderr)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
