"""Paper Table 3: foreground-experience impact of background training.

PCMark-analogue: a foreground app needs the big cores; its score drops by the
fraction of its compute the background trainer steals. The baseline trains
statically on all big cores; Swan's controller infers the interference from
its own slowed steps and migrates down the pruned ladder, relinquishing the
contended cores (paper Fig. 4b loop).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import energy as E
from repro.core.planner import explore_soc
from repro.core.profiler import greedy_baseline_profile

FOREGROUND_CORES = 2  # typical app uses 1-2 threads (paper §3.2, [27])


def _contention(train_cores, model) -> float:
    """Fraction of the foreground app's big-core demand stolen by training."""
    classes = model.classes()
    fast = set(classes.get("big", ()) + classes.get("prime", ()))
    stolen = len(fast & set(train_cores))
    free_fast = len(fast) - stolen
    deficit = max(0, FOREGROUND_CORES - free_fast)
    return deficit / FOREGROUND_CORES


def score_impact(device: str, workload: str = "resnet34", steps: int = 60):
    model = E.SOC_MODELS[device]
    # baseline: static greedy choice, never moves
    base_choice = greedy_baseline_profile(model, workload).choice
    base_impact = _contention(base_choice.cores, model)
    # swan: controller observes inflated latency while foreground runs
    plan = explore_soc(device, workload)
    ctl = plan.controller(upgrade_patience=10)
    impacts = []
    for step in range(steps):
        cont = _contention(ctl.active.choice.cores, model)
        # foreground active the whole benchmark -> training is slowed by
        # sharing, which is exactly the signal Swan can see without root
        observed = ctl.active.latency_s * (1.0 + 1.5 * cont)
        ctl.observe_step(observed)
        impacts.append(cont)
    swan_impact = float(np.mean(impacts[10:]))  # steady state after migration
    return -100 * 0.4 * base_impact, -100 * 0.4 * swan_impact, ctl


def adaptive_vs_static(steps: int = 40, json_path: str = "BENCH_table3_timeline.json"):
    """The engine-backed Table 3: a *real* training run (tiny LM, real
    gradients) under a synthetic co-tenant burst, adaptive (TrainSession
    migrating down the Rung ladder) vs static (pinned to the fastest rung).

    Step latencies are simulated via the rungs' planner estimates so the
    comparison is deterministic; the compute, migrations and state carry-over
    are real. Emits the migration timeline plus both step-time curves as
    JSON for downstream plotting.
    """
    import dataclasses as _dc
    import json

    from repro.configs.base import ModelConfig
    from repro.engine.events import InterferenceTrace
    from repro.engine.jobs import trace_latency_fn
    from repro.engine.rungs import default_rung_ladder
    from repro.engine.session import TrainSession
    from repro.launch.train import make_batch_fn
    from repro.optim.optimizers import sgd

    tiny = ModelConfig(name="table3-tiny", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=256, tie_embeddings=True,
                       source="benchmarks/table3_interference.py")
    burst = (steps // 4, steps // 4 + steps // 3, 3.0)
    trace = InterferenceTrace.parse(f"{burst[0]}:{burst[1]}:{burst[2]}")
    rungs = default_rung_ladder(batch=8, microbatch=1, attn_impl="naive")
    for r in rungs:
        r.latency_estimate_s = 0.1 * r.rel_latency  # virtual clean step time

    latency_fn = trace_latency_fn(trace)

    def session(adaptive):
        ru = rungs if adaptive else [_dc.replace(rungs[0], name="static")]
        return TrainSession(tiny, ru, optimizer=sgd(), lr=0.05,
                            batch_fn=make_batch_fn(tiny, 8, 32),
                            latency_fn=latency_fn, trace=trace,
                            adaptive=adaptive, upgrade_patience=5,
                            verbose=False)

    res_a = session(True).run(steps)
    res_s = session(False).run(steps)

    def virtual_total(res):
        t = sum(res.timeline.step_times(observed=True))
        for m in res.timeline.migrations:  # remesh stalls, in virtual steps
            t += m.cost_steps * (res.timeline.steps[0].observed_s
                                 if res.timeline.steps else 0.0)
        return t

    total_a, total_s = virtual_total(res_a), virtual_total(res_s)
    payload = {
        "trace": trace.to_json(),
        "adaptive": {"step_s": res_a.timeline.step_times(observed=True),
                     "rungs": [s.rung for s in res_a.timeline.steps],
                     "final_loss": res_a.losses[-1],
                     "timeline": res_a.timeline.to_json()},
        "static": {"step_s": res_s.timeline.step_times(observed=True),
                   "final_loss": res_s.losses[-1]},
        "virtual_total_s": {"adaptive": total_a, "static": total_s},
        "speedup": total_s / max(total_a, 1e-12),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
    return payload, res_a, res_s


def run():
    rows = []
    paper = {"tab_s6": (-10.2, -5.8), "oneplus8": (-12.5, 0.0),
             "pixel3": (-27.0, -3.1), "s10e": (-11.2, 0.0)}
    for device in ("tab_s6", "oneplus8", "pixel3", "s10e"):
        t0 = time.perf_counter()
        base, swan, ctl = score_impact(device)
        us = (time.perf_counter() - t0) * 1e6
        pb, ps = paper[device]
        rows.append((f"table3/{device}/baseline_pct", us, f"{base:.1f}(paper {pb})"))
        rows.append((f"table3/{device}/swan_pct", us,
                     f"{swan:.1f}(paper {ps});migrations={len(ctl.migrations)}"))
        assert swan >= base, f"Swan must not be worse than baseline on {device}"
    t0 = time.perf_counter()
    payload, res_a, res_s = adaptive_vs_static()
    us = (time.perf_counter() - t0) * 1e6
    n_mig = len(res_a.timeline.migrations)
    rows.append(("table3/engine/adaptive_vs_static_speedup", us,
                 f"{payload['speedup']:.2f}x;migrations={n_mig};"
                 f"timeline=BENCH_table3_timeline.json"))
    assert payload["speedup"] >= 1.0, \
        "adaptive engine must not be slower than static under interference"
    return rows
