"""Paper Table 3: foreground-experience impact of background training.

PCMark-analogue: a foreground app needs the big cores; its score drops by the
fraction of its compute the background trainer steals. The baseline trains
statically on all big cores; Swan's controller infers the interference from
its own slowed steps and migrates down the pruned ladder, relinquishing the
contended cores (paper Fig. 4b loop).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import energy as E
from repro.core.planner import explore_soc
from repro.core.profiler import greedy_baseline_profile

FOREGROUND_CORES = 2  # typical app uses 1-2 threads (paper §3.2, [27])


def _contention(train_cores, model) -> float:
    """Fraction of the foreground app's big-core demand stolen by training."""
    classes = model.classes()
    fast = set(classes.get("big", ()) + classes.get("prime", ()))
    stolen = len(fast & set(train_cores))
    free_fast = len(fast) - stolen
    deficit = max(0, FOREGROUND_CORES - free_fast)
    return deficit / FOREGROUND_CORES


def score_impact(device: str, workload: str = "resnet34", steps: int = 60):
    model = E.SOC_MODELS[device]
    # baseline: static greedy choice, never moves
    base_choice = greedy_baseline_profile(model, workload).choice
    base_impact = _contention(base_choice.cores, model)
    # swan: controller observes inflated latency while foreground runs
    plan = explore_soc(device, workload)
    ctl = plan.controller(upgrade_patience=10)
    impacts = []
    for step in range(steps):
        cont = _contention(ctl.active.choice.cores, model)
        # foreground active the whole benchmark -> training is slowed by
        # sharing, which is exactly the signal Swan can see without root
        observed = ctl.active.latency_s * (1.0 + 1.5 * cont)
        ctl.observe_step(observed)
        impacts.append(cont)
    swan_impact = float(np.mean(impacts[10:]))  # steady state after migration
    return -100 * 0.4 * base_impact, -100 * 0.4 * swan_impact, ctl


def run():
    rows = []
    paper = {"tab_s6": (-10.2, -5.8), "oneplus8": (-12.5, 0.0),
             "pixel3": (-27.0, -3.1), "s10e": (-11.2, 0.0)}
    for device in ("tab_s6", "oneplus8", "pixel3", "s10e"):
        t0 = time.perf_counter()
        base, swan, ctl = score_impact(device)
        us = (time.perf_counter() - t0) * 1e6
        pb, ps = paper[device]
        rows.append((f"table3/{device}/baseline_pct", us, f"{base:.1f}(paper {pb})"))
        rows.append((f"table3/{device}/swan_pct", us,
                     f"{swan:.1f}(paper {ps});migrations={len(ctl.migrations)}"))
        assert swan >= base, f"Swan must not be worse than baseline on {device}"
    return rows
