"""Paged-vs-contiguous KV cache benchmark under a mixed request trace.

Runs the same mixed short/long request stream through the continuous-
batching engine twice — ``kv_layout="contig"`` (per-slot (max_seq,) slabs)
and ``kv_layout="paged"`` (block-pooled cache + block tables) — at equal
batch size, and reports:

  - tokens/sec for each layout (same jitted decode shape count, so the
    comparison is honest per backend);
  - peak KV bytes: the contiguous slab is fully resident by construction,
    while the paged figure is the pool's high-water mark of blocks in use —
    the quantity a block-granular allocator actually has to back. The
    mixed trace is mostly short requests, exactly the traffic where slabs
    over-provision (ISSUE acceptance: >= 2x reduction).

The correctness gate is token parity: greedy decoding must produce
identical streams per request uid under both layouts (and CI fails the job
otherwise). Writes ``BENCH_paged.json``.

Usage:
  PYTHONPATH=src python benchmarks/paged_bench.py [--out BENCH_paged.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

LAYOUTS = ("contig", "paged")


def _mixed_requests(rng, n_short: int, n_long: int):
    """Mostly-short traffic with a long tail: the regime where per-slot
    max_seq slabs over-provision hardest."""
    from repro.launch.serve import Request
    reqs = []
    uid = 0
    for _ in range(n_short):
        p = int(rng.integers(6, 18))
        reqs.append(Request(uid=uid, prompt=rng.integers(0, 64, p).astype(np.int32),
                            max_new_tokens=int(rng.integers(4, 10))))
        uid += 1
    for _ in range(n_long):
        reqs.append(Request(uid=uid,
                            prompt=rng.integers(0, 64, 96).astype(np.int32),
                            max_new_tokens=24))
        uid += 1
    rng.shuffle(reqs)
    return reqs


def bench_paged(arch: str = "llama3.2-1b", *, batch: int = 4,
                max_seq: int = 256, block_size: int = 16,
                impl: str = "naive", seed: int = 0):
    """One paged-vs-contig cell; returns the records for both layouts."""
    from repro.configs import get_config
    from repro.launch.serve import ContinuousBatchingEngine
    from repro.models import build_model

    cfg = get_config(arch).reduced()
    model = build_model(cfg, impl=impl)
    params = model.init(jax.random.PRNGKey(0))

    results, tokens = [], {}
    for layout in LAYOUTS:
        rng = np.random.default_rng(seed)
        reqs = _mixed_requests(rng, n_short=3 * batch - 2, n_long=2)
        engine = ContinuousBatchingEngine(
            model, params, max_batch=batch, max_seq=max_seq,
            kv_layout=layout, block_size=block_size)
        t0 = time.perf_counter()
        finished = engine.run(reqs)
        dt = time.perf_counter() - t0
        tokens[layout] = {u: f.tokens for u, f in finished.items()}
        stats = engine.stats()
        rec = {
            "bench": "paged_serve", "shape": arch, "impl": impl,
            "kv_layout": layout, "slots": batch, "max_seq": max_seq,
            "block_size": block_size, "requests": len(reqs),
            "tokens": engine.tokens_out, "steps": engine.decode_steps,
            "occupancy": stats["occupancy"],
            "wall_s": round(dt, 4),
            "tok_s": round(engine.tokens_out / max(dt, 1e-9), 1),
            "peak_kv_bytes": engine.kv_bytes(peak=True),
            "status": "ok",
        }
        if layout == "paged":
            rec["pool"] = stats["pool"]
        results.append(rec)

    parity = tokens["contig"] == tokens["paged"]
    contig_b = results[0]["peak_kv_bytes"]
    paged_b = max(results[1]["peak_kv_bytes"], 1)
    reduction = contig_b / paged_b
    for rec in results:
        rec["token_parity"] = parity
        rec["kv_bytes_reduction"] = round(reduction, 2)
        if not parity:
            rec["status"] = "error: paged/contig token mismatch"
    return results


def run(fast: bool = True):
    """Harness entry (benchmarks/run.py): yields (name, us, derived) rows;
    raises after the good rows if the parity gate fails so a broken paged
    path lands in the failure accounting."""
    del fast
    bad = []
    for rec in bench_paged():
        name = f"paged_{rec['shape']}_{rec['kv_layout']}"
        yield (name, rec["wall_s"] * 1e6,
               f"tok_s={rec['tok_s']} peak_kv_bytes={rec['peak_kv_bytes']} "
               f"reduction={rec['kv_bytes_reduction']}x")
        if rec["status"] != "ok":
            bad.append(f"{name}: {rec['status']}")
    if bad:
        raise RuntimeError("paged bench failures: " + "; ".join(sorted(set(bad))))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--impl", default="naive", choices=("naive", "pallas"))
    ap.add_argument("--out", default="BENCH_paged.json")
    args = ap.parse_args()

    results = bench_paged(args.arch, batch=args.batch, max_seq=args.max_seq,
                          block_size=args.block_size, impl=args.impl)
    print("name,us_per_call,derived")
    for rec in results:
        print(f"paged_{rec['shape']}_{rec['kv_layout']},"
              f"{rec['wall_s'] * 1e6:.0f},"
              f"tok_s={rec['tok_s']} peak_kv_bytes={rec['peak_kv_bytes']}")

    reduction = results[0]["kv_bytes_reduction"]
    parity = results[0]["token_parity"]
    # memory gate: the paged layout must at least halve peak KV bytes on the
    # mixed trace (ISSUE acceptance); parity is the hard correctness gate
    payload = {
        "backend": jax.default_backend(),
        "interpret": jax.default_backend() != "tpu",
        "token_parity": parity,
        "kv_bytes_reduction": reduction,
        "memory_gate_2x": bool(reduction >= 2.0),
        "results": results,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {args.out} (reduction={reduction}x parity={parity})",
          file=sys.stderr)
    return 0 if (parity and reduction >= 2.0) else 1


if __name__ == "__main__":
    raise SystemExit(main())
