"""Speculative-decoding benchmark: draft depth vs greedy serve baseline.

Runs the continuous-batching engine over the same request stream with
speculation off (the baseline) and at several draft depths (n-gram
self-drafting), and measures tokens/sec, draft acceptance, and the
*effective speedup* — emitted tokens per verify pass, ``1 + depth *
acceptance``. Decode is KV-bandwidth bound, so a k+1-token verify pass
costs roughly one single-token decode step on a real accelerator and the
effective speedup IS the tokens/sec model the serve loop realizes there;
wall-clock tokens/sec is also recorded, but on CPU every pass is
overhead-bound and the wall-clock ratio is only claimable on an
accelerator backend (same convention as ``decode_bench``).

Three gates, enforced in CI:

- **parity** (greedy): every speculative depth must emit token-identical
  streams to the non-speculative baseline, contig and paged;
- **speedup**: effective speedup must exceed 1.5x at some benched depth
  (wall-clock tokens/sec must exceed 1.5x where claimable);
- **drift** (sampled): speculative sampling may reorder randomness, so
  streams differ token-for-token — but per-request lengths must match
  exactly and the emitted unigram distribution must sit within the
  seed-to-seed null drift (TV distance vs a reseeded baseline, x1.25).

Writes ``BENCH_spec.json``; ``--full`` uses longer generations and the
Pallas verify kernels.

Usage:
  PYTHONPATH=src python benchmarks/spec_bench.py [--full] [--out BENCH_spec.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _tiny_cfg():
    from repro.configs.base import ModelConfig
    return ModelConfig(name="spec-bench-tiny", family="dense", n_layers=2,
                       d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                       vocab_size=256, tie_embeddings=True,
                       source="benchmarks/spec_bench.py")


def _prompts(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 64, 8).astype(np.int32) for _ in range(n)]


def _serve(model, params, prompts, gen, *, depth, layout="contig", **kw):
    """One engine pass; returns (streams, wall_s, acceptance, eff_speedup)."""
    from repro.launch.serve import ContinuousBatchingEngine, Request
    engine = ContinuousBatchingEngine(model, params, max_batch=len(prompts),
                                      max_seq=8 + gen + 32, kv_layout=layout,
                                      draft_depth=depth, **kw)
    t0 = time.perf_counter()
    fin = engine.run([Request(i, p.copy(), gen)
                      for i, p in enumerate(prompts)])
    wall = time.perf_counter() - t0
    streams = {u: f.tokens for u, f in fin.items()}
    acc = engine.spec_accepted / max(engine.spec_drafted, 1)
    slot_rounds = engine.spec_drafted / depth if depth else 0
    eff = 1.0 + (engine.spec_accepted / slot_rounds if slot_rounds else 0.0)
    return streams, wall, acc, eff


def bench_spec(full: bool):
    from repro.models.registry import build_model
    impl = "pallas" if full else "naive"
    gen = 192 if full else 128
    slots = 4
    depths = (2, 4, 6) if full else (2, 4)

    cfg = _tiny_cfg()
    model = build_model(cfg, impl=impl)
    params = model.init(jax.random.PRNGKey(0))
    prompts = _prompts(slots)
    ntok = slots * gen

    results = []
    base, base_wall, _, _ = _serve(model, params, prompts, gen, depth=0)
    base_tps = ntok / base_wall
    results.append({"bench": "spec", "name": "greedy_base", "depth": 0,
                    "layout": "contig", "us_per_req_tok": round(
                        base_wall / ntok * 1e6, 1),
                    "tok_s": round(base_tps, 1), "status": "ok"})

    # greedy: token parity + speedup at each depth (contig)
    for d in depths:
        got, wall, acc, eff = _serve(model, params, prompts, gen, depth=d)
        results.append({
            "bench": "spec", "name": f"greedy_k{d}", "depth": d,
            "layout": "contig",
            "us_per_req_tok": round(wall / ntok * 1e6, 1),
            "tok_s": round(ntok / wall, 1),
            "acceptance": round(acc, 3),
            "eff_speedup": round(eff, 3),
            "wall_speedup": round(base_tps and (ntok / wall) / base_tps, 3),
            "parity": bool(got == base),
            "status": "ok" if got == base else "error: token mismatch"})

    # greedy paged: parity through the paged verify kernel
    got, wall, acc, eff = _serve(model, params, prompts, gen, depth=4,
                                 layout="paged")
    results.append({
        "bench": "spec", "name": "greedy_k4_paged", "depth": 4,
        "layout": "paged", "us_per_req_tok": round(wall / ntok * 1e6, 1),
        "tok_s": round(ntok / wall, 1), "acceptance": round(acc, 3),
        "eff_speedup": round(eff, 3), "parity": bool(got == base),
        "status": "ok" if got == base else "error: token mismatch"})

    # sampled: exact length parity + unigram drift bounded by the
    # seed-to-seed null (speculation must not drift the distribution more
    # than reseeding the baseline does)
    kw = dict(temperature=0.9, top_k=32)
    sb0, _, _, _ = _serve(model, params, prompts, gen, depth=0, **kw)
    sb1, _, _, _ = _serve(model, params, prompts, gen, depth=0,
                          sample_seed=1, **kw)
    sp, wall, acc, eff = _serve(model, params, prompts, gen, depth=4, **kw)

    def unigram(streams):
        h = np.bincount(np.concatenate([np.asarray(t) for t in
                                        streams.values()]),
                        minlength=cfg.vocab_size).astype(np.float64)
        return h / h.sum()

    def tv(a, b):
        return float(0.5 * np.abs(unigram(a) - unigram(b)).sum())

    null_tv, spec_tv = tv(sb0, sb1), tv(sb0, sp)
    lens_ok = {u: len(t) for u, t in sp.items()} == \
        {u: len(t) for u, t in sb0.items()}
    drift_ok = lens_ok and spec_tv <= null_tv * 1.25
    results.append({
        "bench": "spec", "name": "sampled_k4", "depth": 4, "layout": "contig",
        "us_per_req_tok": round(wall / ntok * 1e6, 1),
        "tok_s": round(ntok / wall, 1), "acceptance": round(acc, 3),
        "eff_speedup": round(eff, 3), "length_parity": lens_ok,
        "drift_tv": round(spec_tv, 4), "null_tv": round(null_tv, 4),
        "status": "ok" if drift_ok else
        f"error: drift tv={spec_tv:.3f} > null {null_tv:.3f} * 1.25"})
    return results


def _gates(results):
    """(parity_ok, speedup_ok, drift_ok, wall_gate) from bench rows."""
    greedy = [r for r in results if r["name"].startswith("greedy_k")]
    parity_ok = bool(greedy) and all(r.get("parity") for r in greedy)
    speedup_ok = any(r.get("eff_speedup", 0) > 1.5 for r in greedy)
    sampled = [r for r in results if r["name"].startswith("sampled")]
    drift_ok = all(r["status"] == "ok" for r in sampled)
    # wall-clock 1.5x is only claimable on an accelerator backend, where a
    # verify pass really does cost ~one bandwidth-bound decode step
    wall = None if jax.default_backend() == "cpu" else \
        any(r.get("wall_speedup", 0) > 1.5 for r in greedy)
    return parity_ok, speedup_ok, drift_ok, wall


def run(fast: bool = True):
    """Harness entry (benchmarks/run.py): yields (name, us, derived) rows.

    Raises after yielding if the parity, speedup, or drift gate fails so a
    regressed speculative path lands in the harness failure accounting."""
    results = bench_spec(full=not fast)
    for r in results:
        extra = f"tok_s={r['tok_s']}"
        if "eff_speedup" in r:
            extra += (f";acc={r['acceptance']};eff_x={r['eff_speedup']}"
                      f";wall_x={r.get('wall_speedup', '-')}")
        if "drift_tv" in r:
            extra += f";tv={r['drift_tv']}/null={r['null_tv']}"
        yield f"spec_{r['name']}_{r['layout']}", r["us_per_req_tok"], extra
    parity_ok, speedup_ok, drift_ok, _ = _gates(results)
    bad = [r["name"] + ": " + r["status"]
           for r in results if r["status"] != "ok"]
    if not parity_ok:
        bad.append("greedy speculative streams not token-identical")
    if not speedup_ok:
        bad.append("no benched depth clears 1.5x effective speedup")
    if not drift_ok:
        bad.append("sampled drift exceeds the seed-to-seed null bound")
    if bad:
        raise RuntimeError("spec bench failures: " + "; ".join(sorted(set(bad))))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="longer generations + pallas verify kernels")
    ap.add_argument("--out", default="BENCH_spec.json")
    args = ap.parse_args()

    results = bench_spec(args.full)
    print("name,us_per_call,derived")
    for r in results:
        print(f"spec_{r['name']}_{r['layout']},{r['us_per_req_tok']},"
              f"tok_s={r['tok_s']};status={r['status']}")

    parity_ok, speedup_ok, drift_ok, wall = _gates(results)
    payload = {"mode": "full" if args.full else "ci",
               "backend": jax.default_backend(),
               "gate_greedy_token_parity": parity_ok,
               "gate_eff_speedup_1p5x": speedup_ok,
               "gate_sampled_drift_bounded": drift_ok,
               "gate_wall_speedup_1p5x": wall,
               "results": results}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {args.out} ({len(results)} records)", file=sys.stderr)
    return 0 if (parity_ok and speedup_ok and drift_ok) else 1


if __name__ == "__main__":
    raise SystemExit(main())
