"""Fleet robustness bench: 2400 trace-driven SoCs through SwanRuntime.

Drives the full quality-filtered GreenHub-style trace set (100 base traces x
24 timezone shifts) through the fleet coordinator under a seeded fleet fault
schedule (client churn incl. one >=30%-churn round, dropped / duplicated /
corrupted update delivery) and compares policies:

- ``swan``       — per-device Swan plans + runtime arbitration (thermal,
                   energy loan, foreground preemption, adaptive rungs).
- ``baseline``   — the PyTorch-greedy single execution choice, same traces,
                   same chaos schedule.
- ``swan_crash`` — the swan run with a coordinator crash injected
                   mid-aggregation, then resumed from durable state.

Gates (CI):
- swan goodput (useful samples per fleet-hour) >= baseline goodput under the
  chaos-enabled trace;
- the crash-resumed run is *bitwise* identical to the crash-free run: every
  round's aggregate CRC and accepted-client set match (zero lost, zero
  double-counted updates);
- every round with >=30% injected churn still completes within its
  deadline + stale window with a nonzero accepted set;
- every fleet fault class was actually applied;
- same seed => identical round log (the bench is deterministic end to end).

Writes BENCH_fleet.json: goodput / time-to-accuracy / SLO attainment /
energy, broken down by device class, charge state at acceptance, and the
diurnal online-population curve.
"""
from __future__ import annotations

import dataclasses
import json
import tempfile
import time

SEED = 11
HEAVY_CHURN = 0.35
HEAVY_ROUND = 4
CRASH_AT = (2, 5)  # round 2, after 5 accepted updates


def _chaos(crash_at=None):
    from repro.engine.chaos import FleetChaos
    return FleetChaos(seed=SEED, churn_prob=0.10,
                      churn_rounds={HEAVY_ROUND: HEAVY_CHURN},
                      drop_prob=0.05, dup_prob=0.05, corrupt_prob=0.05,
                      crash_at=crash_at)


def _round_log(result):
    return [dataclasses.asdict(r) for r in result.rounds]


def run(fast: bool = True, json_path: str = "BENCH_fleet.json"):
    from repro.engine.chaos import FLEET_KINDS
    from repro.fl.traces import make_client_traces
    from repro.fleet import (CoordinatorCrash, FleetConfig, FleetCoordinator,
                             build_fleet_clients)

    t0 = time.perf_counter()
    cfg = FleetConfig(n_clients=2400,
                      clients_per_round=25 if fast else 50,
                      rounds=8 if fast else 20, seed=SEED)
    traces = make_client_traces(100, seed=SEED, tz_shifts=24)

    def run_one(policy, chaos, crash=False):
        c = dataclasses.replace(cfg, policy=policy)
        clients = build_fleet_clients(c, traces=traces)
        with tempfile.TemporaryDirectory() as d:
            coord = FleetCoordinator(clients, c, state_dir=d, chaos=chaos)
            if not crash:
                return coord.run(), chaos
            try:
                coord.run()
                raise AssertionError("injected coordinator crash never fired")
            except CoordinatorCrash:
                pass
            resumed = FleetCoordinator.resume(clients, c, state_dir=d,
                                              chaos=chaos)
            return resumed.run(), chaos

    swan, swan_chaos = run_one("swan", _chaos())
    swan2, _ = run_one("swan", _chaos())  # determinism probe
    base, base_chaos = run_one("baseline", _chaos())
    crashed, crash_chaos = run_one("swan", _chaos(crash_at=CRASH_AT),
                                   crash=True)
    us = (time.perf_counter() - t0) * 1e6

    # -- gates ---------------------------------------------------------------
    goodput_speedup = swan.goodput_samples_per_h / \
        max(base.goodput_samples_per_h, 1e-9)
    assert goodput_speedup >= 1.0, \
        f"swan goodput below baseline under chaos: {goodput_speedup:.3f}x"

    assert _round_log(swan) == _round_log(swan2), \
        "same seed produced different round logs (non-deterministic fleet)"

    crash_parity = (
        [r.agg_crc for r in swan.rounds] == [r.agg_crc for r in
                                             crashed.rounds]
        and [r.accepted_cids for r in swan.rounds]
        == [r.accepted_cids for r in crashed.rounds])
    assert crash_parity, \
        "crash-resumed aggregation lost or double-counted accepted updates"
    assert "coordinator_crash" in crash_chaos.applied, \
        "the coordinator crash was never injected"

    churn_rounds = [r for r in swan.rounds
                    if swan_chaos.churn_fraction(r.rnd) >= 0.30]
    assert churn_rounds, "no >=30%-churn round in the schedule"
    for r in churn_rounds:
        window = r.deadline_s * (1.0 + cfg.stale_frac)
        assert r.accepted > 0, \
            f"heavy-churn round {r.rnd} accepted nothing"
        assert r.round_s <= window + 1e-9, \
            f"heavy-churn round {r.rnd} blew its window: " \
            f"{r.round_s:.1f}s > {window:.1f}s"

    applied = set(swan_chaos.applied) | set(base_chaos.applied) \
        | set(crash_chaos.applied)
    missing = set(FLEET_KINDS) - applied
    assert not missing, f"fleet fault classes never applied: {sorted(missing)}"

    # -- derived metrics -----------------------------------------------------
    target = 0.95 * min(swan.final_accuracy, base.final_accuracy)
    tta_swan = swan.time_to_accuracy(target)
    tta_base = base.time_to_accuracy(target)
    tta_speedup = (tta_base / tta_swan) \
        if tta_swan and tta_base and tta_swan > 0 else None
    energy_ratio = base.total_energy_j / max(swan.total_energy_j, 1e-9)
    payload = {
        "config": dataclasses.asdict(cfg),
        "chaos": swan_chaos.to_json(),
        "gates": {
            "goodput_speedup": round(goodput_speedup, 3),
            "crash_parity_bitwise": crash_parity,
            "deterministic": True,
            "heavy_churn_rounds_completed": [r.rnd for r in churn_rounds],
            "fault_kinds_applied": sorted(applied),
        },
        "macro": {
            "goodput_speedup": round(goodput_speedup, 3),
            "tta_speedup": round(tta_speedup, 3) if tta_speedup else None,
            "energy_ratio": round(energy_ratio, 3),
            "paper_band": [1.2, 23.3],
            "in_paper_band": bool(1.2 <= goodput_speedup <= 23.3),
        },
        "diurnal_online": [[r.rnd, r.t_min, r.online] for r in swan.rounds],
        "scenarios": {
            "swan": swan.to_json(),
            "baseline": base.to_json(),
            "swan_crash": crashed.to_json(),
        },
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)

    rows = []
    for name, res in (("swan", swan), ("baseline", base),
                      ("swan_crash", crashed)):
        rows.append((
            f"fleet/{name}/goodput", us,
            f"{res.goodput_samples_per_h:.0f}samples/h;"
            f"slo={res.slo_attainment:.3f};"
            f"energy={res.total_energy_j:.0f}J;"
            f"acc={res.final_accuracy:.5f}"))
    rows.append(("fleet/goodput_speedup", us, f"{goodput_speedup:.2f}x"))
    rows.append(("fleet/crash_parity", us, f"bitwise={crash_parity}"))
    rows.append(("fleet/heavy_churn", us,
                 ";".join(f"r{r.rnd}:acc={r.accepted}/short={r.shortfall}"
                          for r in churn_rounds)))
    rows.append(("fleet/faults_applied", us, "+".join(sorted(applied))))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args()
    for name, us, derived in run(fast=not args.full, json_path=args.out):
        print(f"{name},{us:.1f},{derived}")
