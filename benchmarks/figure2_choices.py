"""Paper Fig. 2: latency/energy/power per core-combination (Pixel 3).

Reproduces the two headline observations:
  O1 - lowest power is NOT lowest energy (little cores lose on energy);
  O2 - ShuffleNet: more cores can be slower (depthwise cache-thrash), so the
       fastest choice is a single big core and pruning collapses the ladder.
"""
from __future__ import annotations

import time

from repro.core import energy as E
from repro.core.choices import enumerate_core_choices
from repro.core.planner import explore_soc
from repro.core.profiler import profile_soc_choice


def run():
    rows = []
    t0 = time.perf_counter()
    model = E.SOC_MODELS["pixel3"]
    for workload in ("resnet34", "shufflenet-v2"):
        for choice in enumerate_core_choices(model):
            p = profile_soc_choice(choice, model, workload)
            rows.append((f"fig2/pixel3/{workload}/{p.name}", p.latency_s * 1e6,
                         f"E={p.energy_j:.2f}J;P={p.power_w:.2f}W"))
        plan = explore_soc("pixel3", workload)
        rows.append((f"fig2/pixel3/{workload}/pruned_ladder",
                     (time.perf_counter() - t0) * 1e6,
                     ">".join(pr.name for pr in plan.ladder)))
    # assertions of the two observations (fail loudly if the model regresses)
    from repro.core.choices import CoreChoice
    little = profile_soc_choice(CoreChoice((0, 1, 2, 3), "pixel3"), model, "resnet34")
    big1 = profile_soc_choice(CoreChoice((4,), "pixel3"), model, "resnet34")
    assert little.power_w < big1.power_w and little.energy_j > big1.energy_j, "O1 regressed"
    all_big = profile_soc_choice(CoreChoice((4, 5, 6, 7), "pixel3"), model, "shufflenet-v2")
    assert big1_shuffle_faster(model), "O2 regressed"
    return rows


def big1_shuffle_faster(model):
    from repro.core.choices import CoreChoice
    one = profile_soc_choice(CoreChoice((4,), "pixel3"), model, "shufflenet-v2")
    four = profile_soc_choice(CoreChoice((4, 5, 6, 7), "pixel3"), model, "shufflenet-v2")
    return one.latency_s < four.latency_s
