"""Attention-kernel benchmark: fwd and fwd+bwd across impls and paper shapes.

Times ``attention_impl`` for every ``impl`` in {naive, chunked, pallas} at the
head geometry of the assigned paper configs, both forward-only and through
``jax.grad`` (the training hot path this PR makes first-class). Writes
``BENCH_kernels.json`` so the perf trajectory is tracked per-PR, and prints
the same ``name,us_per_call,derived`` CSV the rest of the harness uses.

CI mode (default) runs reduced sequence lengths so the interpret-mode Pallas
path finishes in seconds; ``--full`` uses the train_4k-scale sequences and is
only meaningful on a real accelerator.

Usage:
  PYTHONPATH=src python benchmarks/kernel_bench.py [--full] [--out BENCH_kernels.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

IMPLS = ("naive", "chunked", "pallas")


def _shapes(full: bool):
    """(name, B, Sq, H, K, hd) derived from paper-config head geometry."""
    from repro.configs import get_config
    seq = 1024 if full else 128
    batch = 2 if full else 1
    out = []
    for arch in ("llama3.2-1b", "granite-3-2b", "command-r-35b", "whisper-small"):
        cfg = get_config(arch)
        out.append((arch, batch, seq, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim))
    return out


def _time(fn, *args, iters: int, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_attention(full: bool, iters: int):
    from repro.models.attention import attention_impl
    results = []
    for name, B, S, H, K, hd in _shapes(full):
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (B, S, H, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, hd))
        for impl in IMPLS:
            chunk = min(1024, S)

            @jax.jit
            def fwd(q, k, v, impl=impl, chunk=chunk):
                return attention_impl(q, k, v, causal=True, impl=impl, chunk=chunk)

            @jax.jit
            def fwdbwd(q, k, v, impl=impl, chunk=chunk):
                def loss(q, k, v):
                    return attention_impl(q, k, v, causal=True, impl=impl,
                                          chunk=chunk).sum()
                return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)

            rec = {"bench": "attention", "shape": name, "impl": impl,
                   "B": B, "S": S, "H": H, "K": K, "hd": hd}
            try:
                rec["fwd_us"] = round(_time(fwd, q, k, v, iters=iters), 1)
                rec["fwdbwd_us"] = round(_time(fwdbwd, q, k, v, iters=iters), 1)
                rec["status"] = "ok"
            except Exception as e:  # an impl that can't run here is recorded, not fatal
                rec["status"] = f"error: {type(e).__name__}: {e}"
            results.append(rec)
    return results


def bench_rmsnorm(full: bool, iters: int):
    from repro.kernels import ops, ref
    rows = 4096 if full else 512
    d = 2048 if full else 512
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (rows, d))
    s = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    results = []
    for impl, fn in (("pallas", ops.rmsnorm), ("jnp", ref.ref_rmsnorm)):
        fwd = jax.jit(fn)
        fwdbwd = jax.jit(jax.grad(lambda x, s: fn(x, s).sum(), argnums=(0, 1)))
        rec = {"bench": "rmsnorm", "shape": f"{rows}x{d}", "impl": impl}
        try:
            rec["fwd_us"] = round(_time(fwd, x, s, iters=iters), 1)
            rec["fwdbwd_us"] = round(_time(fwdbwd, x, s, iters=iters), 1)
            rec["status"] = "ok"
        except Exception as e:
            rec["status"] = f"error: {type(e).__name__}: {e}"
        results.append(rec)
    return results


def run(fast: bool = True):
    """Harness entry (benchmarks/run.py): yields (name, us, derived) rows.

    Raises after yielding the good rows if any impl errored, so a broken
    kernel path lands in the harness's failure accounting instead of
    silently shrinking the row count.
    """
    bad = []
    for rec in bench_attention(full=not fast, iters=2 if fast else 5):
        if rec["status"] == "ok":
            yield (f"kernel_attn_{rec['shape']}_{rec['impl']}_fwd",
                   rec["fwd_us"], f"S={rec['S']}")
            yield (f"kernel_attn_{rec['shape']}_{rec['impl']}_fwdbwd",
                   rec["fwdbwd_us"], f"S={rec['S']}")
        else:
            bad.append(f"{rec['shape']}/{rec['impl']}: {rec['status']}")
    if bad:
        raise RuntimeError("kernel bench failures: " + "; ".join(bad))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="train-scale sequences (accelerator only)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args()
    iters = args.iters or (5 if args.full else 2)

    results = bench_attention(args.full, iters) + bench_rmsnorm(args.full, iters)

    print("name,us_per_call,derived")
    for rec in results:
        if rec["status"] != "ok":
            print(f"{rec['bench']}_{rec['shape']}_{rec['impl']},0,{rec['status']}")
            continue
        for phase in ("fwd", "fwdbwd"):
            print(f"{rec['bench']}_{rec['shape']}_{rec['impl']}_{phase},"
                  f"{rec[f'{phase}_us']},")

    payload = {"mode": "full" if args.full else "ci",
               "backend": jax.default_backend(),
               "iters": iters, "results": results}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {args.out} ({len(results)} records)", file=sys.stderr)
    bad = [r for r in results if r["status"] != "ok"]
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
