"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV and writes the same rows as
machine-readable JSON (``BENCH_run.json`` by default) so per-PR perf
trajectories can be diffed without parsing stdout. ``--fast`` (default) uses
reduced cohort sizes; ``--full`` runs the 2400-client FL simulation.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names "
                         "(fig1b,fig2,table2,table3,table4,kernels,decode,"
                         "paged,prefix,spec,arbitration,chaos,fleet,obs)")
    ap.add_argument("--json-out", default="BENCH_run.json",
                    help="machine-readable results path ('' disables)")
    args = ap.parse_args()

    from benchmarks import (arbitration_bench, chaos_bench, decode_bench,
                            figure1b_matmul, figure2_choices, fleet_bench,
                            kernel_bench, obs_bench, paged_bench,
                            prefix_bench, spec_bench, table2_local,
                            table3_interference, table4_fl)
    benches = {
        "fig1b": figure1b_matmul.run,
        "fig2": figure2_choices.run,
        "table2": table2_local.run,
        "table3": table3_interference.run,
        "table4": lambda: table4_fl.run(fast=not args.full),
        "kernels": lambda: kernel_bench.run(fast=not args.full),
        "decode": lambda: decode_bench.run(fast=not args.full),
        "paged": lambda: paged_bench.run(fast=not args.full),
        "prefix": lambda: prefix_bench.run(fast=not args.full),
        "spec": lambda: spec_bench.run(fast=not args.full),
        "arbitration": lambda: arbitration_bench.run(fast=not args.full),
        "chaos": lambda: chaos_bench.run(fast=not args.full),
        "fleet": lambda: fleet_bench.run(fast=not args.full),
        "obs": lambda: obs_bench.run(fast=not args.full),
    }
    if args.only:
        keep = set(args.only.split(","))
        benches = {k: v for k, v in benches.items() if k in keep}

    print("name,us_per_call,derived")
    rows = []
    failures = []
    for name, fn in benches.items():
        try:
            for row_name, us, derived in fn():
                print(f"{row_name},{us:.1f},{derived}")
                rows.append({"bench": name, "name": row_name,
                             "us_per_call": round(float(us), 1),
                             "derived": str(derived)})
        except Exception as e:
            traceback.print_exc()
            print(f"{name},0,FAILED:{type(e).__name__}:{e}")
            failures.append({"bench": name,
                             "error": f"{type(e).__name__}: {e}"})
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump({"rows": rows, "failures": failures}, f, indent=1)
        print(f"# wrote {args.json_out} ({len(rows)} rows, "
              f"{len(failures)} failures)", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
