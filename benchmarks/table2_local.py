"""Paper Table 2: local speedup + energy efficiency of Swan vs the PyTorch-
greedy baseline, per (device x model). Paper values inlined for comparison."""
from __future__ import annotations

import time

from repro.core import energy as E
from repro.core.planner import explore_soc
from repro.core.profiler import greedy_baseline_profile

PAPER = {  # (speedup, energy_eff) per (workload, device)
    ("resnet34", "tab_s6"): (1.9, 1.9), ("resnet34", "oneplus8"): (2.1, 2.4),
    ("resnet34", "pixel3"): (1.0, 1.0), ("resnet34", "s10e"): (1.9, 2.1),
    ("resnet34", "mi10"): (2.1, 2.2),
    ("shufflenet-v2", "tab_s6"): (21, 12.2), ("shufflenet-v2", "oneplus8"): (17, 8.5),
    ("shufflenet-v2", "pixel3"): (1.8, 1.8), ("shufflenet-v2", "s10e"): (39, 39),
    ("shufflenet-v2", "mi10"): (17.2, 7.8),
    ("mobilenet-v2", "tab_s6"): (14.5, 9.4), ("mobilenet-v2", "oneplus8"): (13.9, 7.5),
    ("mobilenet-v2", "pixel3"): (1.6, 2.3), ("mobilenet-v2", "s10e"): (31.8, 17.4),
    ("mobilenet-v2", "mi10"): (14, 5.8),
}


def run():
    rows = []
    for (wl, dev), (psp, pee) in PAPER.items():
        t0 = time.perf_counter()
        plan = explore_soc(dev, wl)
        base = greedy_baseline_profile(E.SOC_MODELS[dev], wl)
        us = (time.perf_counter() - t0) * 1e6
        sp = base.latency_s / plan.selected.latency_s
        ee = base.energy_j / plan.selected.energy_j
        rows.append((f"table2/{dev}/{wl}/speedup", us,
                     f"{sp:.1f}x(paper {psp}x);best={plan.selected.name}"))
        rows.append((f"table2/{dev}/{wl}/energy_eff", us, f"{ee:.1f}x(paper {pee}x)"))
        assert sp >= 0.99, f"Swan slower than baseline on {dev}/{wl}"
    return rows
