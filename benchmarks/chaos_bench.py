"""Chaos robustness bench: SLO attainment under injected faults.

Runs the same train + serve + foreground workload on one SwanRuntime three
times over a shared, seeded chaos schedule (engine/chaos.py — device loss,
KV-pool pressure, torn checkpoints, thermal spikes, latency spikes,
foreground bursts):

- ``faultfree``        — no chaos; the parity and attainment baseline.
- ``chaos_serialize``  — faults on, the engine's old implicit admission
                         behavior (head-of-line requests wait out pool
                         pressure; nothing is ever refused).
- ``chaos_shed``       — same faults, ``admission_policy="shed"``: requests
                         that cannot get KV blocks now are rejected with a
                         retry-after hint, so the requests that ARE admitted
                         keep their token latency.

Observed serve latency is modeled deterministically as
``rung estimate x thermal trace x chaos spike x (1 + c·queue_depth)`` — the
queue term is what load shedding buys back.

Gates (CI):
- every injected fault class is applied and every run completes inside one
  process (recovery never needs a restart);
- the training step sequence is contiguous in every scenario — pause/resume
  and torn-checkpoint fallback never skip or redo an optimizer step;
- every foreground pause resumes at exactly the pre-pause step;
- finished requests emit byte-identical token streams vs the fault-free run
  (greedy decode parity survives chaos);
- shed-policy SLO attainment >= serialize-policy attainment.

Writes BENCH_slo.json. The scenarios run in a subprocess with 8 forced host
devices so device-loss faults exercise a real remesh.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

SEED = 7
SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
TRAIN_EST = 0.1
SERVE_EST = 0.1
SLO_P99_S = 0.30      # meets quiet traffic; queue growth + spikes break it
QUEUE_COEF = 0.04     # latency penalty per queued request
DEADLINE_STEPS = 30   # queued-admission deadline (engine steps)
N_REQUESTS = 20
GEN_TOKENS = 8


# ---------------------------------------------------------------------------
# inner: the actual scenarios (run under forced 8-device host)
# ---------------------------------------------------------------------------


def _tiny_cfg(name):
    from repro.configs.base import ModelConfig
    return ModelConfig(name=name, family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                       tie_embeddings=True, source="benchmarks/chaos_bench.py")


def _train_job(trace, ticks):
    from repro.engine.jobs import trace_latency_fn
    from repro.engine.rungs import default_rung_ladder
    from repro.engine.session import TrainSession
    from repro.launch.train import make_batch_fn
    from repro.optim.optimizers import sgd
    from repro.runtime.elastic import ElasticController

    cfg = _tiny_cfg("chaos-train-tiny")
    elastic = ElasticController(total_devices=8)
    rungs = default_rung_ladder(batch=8, microbatch=1, attn_impl="naive",
                                include_bf16=False)
    for r in rungs:
        r.latency_estimate_s = TRAIN_EST * r.rel_latency
    ses = TrainSession(cfg, rungs, optimizer=sgd(), lr=0.05,
                       batch_fn=make_batch_fn(cfg, 8, 16), elastic=elastic,
                       latency_fn=trace_latency_fn(trace), adaptive=True,
                       upgrade_patience=4, verbose=False, name="train")
    return ses.bind(ticks), elastic


def _serve_job(trace, chaos, policy):
    import jax
    import numpy as np
    from repro.engine.jobs import ServeJob, ServeRung
    from repro.launch.serve import ContinuousBatchingEngine, Request
    from repro.models.registry import build_model

    cfg = _tiny_cfg("chaos-serve-tiny")
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    slots, block = 4, 4
    # a deliberately tight pool: 4 residents' worst case (4 blocks each:
    # 6-token prompt + 8-token budget) just fits 17 usable blocks, so a
    # chaos hold of a couple of blocks pushes admission into pressure
    engine = ContinuousBatchingEngine(
        model, params, max_batch=slots, max_seq=48, kv_layout="paged",
        block_size=block, num_blocks=18, admission_policy=policy)
    rng = np.random.default_rng(SEED)
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 6).astype(np.int32),
                    max_new_tokens=GEN_TOKENS, deadline_steps=DEADLINE_STEPS)
            for i in range(N_REQUESTS)]

    def lat_fn(step, rung, dt):
        eff = trace.effective_slowdown(step, rung.interference_sensitivity)
        spike = chaos.latency_multiplier(step) if chaos is not None else 1.0
        queue = 1.0 + QUEUE_COEF * len(engine.queue)
        return rung.latency_estimate_s * eff * spike * queue

    rels = (1.0, 1.4, 1.9)
    sens = (1.0, 0.4, 0.16)
    caps = (None, 2, 1)
    rungs = [ServeRung(name=n, slot_cap=c, interference_sensitivity=s,
                       rel_latency=r, latency_estimate_s=SERVE_EST * r)
             for n, c, s, r in zip(("serve-full", "serve-capped",
                                    "serve-lean"), caps, sens, rels)]
    return ServeJob(engine, reqs, rungs=rungs, latency_fn=lat_fn,
                    adaptive=True, upgrade_patience=4, name="serve",
                    slo_p99_s=SLO_P99_S, slo_window=48, slo_min_samples=8)


def _scenario(name, ticks, *, policy, with_chaos):
    from repro.engine.chaos import ChaosInjector
    from repro.engine.events import ThermalTrace
    from repro.engine.jobs import ForegroundAppJob
    from repro.engine.runtime import SwanRuntime

    trace = ThermalTrace(heat_rate=0.03, cool_rate=0.02, slowdown=2.0)
    chaos = ChaosInjector.random(SEED, ticks, events_per_kind=3) \
        if with_chaos else None
    train, elastic = _train_job(trace, ticks)
    serve = _serve_job(trace, chaos, policy)
    # one scripted burst in every scenario so even fault-free exercises the
    # pause -> checkpoint -> resume path; chaos injects extra fg_burst events
    fg = ForegroundAppJob(bursts=[(ticks // 3, ticks // 3 + 4)])
    rt = SwanRuntime([train, serve, fg], trace=trace, elastic=elastic,
                     chaos=chaos)
    res = rt.run(ticks)

    train_steps = [s.step for s in train.timeline.steps]
    pauses = [m.step for m in train.timeline.migrations if m.reason == "pause"]
    resumes = [m.step for m in train.timeline.migrations
               if m.reason == "resume"]
    finished = {int(u): list(f.tokens)
                for u, f in serve.engine.finished.items()}
    stats = serve.engine.stats()
    return {
        "name": name,
        "policy": policy,
        "chaos": chaos.to_json() if chaos is not None else None,
        "preemptions": res.preemptions,
        "train_steps": train_steps,
        "train_final_step": train_steps[-1] + 1 if train_steps else 0,
        "pauses": pauses,
        "resumes": resumes,
        "finished": finished,
        "slo": serve.slo_stats(),
        "shed": stats["shed"],
        "timeouts": stats["timeouts"],
        "rejected": stats["rejected"],
        "migrations": len(res.timeline.migrations),
        "work": {k: round(v, 2) for k, v in res.work.items()},
    }


def _contiguous(steps):
    return all(b - a == 1 for a, b in zip(steps, steps[1:]))


def inner(ticks: int, out_path: str, trace_path: str = "") -> None:
    # telemetry on for the whole chaos run: the exported Chrome trace is the
    # CI artifact that shows ticks/steps/decodes interleaving under faults
    from repro import obs
    tel = obs.enable() if trace_path else None
    scenarios = [
        _scenario("faultfree", ticks, policy="serialize", with_chaos=False),
        _scenario("chaos_serialize", ticks, policy="serialize",
                  with_chaos=True),
        _scenario("chaos_shed", ticks, policy="shed", with_chaos=True),
    ]
    base = scenarios[0]
    payload = {"ticks": ticks, "seed": SEED, "slo_p99_s": SLO_P99_S,
               "scenarios": {}, "gates": {}}
    for sc in scenarios:
        common = sorted(set(sc["finished"]) & set(base["finished"]))
        parity = all(sc["finished"][u] == base["finished"][u]
                     for u in common)
        payload["scenarios"][sc["name"]] = {
            **{k: v for k, v in sc.items()
               if k not in ("train_steps", "finished")},
            "train_contiguous": _contiguous(sc["train_steps"]),
            "resume_exact": sc["resumes"] == sc["pauses"][:len(sc["resumes"])],
            "finished_requests": len(sc["finished"]),
            "parity_common": len(common),
            "token_parity": parity,
        }
    g = payload["gates"]
    chaos_kinds = set()
    for name in ("chaos_serialize", "chaos_shed"):
        chaos_kinds.update(payload["scenarios"][name]
                           .get("chaos", {}).get("applied", []))
    g["all_fault_kinds_applied"] = sorted(chaos_kinds)
    g["train_contiguous"] = all(
        s["train_contiguous"] for s in payload["scenarios"].values())
    g["resume_exact"] = all(
        s["resume_exact"] and s["pauses"]
        for s in payload["scenarios"].values())
    g["token_parity"] = all(
        s["token_parity"] for s in payload["scenarios"].values())
    att = {n: payload["scenarios"][n]["slo"]["attainment"]
           for n in payload["scenarios"]}
    g["attainment"] = att
    g["shed_ge_serialize"] = (
        att["chaos_shed"] is not None and
        att["chaos_serialize"] is not None and
        att["chaos_shed"] >= att["chaos_serialize"])
    g["pressure_exercised"] = \
        payload["scenarios"]["chaos_shed"]["shed"] > 0
    if tel is not None:
        payload["trace_spans"] = len(tel.tracer.spans())
        tel.tracer.save_chrome_trace(trace_path)
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)


# ---------------------------------------------------------------------------
# outer: subprocess driver + CI gates
# ---------------------------------------------------------------------------


def run(fast: bool = True, json_path: str = "BENCH_slo.json",
        trace_path: str = "BENCH_chaos_trace.json"):
    if SRC not in sys.path:  # direct `python benchmarks/chaos_bench.py` runs
        sys.path.insert(0, SRC)
    ticks = 48 if fast else 96
    env = dict(os.environ,
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""),
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    t0 = time.perf_counter()
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--inner",
         "--ticks", str(ticks), "--out", json_path,
         "--trace-out", trace_path],
        env=env, capture_output=True, text=True, timeout=1800)
    us = (time.perf_counter() - t0) * 1e6
    assert proc.returncode == 0, \
        f"chaos scenarios crashed (recovery failed?):\n{proc.stderr[-4000:]}"
    with open(json_path) as f:
        payload = json.load(f)
    g = payload["gates"]

    from repro.engine.chaos import KINDS
    missing = set(KINDS) - set(g["all_fault_kinds_applied"])
    assert not missing, f"fault classes never applied: {sorted(missing)}"
    assert g["train_contiguous"], \
        "training skipped or redid an optimizer step under chaos"
    assert g["resume_exact"], \
        "a foreground pause did not resume at the pre-pause step"
    assert g["token_parity"], \
        "finished requests diverged from the fault-free token streams"
    assert g["pressure_exercised"], \
        "pool pressure never forced a shed — the chaos schedule is toothless"
    assert g["shed_ge_serialize"], \
        f"shed must not lose SLO attainment to serialize: {g['attainment']}"

    rows = []
    for name, sc in payload["scenarios"].items():
        att = sc["slo"]["attainment"]
        rows.append((f"chaos/{name}/slo_attainment", us,
                     f"{att};shed={sc['shed']};timeouts={sc['timeouts']};"
                     f"preemptions={sc['preemptions']}"))
    rows.append(("chaos/faults_applied", us,
                 "+".join(g["all_fault_kinds_applied"])))
    if trace_path and os.path.exists(trace_path):
        with open(trace_path) as f:
            trace = json.load(f)
        n_x = sum(1 for e in trace["traceEvents"] if e.get("ph") == "X")
        assert n_x > 0, "chaos run recorded no spans in the Chrome trace"
        rows.append(("chaos/trace_spans", us, str(n_x)))
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--inner", action="store_true")
    ap.add_argument("--ticks", type=int, default=48)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_slo.json")
    ap.add_argument("--trace-out", default="BENCH_chaos_trace.json",
                    help="Chrome-trace artifact from the instrumented chaos "
                         "run ('' disables)")
    args = ap.parse_args()
    if args.inner:
        inner(args.ticks, args.out, args.trace_out)
    else:
        for name, us, derived in run(fast=not args.full, json_path=args.out,
                                     trace_path=args.trace_out):
            print(f"{name},{us:.1f},{derived}")
