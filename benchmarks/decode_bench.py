"""Decode-attention benchmark: naive oracle vs Pallas single-query kernel.

Sweeps the paper-config head geometries across batch x cache-length grids
and times one decode-attention step (cache already updated; per-sequence
ragged lengths) for ``impl`` in {naive, pallas}. Decode is KV-bandwidth
bound, so the figure of merit is tokens/sec at a given cache length — the
quantity the continuous-batching serve loop maximizes.

Every timed cell also carries a correctness gate: the two impls must agree
on the attention output at f32 (atol 2e-5), and a reduced full-model greedy
decode must be token-identical between ``impl="naive"`` and
``impl="pallas"``. The gate is what CI enforces; the timing columns are
best-effort on CPU, where the Pallas kernel runs in interpret mode and the
naive jnp path is the honest baseline (recorded as ``backend``/``interpret``
in the JSON so per-PR trajectories only compare like with like).

Writes ``BENCH_decode.json``; ``--full`` uses serving-scale cache lengths
(>= 512, the regime the ISSUE acceptance targets) and is only meaningful on
a real accelerator.

Usage:
  PYTHONPATH=src python benchmarks/decode_bench.py [--full] [--out BENCH_decode.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

IMPLS = ("naive", "pallas")


def _shapes(full: bool):
    """(arch, B, cache_len, H, K, hd) cells from paper-config head geometry."""
    from repro.configs import get_config
    # always include the >= 512 regime the acceptance gate targets; --full
    # adds the serving-scale tail only meaningful on a real accelerator
    cache_lens = (512, 2048) if full else (128, 512)
    batches = (4,) if full else (2,)
    out = []
    for arch in ("llama3.2-1b", "granite-3-2b", "command-r-35b"):
        cfg = get_config(arch)
        for B in batches:
            for cl in cache_lens:
                out.append((arch, B, cl, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim))
    return out


def _time(fn, *args, iters: int, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def bench_decode_attention(full: bool, iters: int):
    """Attention-op level: one ragged decode step, naive vs pallas."""
    from repro.kernels import ops as kops
    from repro.models.attention import naive_attention

    results = []
    for arch, B, cache_len, H, K, hd in _shapes(full):
        key = jax.random.PRNGKey(0)
        Smax = cache_len
        q = jax.random.normal(key, (B, 1, H, hd))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, Smax, K, hd))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, Smax, K, hd))
        # ragged: half the slots at full length, half at half length
        lengths = jnp.asarray([Smax if b % 2 == 0 else Smax // 2
                               for b in range(B)], jnp.int32)

        fns = {
            "naive": jax.jit(lambda q, k, v, ln: naive_attention(
                q, k, v, causal=False, kv_len=ln)),
            "pallas": jax.jit(lambda q, k, v, ln: kops.decode_attention(
                q, k, v, ln)),
        }
        outs = {}
        cell = []
        for impl in IMPLS:
            rec = {"bench": "decode_attn", "shape": arch, "impl": impl,
                   "B": B, "cache_len": cache_len, "H": H, "K": K, "hd": hd}
            try:
                outs[impl] = np.asarray(fns[impl](q, k, v, lengths))
                rec["us_per_step"] = round(_time(fns[impl], q, k, v, lengths,
                                                 iters=iters), 1)
                rec["tok_s"] = round(B / (rec["us_per_step"] / 1e6), 1)
                rec["status"] = "ok"
            except Exception as e:  # a broken impl is recorded, not fatal
                rec["status"] = f"error: {type(e).__name__}: {e}"
            cell.append(rec)
        if all(r["status"] == "ok" for r in cell):
            err = float(np.abs(outs["naive"] - outs["pallas"]).max())
            ok = bool(err < 2e-5)
            speedup = cell[0]["us_per_step"] / cell[1]["us_per_step"]
            for r in cell:
                r["parity_max_err"] = err
                r["parity_ok"] = ok
                r["pallas_speedup"] = round(speedup, 3)
        results.extend(cell)
    return results


def bench_model_parity(steps: int = 6):
    """Full-model gate: greedy decode must be token-identical naive vs pallas."""
    from repro.configs import ASSIGNED
    from repro.launch.steps import greedy_decode_tokens
    from repro.models import build_model

    results = []
    for arch in ("llama3.2-1b", "deepseek-v3-671b"):  # GQA and MLA
        cfg = ASSIGNED[arch].reduced()
        key = jax.random.PRNGKey(0)
        toks = jax.random.randint(key, (2, 1), 0, cfg.vocab_size)
        rec = {"bench": "decode_parity", "shape": arch, "impl": "pallas",
               "steps": steps}
        try:
            streams = {}
            for impl in IMPLS:
                model = build_model(cfg, impl=impl, moe_cf=100.0)
                params = model.init(key)
                streams[impl] = greedy_decode_tokens(
                    model, params, toks, steps=steps, max_len=steps + 2)
            same = bool((streams["naive"] == streams["pallas"]).all())
            rec["token_identical"] = same
            rec["status"] = "ok" if same else "error: token mismatch"
        except Exception as e:
            rec["status"] = f"error: {type(e).__name__}: {e}"
        results.append(rec)
    return results


def run(fast: bool = True):
    """Harness entry (benchmarks/run.py): yields (name, us, derived) rows.

    Raises after yielding the good rows if any impl errored or a parity
    gate failed, so a broken decode path lands in the harness's failure
    accounting instead of silently shrinking the row count.
    """
    bad = []
    for rec in bench_decode_attention(full=not fast, iters=2 if fast else 5):
        if rec["status"] == "ok":
            yield (f"decode_{rec['shape']}_L{rec['cache_len']}_{rec['impl']}",
                   rec["us_per_step"], f"tok_s={rec['tok_s']}")
            if not rec.get("parity_ok", True):
                bad.append(f"{rec['shape']}/L{rec['cache_len']}: parity "
                           f"err={rec.get('parity_max_err')}")
        else:
            bad.append(f"{rec['shape']}/{rec['impl']}: {rec['status']}")
    for rec in bench_model_parity():
        if rec["status"] != "ok":
            bad.append(f"{rec['shape']}: {rec['status']}")
    if bad:
        raise RuntimeError("decode bench failures: " + "; ".join(sorted(set(bad))))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="serving-scale cache lengths (accelerator only)")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--out", default="BENCH_decode.json")
    args = ap.parse_args()
    iters = args.iters or (5 if args.full else 2)

    results = bench_decode_attention(args.full, iters) + bench_model_parity()

    print("name,us_per_call,derived")
    for rec in results:
        name = f"{rec['bench']}_{rec['shape']}"
        if rec["bench"] == "decode_attn":
            name += f"_L{rec['cache_len']}_{rec['impl']}"
        if rec["status"] != "ok":
            print(f"{name},0,{rec['status']}")
        elif rec["bench"] == "decode_attn":
            print(f"{name},{rec['us_per_step']},tok_s={rec['tok_s']}")
        else:
            print(f"{name},0,token_identical={rec['token_identical']}")

    # timing gate: pallas must beat naive tokens/sec at cache_len >= 512.
    # Only claimable on a Mosaic backend — in interpret mode the kernel is
    # being emulated and the verdict is recorded as None (gate = parity).
    interpret = jax.default_backend() != "tpu"
    long_cells = [r["pallas_speedup"] for r in results
                  if r.get("cache_len", 0) >= 512 and "pallas_speedup" in r]
    timing_gate = None if (interpret or not long_cells) else \
        bool(min(long_cells) > 1.0)
    payload = {"mode": "full" if args.full else "ci",
               "backend": jax.default_backend(),
               "interpret": interpret,
               "timing_gate_pallas_wins_at_512": timing_gate,
               "iters": iters, "results": results}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {args.out} ({len(results)} records)", file=sys.stderr)
    bad = [r for r in results if r["status"] != "ok"
           or not r.get("parity_ok", True)]
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
