"""Shared-SoC arbitration: one arbiter over train+serve vs the alternatives.

Three ways to run a personalization-training job and an interactive serving
job on one SoC, measured under the same contention trace:

- **shared-arbiter**: both jobs under one SwanRuntime. Each runs its fastest
  rung while the device is quiet; under contention the arbiter downgrades
  the job that relinquishes the most contended resource per unit of goodput
  lost, and upgrades back when the trace clears.
- **static-partition**: the no-runtime baseline — resources are split ahead
  of time, each job pinned to its middle rung forever. Safe under
  contention, wasteful the rest of the time.
- **serve-only**: the serving job alone (training deferred entirely) — what
  a device does today; its goodput counts only serving.

Goodput is normalized useful compute per virtual second: a train step is
worth its full-rung clean latency, a served token 1/slots of the serving
job's; virtual time is the per-tick max of the jobs' observed latencies
(they share the quantum). Step latencies are simulated from the rungs'
estimates x the trace (deterministic); the compute, migrations and state
carry-over are real.

Gate (CI): shared-arbiter goodput >= static-partition goodput.
Writes BENCH_arbitration.json.
"""
from __future__ import annotations

import json
import time

import numpy as np

TRAIN_EST = 0.1   # clean full-rung train-step seconds (virtual)
SERVE_EST = 0.1   # clean full-rung decode-step seconds (virtual)


def _tiny_cfg(name):
    from repro.configs.base import ModelConfig
    return ModelConfig(name=name, family="dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                       tie_embeddings=True,
                       source="benchmarks/arbitration_bench.py")


def _train_job(trace, ticks, *, pinned=False):
    import dataclasses
    from repro.engine.jobs import trace_latency_fn
    from repro.engine.rungs import default_rung_ladder
    from repro.engine.session import TrainSession
    from repro.launch.train import make_batch_fn
    from repro.optim.optimizers import sgd

    cfg = _tiny_cfg("arb-train-tiny")
    rungs = default_rung_ladder(batch=8, microbatch=1, attn_impl="naive")
    for r in rungs:
        r.latency_estimate_s = TRAIN_EST * r.rel_latency
    if pinned:  # static partition: the middle rung, forever
        rungs = [dataclasses.replace(rungs[min(1, len(rungs) - 1)],
                                     name="train-pinned")]
    ses = TrainSession(cfg, rungs, optimizer=sgd(), lr=0.05,
                       batch_fn=make_batch_fn(cfg, 8, 32),
                       latency_fn=trace_latency_fn(trace), adaptive=not pinned,
                       upgrade_patience=4, verbose=False, name="train")
    return ses.bind(ticks)


def _serve_job(slots, trace, *, pinned=False):
    import jax
    from repro.engine.jobs import ServeJob, ServeRung, trace_latency_fn
    from repro.launch.serve import ContinuousBatchingEngine, Request
    from repro.models.registry import build_model

    cfg = _tiny_cfg("arb-serve-tiny")
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    engine = ContinuousBatchingEngine(model, params, max_batch=slots,
                                      max_seq=48)
    rng = np.random.default_rng(0)
    # a stream long enough to outlast the tick budget in every config
    reqs = [Request(uid=i, prompt=rng.integers(0, 64, 6).astype(np.int32),
                    max_new_tokens=16) for i in range(64)]
    rels = (1.0, 1.4, 1.9)
    sens = (1.0, 0.4, 0.16)
    caps = (None, max(1, slots // 2), max(1, slots // 4))
    rungs = [ServeRung(name=n, slot_cap=c, interference_sensitivity=s,
                       rel_latency=r, latency_estimate_s=SERVE_EST * r)
             for n, c, s, r in zip(("serve-full", "serve-capped",
                                    "serve-lean"), caps, sens, rels)]
    if pinned:
        import dataclasses
        rungs = [dataclasses.replace(rungs[1], name="serve-pinned")]
    return ServeJob(engine, reqs, rungs=rungs, latency_fn=trace_latency_fn(trace),
                    adaptive=not pinned, upgrade_patience=4, name="serve")


def _goodput(result, slots) -> float:
    """Normalized useful compute per virtual second (see module docstring)."""
    useful = 0.0
    for s in result.timeline.steps:
        if s.job == "train":
            useful += TRAIN_EST  # one optimizer step, whatever the rung
        elif s.job == "serve":
            useful += s.work * SERVE_EST / slots
    return useful / max(result.virtual_time_s, 1e-12)


def compare(ticks: int = 60, slots: int = 4,
            json_path: str = "BENCH_arbitration.json"):
    """Run the three configurations on the same contention trace."""
    from repro.engine.events import InterferenceTrace
    from repro.engine.runtime import SwanRuntime

    burst = (ticks // 3, ticks // 3 + ticks // 4, 3.0)
    trace = InterferenceTrace.parse(f"{burst[0]}:{burst[1]}:{burst[2]}")

    def run(jobs):
        rt = SwanRuntime(jobs, trace=trace)
        return rt.run(ticks)

    res_shared = run([_train_job(trace, ticks), _serve_job(slots, trace)])
    res_part = run([_train_job(trace, ticks, pinned=True),
                    _serve_job(slots, trace, pinned=True)])
    res_serve = run([_serve_job(slots, trace)])

    out = {}
    for name, res in (("shared", res_shared), ("partition", res_part),
                      ("serve_only", res_serve)):
        out[name] = {
            "goodput": round(_goodput(res, slots), 4),
            "virtual_time_s": round(res.virtual_time_s, 4),
            "work": {k: round(v, 1) for k, v in res.work.items()},
            "migrations": len(res.timeline.migrations),
            "summary": res.timeline.summary(),
        }
    payload = {
        "ticks": ticks, "slots": slots,
        "trace": trace.to_json(),
        "configs": out,
        "shared_vs_partition": round(out["shared"]["goodput"]
                                     / max(out["partition"]["goodput"], 1e-12), 4),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
    return payload


def run(fast: bool = True, json_path: str = "BENCH_arbitration.json"):
    ticks = 60 if fast else 120
    t0 = time.perf_counter()
    payload = compare(ticks=ticks, json_path=json_path)
    us = (time.perf_counter() - t0) * 1e6
    rows = []
    for name in ("shared", "partition", "serve_only"):
        c = payload["configs"][name]
        rows.append((f"arbitration/{name}/goodput", us,
                     f"{c['goodput']};migrations={c['migrations']}"))
    rows.append(("arbitration/shared_vs_partition", us,
                 f"{payload['shared_vs_partition']}x"))
    assert payload["configs"]["shared"]["goodput"] >= \
        payload["configs"]["partition"]["goodput"], \
        "shared arbiter must match or beat the static partition's goodput"
    assert payload["configs"]["shared"]["goodput"] >= \
        payload["configs"]["serve_only"]["goodput"], \
        "co-tenancy must not lose to deferring training entirely"
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_arbitration.json")
    args = ap.parse_args()
    for name, us, derived in run(fast=not args.full, json_path=args.out):
        print(f"{name},{us:.1f},{derived}")
