"""Telemetry overhead smoke: decode tokens/sec, telemetry on vs off.

The repro.obs design promise is that instrumentation is cheap enough to
leave in the hot loops: disabled it is one attribute lookup + shared no-op
handle, enabled it is a perf_counter pair and a list append per span. This
bench holds the promise to a number CI can gate.

One tiny dense engine (paged layout, so the decode path crosses the
block-table accounting the spans wrap) is compiled once and warmed, then
identical request waves are decoded with telemetry alternately disabled
and enabled — interleaved repetitions, best-of per mode, so machine noise
hits both modes equally. Gate: enabled throughput >= 97% of disabled
(<= 3% tokens/sec overhead). Writes ``BENCH_obs.json``.

Usage:
  PYTHONPATH=src python benchmarks/obs_bench.py [--full] [--out BENCH_obs.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SEED = 11
N_REQUESTS = 8
PROMPT_LEN = 8
GEN_TOKENS = 16
MAX_OVERHEAD = 0.03  # enabled may cost at most 3% tokens/sec


def _engine():
    import jax
    from repro.configs.base import ModelConfig
    from repro.launch.serve import ContinuousBatchingEngine
    from repro.models.registry import build_model

    cfg = ModelConfig(name="obs-tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      tie_embeddings=True, source="benchmarks/obs_bench.py")
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    return ContinuousBatchingEngine(model, params, max_batch=4, max_seq=64,
                                    kv_layout="paged", block_size=4,
                                    prefix_cache=False)


def _wave(rng, uid0):
    from repro.launch.serve import Request
    import numpy as np
    return [Request(uid=uid0 + i,
                    prompt=rng.integers(0, 64, PROMPT_LEN).astype(np.int32),
                    max_new_tokens=GEN_TOKENS) for i in range(N_REQUESTS)]


def run(fast: bool = True, json_path: str = "BENCH_obs.json"):
    import numpy as np
    from repro import obs

    reps = 5 if fast else 9
    engine = _engine()
    rng = np.random.default_rng(SEED)

    prev = obs.set_telemetry(obs.Telemetry(enabled=False))
    try:
        engine.run(_wave(rng, 0))  # compile + warm every jitted path

        uid = 1000
        samples = {"off": [], "on": []}
        span_count = 0
        for _ in range(reps):
            for mode in ("off", "on"):  # interleaved: noise hits both modes
                tel = obs.Telemetry(enabled=(mode == "on"))
                obs.set_telemetry(tel)
                reqs = _wave(rng, uid)
                uid += N_REQUESTS
                t0 = time.perf_counter()
                engine.run(reqs)
                dt = time.perf_counter() - t0
                samples[mode].append(N_REQUESTS * GEN_TOKENS / dt)
                if mode == "on":
                    span_count = len(tel.tracer.spans())
    finally:
        obs.set_telemetry(prev)

    best_off = max(samples["off"])
    best_on = max(samples["on"])
    ratio = best_on / best_off
    gate = ratio >= 1.0 - MAX_OVERHEAD
    payload = {
        "mode": "fast" if fast else "full",
        "reps": reps,
        "tokens_per_run": N_REQUESTS * GEN_TOKENS,
        "tok_s_off": samples["off"],
        "tok_s_on": samples["on"],
        "best_tok_s_off": best_off,
        "best_tok_s_on": best_on,
        "overhead_ratio": ratio,
        "spans_per_run": span_count,
        "max_overhead": MAX_OVERHEAD,
        "gate_overhead_ok": gate,
    }
    with open(json_path, "w") as f:
        json.dump(payload, f, indent=1)

    assert span_count > 0, "enabled runs recorded no spans — bench is blind"
    assert gate, (
        f"telemetry overhead gate: enabled decode reached {ratio:.3f}x of "
        f"disabled tokens/sec (floor {1.0 - MAX_OVERHEAD:.2f}); "
        f"off={best_off:.0f} on={best_on:.0f}")

    return [
        ("obs/decode_tok_s_off", 1e6 / best_off, f"{best_off:.0f}"),
        ("obs/decode_tok_s_on", 1e6 / best_on, f"{best_on:.0f}"),
        ("obs/overhead_ratio", 0.0,
         f"{ratio:.4f};gate>={1.0 - MAX_OVERHEAD:.2f};spans={span_count}"),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args()
    for name, us, derived in run(fast=not args.full, json_path=args.out):
        print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {args.out}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
