"""End-to-end driver: train a ~100M-param LM for a few hundred steps on CPU,
with checkpointing + restart and Swan interference monitoring.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(Defaults are sized to finish on a small CPU; the model is a genuine ~100M
llama-family config, not a toy.)
"""
import argparse
import dataclasses
import sys

from repro.configs.base import ModelConfig
import repro.configs as C
from repro.launch import train as T

CONFIG_100M = ModelConfig(
    name="llama-100m", family="dense", n_layers=8, d_model=512, n_heads=8,
    n_kv_heads=4, d_ff=2048, vocab_size=32000, activation="silu",
    norm="rmsnorm", tie_embeddings=True, rope_theta=10000.0,
    source="examples/train_lm.py (~100M params)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/swan_lm_ckpt")
    ap.add_argument("--adaptive", action="store_true",
                    help="train on the engine's Rung ladder and migrate "
                         "under (synthetic) co-tenant pressure")
    ap.add_argument("--interference-trace", default=None,
                    help="e.g. '100:160:3.0' — requires --adaptive to react")
    args = ap.parse_args()

    print(f"params: {CONFIG_100M.param_count() / 1e6:.1f}M")
    C.REGISTRY[CONFIG_100M.name] = CONFIG_100M
    argv = [
        "--arch", CONFIG_100M.name, "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--optimizer", "adam", "--lr", "3e-4",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100", "--resume",
        "--log-every", "25",
    ]
    if args.adaptive:
        argv += ["--adaptive"]
    if args.interference_trace:
        argv += ["--interference-trace", args.interference_trace]
    losses = T.main(argv)
    if not losses:
        print("nothing to do (checkpoint already at --steps); "
              "bump --steps to continue training")
        return
    assert losses[-1] < losses[0], "loss did not decrease"
    print("OK: loss decreased", losses[0], "->", losses[-1])


if __name__ == "__main__":
    main()
