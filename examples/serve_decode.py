"""Continuous-batching serving demo on a reduced llama config.

Streams a ragged request mix through the slot-based engine (per-sequence
cache lengths, mid-stream retirement and admission) and prints tokens/sec
plus slot occupancy. ``--lockstep`` falls back to the legacy fixed-batch
loop; non-KV-cache families (whisper, rwkv, zamba) use it automatically.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch llama3.2-1b]
"""
import argparse

from repro.launch import serve as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4, help="serving slots")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--attn-impl", default="auto",
                    choices=("auto", "naive", "pallas"))
    ap.add_argument("--kv-layout", default="contig",
                    choices=("contig", "paged"),
                    help="contiguous per-slot slabs or block-pooled paged KV")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--lockstep", action="store_true")
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--reduced", "--batch", str(args.batch),
            "--requests", str(args.requests), "--prompt-len", "32",
            "--gen", str(args.gen), "--attn-impl", args.attn_impl,
            "--kv-layout", args.kv_layout,
            "--temperature", str(args.temperature)]
    if args.lockstep:
        argv.append("--lockstep")
    S.main(argv)


if __name__ == "__main__":
    main()
