"""Batched serving demo: prefill + KV-cache decode on a reduced llama config.

Run:  PYTHONPATH=src python examples/serve_decode.py [--arch llama3.2-1b]
"""
import argparse

from repro.launch import serve as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    S.main(["--arch", args.arch, "--reduced", "--batch", str(args.batch),
            "--prompt-len", "32", "--gen", str(args.gen)])


if __name__ == "__main__":
    main()
