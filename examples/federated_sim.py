"""Federated-learning macro simulation (paper §5.3): Swan vs baseline across
hundreds of GreenHub-like clients with energy loans.

Run:  PYTHONPATH=src python examples/federated_sim.py [--rounds 200]
"""
import argparse

import numpy as np

from repro.fl.simulator import compare_policies


def sparkline(vals, width=60):
    vals = np.asarray(vals, float)
    if len(vals) > width:
        idx = np.linspace(0, len(vals) - 1, width).astype(int)
        vals = vals[idx]
    lo, hi = vals.min(), vals.max()
    chars = " .:-=+*#%@"
    out = "".join(chars[int((v - lo) / max(hi - lo, 1e-9) * (len(chars) - 1))] for v in vals)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="shufflenet-v2",
                    choices=["shufflenet-v2", "mobilenet-v2", "resnet34"])
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=480)
    args = ap.parse_args()

    res = compare_policies(args.workload, rounds=args.rounds,
                           n_clients=args.clients, clients_per_round=50)
    for pol, r in res.items():
        acc = [x.accuracy for x in r.rounds]
        online = [x.online for x in r.rounds]
        print(f"\n== {pol} ==")
        print(f"accuracy  |{sparkline(acc)}| final {r.final_accuracy:.3f}")
        print(f"online    |{sparkline(online)}| last {online[-1]}")
        print(f"wall-clock {r.rounds[-1].t_min / 60:.1f}h, energy {r.total_energy_j / 1e3:.0f}kJ")

    tgt = min(res["baseline"].final_accuracy, res["swan"].final_accuracy)
    tb = res["baseline"].time_to_accuracy(tgt)
    ts = res["swan"].time_to_accuracy(tgt)
    print(f"\ntime-to-{tgt:.3f}: baseline {tb:.0f}min, swan {ts:.0f}min "
          f"-> {tb / ts:.2f}x speedup")
    print(f"energy efficiency: "
          f"{res['baseline'].total_energy_j / res['swan'].total_energy_j:.1f}x")


if __name__ == "__main__":
    main()
