"""Quickstart: Swan's explore -> prune -> select -> migrate loop in 40 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import energy as E
from repro.core.planner import explore_soc
from repro.core.profiler import greedy_baseline_profile

# 1. Explore every execution choice for ShuffleNet on a Pixel 3 (paper §4.2).
plan = explore_soc("pixel3", "shufflenet-v2")
print("explored choices:", plan.explored_names)

# 2. Pruning (paper §4.3) removes dominated choices — more cores is SLOWER
#    for depthwise-heavy models (cache thrashing), so the ladder collapses:
print("pruned ladder  :", [p.name for p in plan.ladder])

# 3. The selected choice beats the PyTorch-greedy baseline:
base = greedy_baseline_profile(E.SOC_MODELS["pixel3"], "shufflenet-v2")
print(f"selected {plan.selected.name}: {base.latency_s / plan.selected.latency_s:.1f}x "
      f"faster, {base.energy_j / plan.selected.energy_j:.1f}x less energy than baseline")

# 4. Dynamic migration (paper Fig. 4b): a foreground app appears; observed
#    step latency inflates; the controller downgrades, then recovers.
ctl = plan.controller(upgrade_patience=3)
lat = ctl.active.latency_s
for step in range(12):
    interference = 1.0 if 3 <= step < 7 else 0.0
    observed = ctl.active.latency_s * (1 + interference)
    ctl.observe_step(observed)
for m in ctl.migrations:
    print(f"  step {m.step}: {ctl.ladder[m.from_idx].name} -> "
          f"{ctl.ladder[m.to_idx].name} ({m.reason})")
