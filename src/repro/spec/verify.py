"""Accept/rollback verdicts for speculative windows.

Both verifiers consume the (B, S, V) logits of one multi-token verify pass
over the window [last_emitted, d_1, .., d_{S-1}]: row qi is the target
model's next-token distribution after window position qi, so row 0 scores
draft d_1 and row S-1 is the bonus distribution past the last draft.

They return ``(tokens, n_emit)`` where ``tokens[b, :n_emit[b]]`` are the
tokens to emit for row b (1 <= n_emit <= S): the accepted draft prefix plus
exactly one non-draft token (greedy argmax / residual resample / bonus).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.launch.sampling import sample_probs


def greedy_verify(logits, drafts):
    """Greedy acceptance: token-identical to non-speculative argmax decode.

    logits: (B, S, V); drafts: (B, S-1) int32 draft tokens d_1..d_{S-1}.

    Draft d_i is accepted iff it equals the argmax after window position
    i-1; the emitted token at every position — accepted draft or first
    mismatch — is that position's argmax, so the emitted stream is exactly
    the chain a one-token-at-a-time greedy decode would produce.
    """
    best = jnp.argmax(logits, axis=-1).astype(jnp.int32)       # (B, S)
    B, S = best.shape
    if S > 1:
        ok = (drafts.astype(jnp.int32) == best[:, :-1]).astype(jnp.int32)
        n_acc = jnp.cumprod(ok, axis=1).sum(axis=1)            # (B,) 0..S-1
    else:
        n_acc = jnp.zeros((B,), jnp.int32)
    return best, n_acc + 1


def _split_keys(keys, tag: int):
    return jax.vmap(jax.vmap(lambda k: jax.random.fold_in(k, tag)))(keys)


def rejection_verify(logits, drafts, draft_probs: Optional[jax.Array], keys,
                     *, temperature: float, top_k: int = 0):
    """Distribution-faithful speculative sampling (accept/resample).

    logits: (B, S, V) target logits; drafts: (B, S-1) proposed tokens;
    draft_probs: (B, S-1, V) proposal distributions, or None for
    deterministic proposals (one-hot — the n-gram head and greedy model
    drafts); keys: (B, S, 2) uint32 — the engine's fold_in(seed, uid, index)
    stream keys for the S candidate emission indices, so a request's
    randomness stays batch-composition independent.

    Draft d_i is accepted with probability min(1, q_i(d_i) / p_i(d_i))
    where q is the target distribution under the SHARED temperature/top-k
    masking (repro.launch.sampling — the same shaping the engine's fallback
    sampler uses). On first rejection the token resamples from the residual
    norm(max(q - p, 0)); if every draft survives, the bonus position samples
    from q directly. Marginally, every emitted token ~ q exactly.
    """
    q = sample_probs(logits, temperature, top_k)               # (B, S, V)
    B, S, V = q.shape
    u_keys = _split_keys(keys, 0)
    r_keys = _split_keys(keys, 1)
    u = jax.vmap(jax.vmap(jax.random.uniform))(u_keys)         # (B, S)

    if S > 1:
        d = drafts.astype(jnp.int32)
        qd = jnp.take_along_axis(q[:, :-1], d[..., None], -1)[..., 0]
        if draft_probs is None:
            # deterministic proposal: p(d) = 1, residual = q with d zeroed
            pd = jnp.ones_like(qd)
            onehot = jax.nn.one_hot(d, V, dtype=q.dtype)
            resid = jnp.maximum(q[:, :-1] - onehot * qd[..., None], 0.0)
        else:
            p = draft_probs.astype(jnp.float32)
            pd = jnp.take_along_axis(p, d[..., None], -1)[..., 0]
            resid = jnp.maximum(q[:, :-1] - p, 0.0)
        # u < min(1, qd/pd) without dividing: u*pd < qd (pd = 0 rejects
        # unless qd > 0, which accepts — the proposal was impossible anyway)
        ok = (u[:, :-1] * pd < qd).astype(jnp.int32)
        n_acc = jnp.cumprod(ok, axis=1).sum(axis=1)            # (B,) 0..S-1
        total = resid.sum(-1, keepdims=True)
        resid = jnp.where(total > 0, resid / jnp.maximum(total, 1e-30),
                          q[:, :-1])
        fb_probs = jnp.concatenate([resid, q[:, -1:]], axis=1)  # (B, S, V)
    else:
        n_acc = jnp.zeros((B,), jnp.int32)
        fb_probs = q

    fb_logits = jnp.where(fb_probs > 0, jnp.log(fb_probs), -jnp.inf)
    fallback = jax.vmap(jax.vmap(jax.random.categorical))(
        r_keys, fb_logits).astype(jnp.int32)                   # (B, S)

    pos = jnp.arange(S, dtype=jnp.int32)[None]
    if S > 1:
        dpad = jnp.concatenate(
            [drafts.astype(jnp.int32), jnp.zeros((B, 1), jnp.int32)], axis=1)
    else:
        dpad = jnp.zeros((B, S), jnp.int32)
    tokens = jnp.where(pos < n_acc[:, None], dpad, fallback)
    return tokens, n_acc + 1
