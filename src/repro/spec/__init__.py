"""Speculative decoding: draft sources + multi-token verification.

A draft source proposes k cheap tokens per request; the target model scores
the whole window in ONE multi-token decode pass (the flash-decode kernel
grown to a q-block, ``kernels.flash_attention.flash_decode_spec{,_paged}``);
the verifier accepts a prefix and emits one extra token — greedy mode is
token-identical to non-speculative decoding, sampled mode is
distribution-faithful rejection sampling against the engine's per-request
PRNG streams. Draft depth k is a serving-rung axis
(``engine.jobs.ServeRung.draft_depth``) so the SoC arbiter can walk
speculation down under thermal or energy pressure.
"""
from repro.spec.draft import DraftSource, ModelDraft, NGramDraft, build_draft_source
from repro.spec.verify import greedy_verify, rejection_verify

__all__ = [
    "DraftSource", "ModelDraft", "NGramDraft", "build_draft_source",
    "greedy_verify", "rejection_verify",
]
