"""Draft sources: where the k cheap tokens per request come from.

Two implementations behind one protocol:

* :class:`NGramDraft` — self-drafting n-gram head. No extra model: it
  predicts the continuation from the longest-suffix match over the
  request's own token history (prompt + emitted). Proposals are
  deterministic (one-hot), so rejection sampling degenerates to the exact
  q(d) accept test. Near-zero draft cost; shines on repetitive output.

* :class:`ModelDraft` — a small-config registry model with its own KV
  cache, run autoregressively k steps ahead of the target. Rollback mirrors
  the target engine's: accepted proposal KVs are kept (they were computed
  from the very tokens that got accepted), the rest is masked dead by
  cache_len bookkeeping and overwritten in place next round.

Draft sources are host-side engine components: slots, numpy token lists,
and explicit admit/commit/release lifecycle calls from the serve engine.
"""
from __future__ import annotations

from typing import Optional, Protocol, Sequence, runtime_checkable

import numpy as np


@runtime_checkable
class DraftSource(Protocol):
    """Engine-facing lifecycle + proposal interface."""

    def admit(self, slot: int, prompt_tokens: Sequence[int]) -> None:
        """A request was admitted to ``slot`` with this prompt."""

    def release(self, slot: int) -> None:
        """The slot was retired/preempted; drop its draft state."""

    def commit(self, slot: int, accepted: Sequence[int], extra: int) -> None:
        """A verify round emitted ``accepted + [extra]``: the accepted
        prefix of the last proposal plus one non-draft token (greedy
        argmax / residual resample / bonus). Roll back rejected proposal
        state and ingest ``extra``."""

    def propose(self, slots: Sequence[int], k: int):
        """Propose ``k`` draft tokens for each slot. Returns
        ``(drafts, probs)``: drafts (len(slots), k) int32; probs
        (len(slots), k, V) float proposal distributions, or None for
        deterministic (one-hot) proposals."""


class NGramDraft:
    """Longest-suffix n-gram predictor over each slot's own history.

    ``observe`` maintains, per slot, one table per context length n
    (1..max_n) mapping the n-gram tuple to the token that most recently
    followed it. ``propose`` extends the history virtually: each predicted
    token is fed back as context (with a local overlay so in-window
    transitions chain), which lets the head ride multi-token cycles —
    exactly the structure greedy decode of small models collapses into.
    """

    def __init__(self, max_n: int = 3):
        if max_n < 1:
            raise ValueError("max_n must be >= 1")
        self.max_n = max_n
        self._hist: dict = {}
        self._tabs: dict = {}

    def admit(self, slot, prompt_tokens):
        self.release(slot)
        self._hist[slot] = []
        self._tabs[slot] = [dict() for _ in range(self.max_n)]
        self._observe(slot, prompt_tokens)

    def release(self, slot):
        self._hist.pop(slot, None)
        self._tabs.pop(slot, None)

    def commit(self, slot, accepted, extra):
        self._observe(slot, list(accepted) + [int(extra)])

    def _observe(self, slot, tokens):
        h = self._hist[slot]
        tabs = self._tabs[slot]
        for t in tokens:
            t = int(t)
            for n in range(1, self.max_n + 1):
                if len(h) >= n:
                    tabs[n - 1][tuple(h[-n:])] = t
            h.append(t)

    def propose(self, slots, k):
        out = np.zeros((len(slots), k), np.int32)
        for row, slot in enumerate(slots):
            seq = list(self._hist.get(slot, []))
            tabs = self._tabs.get(slot) or [dict() for _ in range(self.max_n)]
            local = [dict() for _ in range(self.max_n)]
            for j in range(k):
                tok = None
                for n in range(min(self.max_n, len(seq)), 0, -1):
                    key = tuple(seq[-n:])
                    tok = local[n - 1].get(key)
                    if tok is None:
                        tok = tabs[n - 1].get(key)
                    if tok is not None:
                        break
                if tok is None:  # cold start: repeat the last token
                    tok = seq[-1] if seq else 0
                for n in range(1, self.max_n + 1):
                    if len(seq) >= n:
                        local[n - 1][tuple(seq[-n:])] = tok
                seq.append(tok)
                out[row, j] = tok
        return out, None


class ModelDraft:
    """Small registry model running k steps ahead of the target.

    Keeps a contiguous KV cache of its own, synchronized with the engine's
    emitted history through the lifecycle calls: ``admit`` queues the
    prompt, ``commit`` rolls the draft cache back to the accepted prefix
    (the accepted proposals' KV is already correct — it was computed from
    those very tokens) and queues the one non-draft emission, ``propose``
    first catches up the queue one batched decode step at a time, then
    rolls the window forward. Rejected positions keep stale KV, masked dead
    by cache_len and overwritten next round — the same rollback-by-
    bookkeeping the target engine uses.

    With ``temperature > 0`` proposals are sampled from the draft's own
    temperature/top-k distribution (returned as the rejection test's p);
    greedy proposals are argmax with one-hot p (probs=None).
    """

    def __init__(self, model, params, *, max_batch: int, max_seq: int,
                 cache_dtype=None, temperature: float = 0.0, top_k: int = 0,
                 seed: int = 0):
        import jax.numpy as jnp

        from repro.launch.steps import build_decode_step

        self.model = model
        self.params = params
        self.max_batch = int(max_batch)
        self.max_seq = int(max_seq)
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.vocab_size = int(model.cfg.vocab_size)
        self._step = build_decode_step(model, greedy=False)
        self._cache = model.init_cache(self.max_batch, self.max_seq,
                                       cache_dtype or jnp.float32)
        self.cache_len = np.zeros(self.max_batch, np.int32)
        self._pending: dict = {}
        self._base: dict = {}
        self._next_logits = np.zeros((self.max_batch, self.vocab_size),
                                     np.float32)
        self._rng = np.random.default_rng(seed)

    def admit(self, slot, prompt_tokens):
        self.cache_len[slot] = 0
        self._pending[slot] = [int(t) for t in prompt_tokens]
        self._base.pop(slot, None)

    def release(self, slot):
        self.cache_len[slot] = 0
        self._pending.pop(slot, None)
        self._base.pop(slot, None)

    def commit(self, slot, accepted, extra):
        base = self._base.pop(slot, None)
        if base is not None:
            self.cache_len[slot] = base + len(accepted)
        self._pending.setdefault(slot, []).append(int(extra))

    def _advance(self, feed):
        """One batched decode step. ``feed``: {slot: token} — those slots
        consume their token and advance; every other row ingests a dummy at
        its frozen position (overwritten later, output discarded)."""
        import jax.numpy as jnp

        toks = np.zeros((self.max_batch, 1), np.int32)
        for slot, tok in feed.items():
            toks[slot, 0] = tok
        _, logits, self._cache = self._step(
            self.params, self._cache, jnp.asarray(toks),
            jnp.asarray(self.cache_len))
        rows = np.asarray(logits[:, -1, :], np.float32)
        for slot in feed:
            self._next_logits[slot] = rows[slot]
            self.cache_len[slot] += 1

    def _catch_up(self, slots):
        while True:
            feed = {s: self._pending[s].pop(0)
                    for s in slots if self._pending.get(s)}
            if not feed:
                return
            self._advance(feed)

    def _pick(self, slots):
        """Next proposal per slot from its current next-token logits.
        Returns (tokens {slot: tok}, probs (n, V) or None)."""
        rows = self._next_logits[list(slots)]
        if self.temperature <= 0.0:
            toks = rows.argmax(-1).astype(np.int32)
            return dict(zip(slots, toks)), None
        from repro.launch.sampling import sample_probs
        probs = np.asarray(sample_probs(rows, self.temperature, self.top_k))
        toks = np.array([self._rng.choice(self.vocab_size, p=p / p.sum())
                         for p in probs], np.int32)
        return dict(zip(slots, toks)), probs

    def propose(self, slots, k):
        slots = list(slots)
        self._catch_up(slots)
        for s in slots:
            self._base[s] = int(self.cache_len[s])
        drafts = np.zeros((len(slots), k), np.int32)
        probs = (np.zeros((len(slots), k, self.vocab_size), np.float32)
                 if self.temperature > 0.0 else None)
        for j in range(k):
            feed, p = self._pick(slots)
            for row, s in enumerate(slots):
                drafts[row, j] = feed[s]
                if probs is not None:
                    probs[row, j] = p[row]
            if j < k - 1:  # the last proposal is never ingested here
                self._advance(feed)
        return drafts, probs


def build_draft_source(name: str, *, target_cfg=None, max_batch: int = 1,
                       max_seq: int = 1024, temperature: float = 0.0,
                       top_k: int = 0, seed: int = 0,
                       ngram_max_n: int = 3) -> "DraftSource":
    """Resolve a ``--draft-source`` string: ``"ngram"`` or a registry arch
    name (built ``.reduced()`` with fresh params — the serving examples run
    random weights throughout). A registry draft must share the target's
    vocab; anything else would propose unverifiable ids."""
    if name == "ngram":
        return NGramDraft(max_n=ngram_max_n)

    import jax

    from repro.configs import ASSIGNED
    from repro.models.registry import build_model

    if name not in ASSIGNED:
        raise ValueError(f"unknown draft source {name!r}: expected 'ngram' "
                         f"or one of {sorted(ASSIGNED)}")
    cfg = ASSIGNED[name].reduced()
    if target_cfg is not None and cfg.vocab_size != target_cfg.vocab_size:
        raise ValueError(
            f"draft model {name!r} vocab {cfg.vocab_size} != target vocab "
            f"{target_cfg.vocab_size}")
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(seed))
    return ModelDraft(model, params, max_batch=max_batch, max_seq=max_seq,
                      temperature=temperature, top_k=top_k, seed=seed)
