"""Elastic re-meshing: Swan's migration loop applied to a device pool.

The controller owns a device pool; when capacity changes (failures from
FaultModel, or co-tenant pressure from the interference monitor), it asks the
Swan planner for the best *surviving* execution choice and produces a new
mesh. Training resumes from the latest checkpoint via
``CheckpointManager.restore_latest(mesh=new_mesh)`` — parameters re-shard on
restore, so the migration cost is one checkpoint round-trip (exactly the
downgrade/upgrade transition of paper Fig. 4b, with save/restore standing in
for the thread-affinity switch).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import numpy as np


@dataclasses.dataclass
class ElasticController:
    total_devices: int
    min_devices: int = 1
    # candidate mesh shapes in Swan cost order (costliest/fastest first)
    candidates: Optional[List[Tuple[int, ...]]] = None

    def __post_init__(self):
        if self.candidates is None:
            self.candidates = default_mesh_ladder(self.total_devices)
        self._healthy = np.ones(self.total_devices, bool)

    def mark_failed(self, idx: Sequence[int]):
        self._healthy[np.asarray(idx, dtype=np.int64)] = False

    def mark_recovered(self, idx: Sequence[int]):
        self._healthy[np.asarray(idx, dtype=np.int64)] = True

    @property
    def n_healthy(self) -> int:
        return int(self._healthy.sum())

    def current_shape(self) -> Tuple[int, ...]:
        """Largest candidate mesh that fits in the healthy pool."""
        n = self.n_healthy
        for shape in self.candidates:
            size = int(np.prod(shape))
            if size <= n:
                return shape
        return self.candidates[-1]

    def make_mesh(self, axis_names=("data", "model"), devices=None, shape=None):
        """Mesh over the healthy pool. ``shape`` overrides the ladder pick
        (used when a Rung pins its own mesh shape) but must fit the pool."""
        if shape is None:
            shape = self.current_shape()
        elif int(np.prod(shape)) > self.n_healthy:
            raise ValueError(f"mesh shape {shape} needs {int(np.prod(shape))} "
                             f"devices, only {self.n_healthy} healthy")
        devices = devices if devices is not None else jax.devices()
        healthy = [d for d, ok in zip(devices, self._healthy) if ok]
        size = int(np.prod(shape))
        devs = np.array(healthy[:size]).reshape(shape)
        names = axis_names[-len(shape):]
        return jax.sharding.Mesh(devs, names)

    def healthy_ids(self) -> List[int]:
        return [i for i, ok in enumerate(self._healthy) if ok]


def default_mesh_ladder(total: int) -> List[Tuple[int, ...]]:
    """Swan-ordered ladder of (data, model) shapes: fastest (all devices)
    first, then progressively cheaper submeshes (power-of-two downgrades)."""
    ladder: List[Tuple[int, ...]] = []
    n = 1
    while n * 2 <= total:
        n *= 2
    while n >= 1:
        model = 1
        # the model*2 <= n guard keeps the doubling from overshooting the
        # pool itself (without it, n=1 yields the degenerate shape (0, 2))
        while model * model <= n and model * 2 <= n and model < 32:
            model *= 2
        ladder.append((n // model, model))
        n //= 2
    return ladder
