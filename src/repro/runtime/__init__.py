from repro.runtime.fault import FaultModel, StragglerPolicy  # noqa: F401
from repro.runtime.elastic import ElasticController  # noqa: F401
