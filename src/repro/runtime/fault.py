"""Fault tolerance & straggler mitigation policies.

At fleet scale, Swan's "interference" becomes node failure / preemption /
stragglers. Two standard mitigations implemented here, both driven by the same
profiles the Swan planner maintains:

- FaultModel: exponential per-node MTBF; decides which nodes die during a
  step window. Drives both the FL simulator and the elastic-train example.
- StragglerPolicy: over-provisioned participation + deadline. Select
  ceil(K * over_provision) participants, accept the first K results within
  ``deadline_factor * median_latency`` (FedScale/Papaya-style); the laggards'
  work is dropped, so one slow node never stalls the round.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class FaultModel:
    mtbf_steps: float  # mean steps between failures per node
    recovery_steps: float = 50.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def step_failures(self, n_nodes: int) -> np.ndarray:
        """Bool mask of nodes that fail during this step.

        ``mtbf_steps <= 0`` means "no time between failures": every node
        fails every step, deterministically — not a division blow-up into a
        probability of 1e9 that happens to behave the same by accident."""
        if n_nodes <= 0:
            return np.zeros(0, dtype=bool)
        if self.mtbf_steps <= 0:
            return np.ones(n_nodes, dtype=bool)
        return self._rng.random(n_nodes) < 1.0 / self.mtbf_steps

    def recovery_time(self) -> int:
        return int(self._rng.exponential(self.recovery_steps)) + 1


@dataclasses.dataclass(frozen=True)
class AcceptOutcome:
    """Result of a round's acceptance decision.

    ``indices`` are the accepted participants, fastest first. ``shortfall``
    is how far the round fell short of its target (accepted vs min(k,
    invited)) — callers must see a short round rather than having laggards
    silently accepted for them.
    """
    indices: np.ndarray
    invited: int
    target: int
    deadline_s: float

    @property
    def shortfall(self) -> int:
        return max(0, min(self.target, self.invited) - int(self.indices.size))

    def __len__(self) -> int:
        return int(self.indices.size)

    def __iter__(self) -> Iterator[int]:
        return iter(int(i) for i in self.indices)


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    over_provision: float = 1.3
    deadline_factor: float = 2.0

    def n_to_invite(self, k: int) -> int:
        return max(k, math.ceil(k * self.over_provision))

    def accept(self, latencies: Sequence[float], k: int, *,
               deadline_s: Optional[float] = None) -> AcceptOutcome:
        """First-k finishers within the deadline — and the deadline is
        binding. A round where fewer than k nodes beat it completes short
        (graceful degradation); the shortfall is surfaced on the outcome, it
        is never papered over by accepting laggards. The effective deadline
        is ``deadline_factor * median_latency``, clamped by the absolute
        ``deadline_s`` when given. An empty round (every invited node died)
        accepts nobody rather than warning about the median of nothing."""
        lat = np.asarray(latencies, dtype=np.float64)
        if lat.size == 0 or k <= 0:
            bound = float(deadline_s) if deadline_s is not None else 0.0
            return AcceptOutcome(indices=np.zeros(0, dtype=np.int64),
                                 invited=int(lat.size), target=max(0, k),
                                 deadline_s=bound)
        order = np.argsort(lat, kind="stable")
        med = float(np.median(lat))
        deadline = med * self.deadline_factor
        if deadline_s is not None:
            deadline = min(deadline, float(deadline_s))
        accepted = [int(i) for i in order if lat[i] <= deadline][:k]
        return AcceptOutcome(indices=np.asarray(accepted, dtype=np.int64),
                             invited=int(lat.size), target=int(k),
                             deadline_s=deadline)
