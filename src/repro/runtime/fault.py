"""Fault tolerance & straggler mitigation policies.

At fleet scale, Swan's "interference" becomes node failure / preemption /
stragglers. Two standard mitigations implemented here, both driven by the same
profiles the Swan planner maintains:

- FaultModel: exponential per-node MTBF; decides which nodes die during a
  step window. Drives both the FL simulator and the elastic-train example.
- StragglerPolicy: over-provisioned participation + deadline. Select
  ceil(K * over_provision) participants, accept the first K results within
  ``deadline_factor * median_latency`` (FedScale/Papaya-style); the laggards'
  work is dropped, so one slow node never stalls the round.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class FaultModel:
    mtbf_steps: float  # mean steps between failures per node
    recovery_steps: float = 50.0
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def step_failures(self, n_nodes: int) -> np.ndarray:
        """Bool mask of nodes that fail during this step.

        ``mtbf_steps <= 0`` means "no time between failures": every node
        fails every step, deterministically — not a division blow-up into a
        probability of 1e9 that happens to behave the same by accident."""
        if n_nodes <= 0:
            return np.zeros(0, dtype=bool)
        if self.mtbf_steps <= 0:
            return np.ones(n_nodes, dtype=bool)
        return self._rng.random(n_nodes) < 1.0 / self.mtbf_steps

    def recovery_time(self) -> int:
        return int(self._rng.exponential(self.recovery_steps)) + 1


@dataclasses.dataclass(frozen=True)
class StragglerPolicy:
    over_provision: float = 1.3
    deadline_factor: float = 2.0

    def n_to_invite(self, k: int) -> int:
        return max(k, math.ceil(k * self.over_provision))

    def accept(self, latencies: Sequence[float], k: int) -> np.ndarray:
        """Indices of the first-k finishers within the deadline. An empty
        round (every invited node died) accepts nobody rather than warning
        about the median of nothing."""
        lat = np.asarray(latencies, dtype=np.float64)
        if lat.size == 0 or k <= 0:
            return np.zeros(0, dtype=np.int64)
        order = np.argsort(lat)
        med = float(np.median(lat))
        deadline = med * self.deadline_factor
        accepted = [i for i in order if lat[i] <= deadline][:k]
        if len(accepted) < min(k, len(lat)):  # fallback: take fastest k anyway
            accepted = list(order[:k])
        return np.asarray(accepted, dtype=np.int64)
