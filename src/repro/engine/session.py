"""TrainSession: the live migration loop (paper Fig. 4b, runnable).

The session owns the training state and implements the :class:`SocJob`
protocol (engine/jobs.py); its old private event loop is now the single-job
special case of :class:`engine.runtime.SwanRuntime` — ``run()`` builds a
one-job runtime, so training standalone and training under multi-job
arbitration execute the exact same code. Per quantum the job:

1. applies device-loss events pushed by the runtime (``on_device_loss``:
   SwanController.force_downgrade + mandatory remesh),
2. executes the active Rung's cached jitted step (``step``),
3. digests the observed latency and lets its SwanController *propose* a
   migration (``observe``) — the runtime arbitrates across co-tenant jobs,
4. applies a committed migration *without restarting* (``migrate``):
   - same-mesh migrations (microbatch / kernel / dtype) carry state over in
     place, casting parameters with launch.steps.cast_params when the dtype
     changes;
   - mesh-shape migrations go through one CheckpointManager save/restore
     round-trip against ElasticController.make_mesh, re-sharding parameters
     under the surviving mesh.

Latency semantics: the wall time of each step is measured; a synthetic
InterferenceTrace (the ``--interference-trace`` flag) multiplies what the
*monitor observes* by the burst's slowdown scaled by the active rung's
interference sensitivity — i.e. downgrading genuinely shrinks the simulated
contention, exactly the relinquish-and-recover dynamic of the paper. A
``latency_fn`` override replaces the observation entirely (deterministic
tests / benchmarks); real compute still runs either way.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager, shard_restore
from repro.compat import set_mesh
from repro.core.controller import SwanController
from repro.engine.events import InterferenceTrace
from repro.engine.jobs import SocJob, StepReport
from repro.engine.rungs import Rung
from repro.engine.timeline import MigrationRecord, Timeline
from repro.launch.steps import cast_params, init_train_state
from repro.runtime.elastic import ElasticController


@dataclasses.dataclass
class SessionResult:
    losses: List[float]
    timeline: Timeline
    state: Any
    final_rung: str
    controller: Optional[SwanController] = None


class TrainSession(SocJob):
    # background personalization training: a foreground burst pauses it
    preemptible = True

    def __init__(self, cfg, rungs: Sequence[Rung], *, optimizer, batch_fn,
                 lr: float = 0.05, compressor=None,
                 ckpt: Optional[CheckpointManager] = None, ckpt_every: int = 0,
                 elastic: Optional[ElasticController] = None,
                 fault_events: Optional[Callable] = None,
                 trace: Optional[InterferenceTrace] = None,
                 adaptive: bool = True, upgrade_patience: int = 5,
                 latency_fn: Optional[Callable] = None,
                 log_every: int = 0, verbose: bool = True,
                 name: str = "train", priority: float = 1.0):
        if not rungs:
            raise ValueError("need at least one rung")
        if latency_fn is not None and any(
                r.latency_estimate_s is None for r in rungs):
            raise ValueError("latency_fn mode needs latency_estimate_s on "
                             "every rung (observations are compared to them)")
        self.cfg = cfg
        self._rungs = list(rungs)
        self.optimizer = optimizer
        self.batch_fn = batch_fn
        self.lr = lr
        self.compressor = compressor
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.elastic = elastic
        self.fault_events = fault_events
        self.trace = trace
        self.adaptive = adaptive and len(self._rungs) > 1
        self.latency_fn = latency_fn
        self.log_every = log_every
        self.verbose = verbose
        self.name = name
        self.priority = float(priority)

        n = len(self._rungs)
        profiles = [r.profile(position=i, n=n)
                    for i, r in enumerate(self._rungs)]
        self.ctl = SwanController(profiles, upgrade_patience=upgrade_patience)
        self.controller = self.ctl  # SocJob protocol name (same object)
        self.timeline = Timeline()
        self._expected: Dict[str, float] = {}  # rung name -> clean latency
        if latency_fn is not None:
            for r in self._rungs:
                self._expected[r.name] = r.latency_estimate_s
        self._steps_on_rung = 0
        self._mesh = None
        self._mesh_key = None
        self._migrate_ckpt: Optional[CheckpointManager] = None
        self._migrate_tmpdir = None
        # job binding (set by bind()/run())
        self._until: Optional[int] = None
        self._step_idx = 0
        self._losses: List[float] = []
        self._state = None
        self._init_state = None
        self._rng_seed = 0
        self._prepared = False
        self._last_dt = 0.0
        self._last_rung_name = self.rung.name
        self._ran_tick = None  # last tick whose step() already executed

    # -- rung / mesh plumbing ----------------------------------------------
    def rungs(self) -> Sequence[Rung]:
        return self._rungs

    @property
    def rung(self) -> Rung:
        return self._rungs[self.ctl.idx]

    def _mesh_for(self, rung: Rung):
        if self.elastic is not None:
            shape = None
            if rung.mesh_shape is not None and \
                    int(np.prod(rung.mesh_shape)) <= self.elastic.n_healthy:
                shape = rung.mesh_shape
            return self.elastic.make_mesh(shape=shape)
        if rung.mesh_shape is not None:
            from repro.compat import make_mesh
            names = ("pod", "data", "model")[-len(rung.mesh_shape):]
            return make_mesh(rung.mesh_shape, names)
        return None

    @staticmethod
    def _mesh_fingerprint(mesh):
        if mesh is None:
            return None
        return (tuple(mesh.devices.shape),
                tuple(d.id for d in mesh.devices.flat))

    def _run_step(self, state, batch):
        fn = self.rung.jitted_step(self.cfg, self.optimizer, lr=self.lr,
                                   compressor=self.compressor)
        if self._mesh is not None:
            with set_mesh(self._mesh):
                return fn(state, batch)
        return fn(state, batch)

    # -- migrations --------------------------------------------------------
    def _ckpt(self) -> CheckpointManager:
        """Manager for migration round-trips: the user's, or a private
        tempdir one (kept separate so an unconfigured session doesn't start
        periodic-checkpointing into a directory nobody reads)."""
        if self.ckpt is not None:
            return self.ckpt
        if self._migrate_ckpt is None:
            # TemporaryDirectory cleans itself up when the session is
            # collected, so migration round-trips don't leak checkpoints
            self._migrate_tmpdir = tempfile.TemporaryDirectory(
                prefix="swan_migrate_")
            self._migrate_ckpt = CheckpointManager(self._migrate_tmpdir.name)
        return self._migrate_ckpt

    def _remesh(self, completed: int, state, new_mesh):
        """One checkpoint round-trip: gather to host under the old mesh,
        re-shard under the new one. ``completed`` is the number of finished
        optimizer steps — a crash-resume from this checkpoint must not skip
        work. Also drops every cached executable — the device set changed
        under them."""
        with obs.get_telemetry().span("train.remesh", job=self.name,
                                      step=completed):
            mgr = self._ckpt()
            mgr.save(completed, state)
            # restore exactly the checkpoint just written — restore_latest
            # could pick up a stale higher-step file in a reused checkpoint
            # directory
            if new_mesh is not None:
                _, state = mgr.restore(completed, mesh=new_mesh)
            else:
                _, state = mgr.restore(completed)
                state = jax.tree_util.tree_map(
                    lambda a: jnp.asarray(a) if hasattr(a, "dtype") else a,
                    state)
            for r in self._rungs:
                r.invalidate()
            self._mesh = new_mesh
            self._mesh_key = self._mesh_fingerprint(new_mesh)
            return state

    def _apply_migration(self, step: int, state, from_rung: Rung,
                         reason: str, completed: int):
        """Carry state from ``from_rung`` onto the (already switched)
        controller's active rung. ``completed`` = optimizer steps finished so
        far (== step before the step runs, step + 1 after). Returns
        (state, MigrationRecord)."""
        to_rung = self.rung
        t0 = time.perf_counter()
        new_mesh = self._mesh_for(to_rung)
        kind = "in-place"
        if self._mesh_fingerprint(new_mesh) != self._mesh_key:
            kind = "remesh"
            state = self._remesh(completed, state, new_mesh)
        if to_rung.param_dtype != from_rung.param_dtype:
            # cast only the parameters: optimizer moments stay float32 (adam
            # keeps full-precision state under bf16 params; recasting them
            # would change the step's input avals and force a recompile)
            state = dict(state)
            state["params"] = cast_params(state["params"], to_rung.dtype)
        cost_s = time.perf_counter() - t0
        expected = self._recalibrate(from_rung, to_rung)
        cost_steps = 0
        if kind == "remesh":
            cost_steps = max(1, int(round(cost_s / expected))) \
                if expected else 1
        rec = self.timeline.record_migration(
            step=step, from_rung=from_rung.name, to_rung=to_rung.name,
            reason=reason, kind=kind, cost_s=round(cost_s, 6),
            cost_steps=cost_steps)
        self._steps_on_rung = 0
        if self.verbose:
            print(f"[swan] step {step}: migrate {from_rung.name} -> "
                  f"{to_rung.name} ({reason}, {kind})")
        return state, rec

    # -- SocJob surface ------------------------------------------------------
    def bind(self, until: int, *, start: int = 0, state=None,
             rng_seed: int = 0) -> "TrainSession":
        """Set this job's work target before handing it to a SwanRuntime.
        ``run()`` does this implicitly for the standalone path."""
        self._until = until
        self._step_idx = start
        self._losses = []
        self._init_state = state
        self._rng_seed = rng_seed
        self._prepared = False
        return self

    @property
    def done(self) -> bool:
        return self._prepared and self._step_idx >= self._until

    def _materialize(self, state):
        """Align a host/checkpoint state with the active rung and place it on
        the current mesh. A checkpoint may have been written on any rung
        (e.g. the bf16 bottom), so the parameter dtype is re-aligned here."""
        state = dict(state)
        state["params"] = cast_params(state["params"], self.rung.dtype)
        if self._mesh is not None:
            host = jax.tree_util.tree_map(
                lambda a: jax.device_get(a) if hasattr(a, "dtype") else a,
                state)
            return shard_restore(host, self._mesh)
        return jax.tree_util.tree_map(
            lambda a: jnp.asarray(a) if hasattr(a, "dtype") else a, state)

    def prepare(self) -> None:
        if self._prepared:
            return
        if self._until is None:
            raise RuntimeError("TrainSession must be bind()-ed (or run via "
                               "run()) before a runtime can step it")
        self._mesh = self._mesh_for(self.rung)
        self._mesh_key = self._mesh_fingerprint(self._mesh)
        state = self._init_state
        if state is None:
            model = self.rung.build_model(self.cfg)
            state = init_train_state(model, self.optimizer,
                                     jax.random.PRNGKey(self._rng_seed),
                                     compressor=self.compressor)
        self._state = self._materialize(state)
        self._prepared = True

    # -- preemption (foreground bursts) --------------------------------------
    def on_pause(self, tick: int) -> None:
        """Checkpoint and *release* the training state — the foreground app
        that preempted us wants the memory. The checkpoint is labeled with
        the completed-step count, so resume (or a crash during the pause)
        restarts exactly at the pre-pause step."""
        if not self._prepared or self._state is None:
            return
        t0 = time.perf_counter()
        self._ckpt().save(self._step_idx, self._state)
        self._state = None
        self.timeline.record_migration(
            step=self._step_idx, from_rung=self.rung.name,
            to_rung=self.rung.name, reason="pause", kind="pause",
            cost_s=round(time.perf_counter() - t0, 6))

    def on_resume(self, tick: int) -> None:
        """Reload the pause checkpoint through the normal restore machinery.
        ``restore_latest`` skips a corrupt/torn newest file (chaos: crash
        mid-write) and falls back to the previous step — in that case the
        step counter rewinds with the state so no optimizer step is skipped;
        in the normal case the restored step IS the pre-pause step."""
        if not self._prepared or self._state is not None:
            return
        t0 = time.perf_counter()
        restored = self._ckpt().restore_latest()
        if restored is None:
            raise RuntimeError(
                f"{self.name}: no readable checkpoint to resume from")
        step, state = restored
        self._state = self._materialize(state)
        self._step_idx = int(step)
        self._steps_on_rung = 0  # first post-resume step re-warms caches
        self.timeline.record_migration(
            step=self._step_idx, from_rung=self.rung.name,
            to_rung=self.rung.name, reason="resume", kind="pause",
            cost_s=round(time.perf_counter() - t0, 6))

    def on_device_loss(self, tick: int, failed: Sequence[int]) -> None:
        """Device loss forces a downgrade + remesh (the runtime already
        marked the shared pool)."""
        if self.elastic is None:
            return
        step = self._step_idx
        prev = self.ctl.idx
        self.ctl.force_downgrade("device-loss")
        if self.ctl.idx != prev:
            # the step hasn't run yet: only `step` steps finished
            self._state, _ = self._apply_migration(
                step, self._state, self._rungs[prev], "device-loss",
                completed=step)
        new_mesh = self._mesh_for(self.rung)
        if self._mesh_fingerprint(new_mesh) != self._mesh_key:
            # no rung change (ladder bottom) but a lost device may hold
            # shards: remesh is still mandatory
            t0 = time.perf_counter()
            self._state = self._remesh(step, self._state, new_mesh)
            self.timeline.record_migration(
                step=step, from_rung=self.rung.name, to_rung=self.rung.name,
                reason="device-loss", kind="remesh",
                cost_s=round(time.perf_counter() - t0, 6), cost_steps=1)
            self._steps_on_rung = 0

    def step(self, tick: int) -> StepReport:
        step = self._step_idx
        rung = self.rung
        self._ran_tick = tick
        batch = self.batch_fn(step)
        warmup = self._steps_on_rung == 0
        # compile=True marks the first quantum on a rung (pays trace+compile)
        # so the trace distinguishes compile spans from steady-state steps
        with obs.get_telemetry().span("train.step", job=self.name, step=step,
                                      rung=rung.name, compile=warmup):
            t0 = time.perf_counter()
            self._state, metrics = self._run_step(self._state, batch)
            loss = float(metrics["loss"])  # blocks until the step is done
            dt = time.perf_counter() - t0
        self._steps_on_rung += 1
        self._losses.append(loss)
        self._last_dt = dt
        self._last_rung_name = rung.name
        leaves = jax.tree_util.tree_leaves(batch)
        work = float(leaves[0].shape[0]) if leaves else 1.0  # samples
        return StepReport(latency_s=dt, work=work, loss=loss, warmup=warmup)

    def observe(self, tick: int, report: StepReport,
                slowdown: float) -> Optional[str]:
        step = self._step_idx
        rung = self.rung
        dt = report.latency_s
        # what the monitor sees
        if self.latency_fn is not None:
            observed = float(self.latency_fn(step, rung, dt))
        else:
            observed = dt * slowdown
        report.observed_s = observed
        self.timeline.record_step(step=step, rung=rung.name,
                                  latency_s=round(dt, 6),
                                  observed_s=round(observed, 6),
                                  loss=report.loss, warmup=report.warmup,
                                  work=report.work)
        return self._monitor_proposal(report, rung, dt, observed)

    def migrate(self, direction: str, reason: str,
                tick: int) -> Optional[MigrationRecord]:
        prev = self.ctl.idx
        self.ctl.commit(direction, reason)
        if self.ctl.idx == prev:
            return None
        # post-observation migrations land after this tick's step (step + 1
        # finished); a pre-step commit (the runtime's energy walk-down) must
        # not label the remesh checkpoint with work that hasn't happened
        ran = self._ran_tick == tick
        self._state, rec = self._apply_migration(
            self._step_idx, self._state, self._rungs[prev], reason,
            completed=self._step_idx + (1 if ran else 0))
        return rec

    def end_tick(self, tick: int) -> None:
        step = self._step_idx
        if self.log_every and (step % self.log_every == 0
                               or step == self._until - 1):
            print(f"step {step:5d} loss {self._losses[-1]:8.4f} "
                  f"({self._last_dt * 1e3:.0f} ms) [{self._last_rung_name}]")
        if self.ckpt is not None and self.ckpt_every and \
                (step + 1) % self.ckpt_every == 0:
            self.ckpt.save(step + 1, self._state)
        self._step_idx = step + 1

    def publish_metrics(self, metrics) -> None:
        if self._losses:
            metrics.gauge("train_loss").labels(job=self.name).set(
                self._losses[-1])
        metrics.gauge("train_steps_total").labels(job=self.name).set(
            float(self._step_idx))

    def finalize(self) -> None:
        if self.ckpt is not None and self._losses:
            self.ckpt.save(self._step_idx, self._state)

    def result(self) -> SessionResult:
        return SessionResult(losses=self._losses, timeline=self.timeline,
                             state=self._state, final_rung=self.rung.name,
                             controller=self.ctl)

    # -- standalone entry point ---------------------------------------------
    def run(self, steps: int, *, start: int = 0, state=None,
            rng_seed: int = 0) -> SessionResult:
        """Train standalone: a single-job SwanRuntime over this session.
        The loop structure is the old event loop's; the one behavioral
        change riding along is the controller's post-migration sample skip
        (migrate -> no bounce), which can shift migration steps by one
        versus pre-SocRuntime timelines."""
        from repro.engine.runtime import SwanRuntime
        self.bind(steps, start=start, state=state, rng_seed=rng_seed)
        rt = SwanRuntime([self], trace=self.trace, elastic=self.elastic,
                         fault_events=self.fault_events)
        rt.run(steps, start=start)
        return self.result()
