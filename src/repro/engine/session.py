"""TrainSession: the live migration loop (paper Fig. 4b, runnable).

The session owns the training state and an event loop that, per step:

1. applies device-loss events (ElasticController.mark_failed +
   SwanController.force_downgrade + mandatory remesh),
2. executes the active Rung's cached jitted step,
3. feeds the observed latency to SwanController, and
4. applies any migration decision *without restarting*:
   - same-mesh migrations (microbatch / kernel / dtype) carry state over in
     place, casting parameters with launch.steps.cast_params when the dtype
     changes;
   - mesh-shape migrations go through one CheckpointManager save/restore
     round-trip against ElasticController.make_mesh, re-sharding parameters
     under the surviving mesh.

Latency semantics: the wall time of each step is measured; a synthetic
InterferenceTrace (the ``--interference-trace`` flag) multiplies what the
*monitor observes* by the burst's slowdown scaled by the active rung's
interference sensitivity — i.e. downgrading genuinely shrinks the simulated
contention, exactly the relinquish-and-recover dynamic of the paper. A
``latency_fn`` override replaces the observation entirely (deterministic
tests / benchmarks); real compute still runs either way.
"""
from __future__ import annotations

import dataclasses
import tempfile
import time
from typing import Any, Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager, shard_restore
from repro.compat import set_mesh
from repro.core.controller import SwanController
from repro.engine.events import InterferenceTrace
from repro.engine.rungs import Rung
from repro.engine.timeline import Timeline
from repro.launch.steps import cast_params, init_train_state
from repro.runtime.elastic import ElasticController


@dataclasses.dataclass
class SessionResult:
    losses: List[float]
    timeline: Timeline
    state: Any
    final_rung: str
    controller: Optional[SwanController] = None


class TrainSession:
    def __init__(self, cfg, rungs: Sequence[Rung], *, optimizer, batch_fn,
                 lr: float = 0.05, compressor=None,
                 ckpt: Optional[CheckpointManager] = None, ckpt_every: int = 0,
                 elastic: Optional[ElasticController] = None,
                 fault_events: Optional[Callable] = None,
                 trace: Optional[InterferenceTrace] = None,
                 adaptive: bool = True, upgrade_patience: int = 5,
                 latency_fn: Optional[Callable] = None,
                 log_every: int = 0, verbose: bool = True):
        if not rungs:
            raise ValueError("need at least one rung")
        if latency_fn is not None and any(
                r.latency_estimate_s is None for r in rungs):
            raise ValueError("latency_fn mode needs latency_estimate_s on "
                             "every rung (observations are compared to them)")
        self.cfg = cfg
        self.rungs = list(rungs)
        self.optimizer = optimizer
        self.batch_fn = batch_fn
        self.lr = lr
        self.compressor = compressor
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.elastic = elastic
        self.fault_events = fault_events
        self.trace = trace
        self.adaptive = adaptive and len(self.rungs) > 1
        self.latency_fn = latency_fn
        self.log_every = log_every
        self.verbose = verbose

        n = len(self.rungs)
        profiles = [r.profile(position=i, n=n) for i, r in enumerate(self.rungs)]
        self.ctl = SwanController(profiles, upgrade_patience=upgrade_patience)
        self.timeline = Timeline()
        self._expected: dict = {}  # rung name -> calibrated clean latency
        if latency_fn is not None:
            for r in self.rungs:
                self._expected[r.name] = r.latency_estimate_s
        self._steps_on_rung = 0
        self._mesh = None
        self._mesh_key = None
        self._migrate_ckpt: Optional[CheckpointManager] = None
        self._migrate_tmpdir = None

    # -- rung / mesh plumbing ----------------------------------------------
    @property
    def rung(self) -> Rung:
        return self.rungs[self.ctl.idx]

    def _mesh_for(self, rung: Rung):
        if self.elastic is not None:
            shape = None
            if rung.mesh_shape is not None and \
                    int(np.prod(rung.mesh_shape)) <= self.elastic.n_healthy:
                shape = rung.mesh_shape
            return self.elastic.make_mesh(shape=shape)
        if rung.mesh_shape is not None:
            from repro.compat import make_mesh
            names = ("pod", "data", "model")[-len(rung.mesh_shape):]
            return make_mesh(rung.mesh_shape, names)
        return None

    @staticmethod
    def _mesh_fingerprint(mesh):
        if mesh is None:
            return None
        return (tuple(mesh.devices.shape),
                tuple(d.id for d in mesh.devices.flat))

    def _run_step(self, state, batch):
        fn = self.rung.jitted_step(self.cfg, self.optimizer, lr=self.lr,
                                   compressor=self.compressor)
        if self._mesh is not None:
            with set_mesh(self._mesh):
                return fn(state, batch)
        return fn(state, batch)

    # -- migrations --------------------------------------------------------
    def _ckpt(self) -> CheckpointManager:
        """Manager for migration round-trips: the user's, or a private
        tempdir one (kept separate so an unconfigured session doesn't start
        periodic-checkpointing into a directory nobody reads)."""
        if self.ckpt is not None:
            return self.ckpt
        if self._migrate_ckpt is None:
            # TemporaryDirectory cleans itself up when the session is
            # collected, so migration round-trips don't leak checkpoints
            self._migrate_tmpdir = tempfile.TemporaryDirectory(
                prefix="swan_migrate_")
            self._migrate_ckpt = CheckpointManager(self._migrate_tmpdir.name)
        return self._migrate_ckpt

    def _remesh(self, completed: int, state, new_mesh):
        """One checkpoint round-trip: gather to host under the old mesh,
        re-shard under the new one. ``completed`` is the number of finished
        optimizer steps — a crash-resume from this checkpoint must not skip
        work. Also drops every cached executable — the device set changed
        under them."""
        mgr = self._ckpt()
        mgr.save(completed, state)
        # restore exactly the checkpoint just written — restore_latest could
        # pick up a stale higher-step file in a reused checkpoint directory
        if new_mesh is not None:
            _, state = mgr.restore(completed, mesh=new_mesh)
        else:
            _, state = mgr.restore(completed)
            state = jax.tree_util.tree_map(
                lambda a: jnp.asarray(a) if hasattr(a, "dtype") else a, state)
        for r in self.rungs:
            r.invalidate()
        self._mesh = new_mesh
        self._mesh_key = self._mesh_fingerprint(new_mesh)
        return state

    def _apply_migration(self, step: int, state, from_rung: Rung,
                         reason: str, completed: int):
        """Carry state from ``from_rung`` onto the (already switched)
        controller's active rung. ``completed`` = optimizer steps finished so
        far (== step before the step runs, step + 1 after). Returns
        (state, MigrationRecord)."""
        to_rung = self.rung
        t0 = time.perf_counter()
        new_mesh = self._mesh_for(to_rung)
        kind = "in-place"
        if self._mesh_fingerprint(new_mesh) != self._mesh_key:
            kind = "remesh"
            state = self._remesh(completed, state, new_mesh)
        if to_rung.param_dtype != from_rung.param_dtype:
            # cast only the parameters: optimizer moments stay float32 (adam
            # keeps full-precision state under bf16 params; recasting them
            # would change the step's input avals and force a recompile)
            state = dict(state)
            state["params"] = cast_params(state["params"], to_rung.dtype)
        cost_s = time.perf_counter() - t0
        expected = self._expected.get(to_rung.name)
        # re-anchor the monitor: prefer the rung's own calibration, else
        # scale the departing rung's by the ladder's relative latencies
        if expected is None:
            base = self._expected.get(from_rung.name)
            if base is not None and from_rung.rel_latency > 0:
                expected = base * (to_rung.rel_latency / from_rung.rel_latency)
        if expected is not None:
            self.ctl.calibrate(expected)
        cost_steps = 0
        if kind == "remesh":
            cost_steps = max(1, int(round(cost_s / expected))) \
                if expected else 1
        rec = self.timeline.record_migration(
            step=step, from_rung=from_rung.name, to_rung=to_rung.name,
            reason=reason, kind=kind, cost_s=round(cost_s, 6),
            cost_steps=cost_steps)
        self._steps_on_rung = 0
        if self.verbose:
            print(f"[swan] step {step}: migrate {from_rung.name} -> "
                  f"{to_rung.name} ({reason}, {kind})")
        return state, rec

    def _sync_rung(self, step: int, state, prev_idx: int, completed: int):
        if self.ctl.idx == prev_idx:
            return state
        state, _ = self._apply_migration(
            step, state, self.rungs[prev_idx],
            self.ctl.migrations[-1].reason, completed)
        return state

    # -- event loop --------------------------------------------------------
    def run(self, steps: int, *, start: int = 0, state=None,
            rng_seed: int = 0) -> SessionResult:
        self._mesh = self._mesh_for(self.rung)
        self._mesh_key = self._mesh_fingerprint(self._mesh)
        if state is None:
            model = self.rung.build_model(self.cfg)
            state = init_train_state(model, self.optimizer,
                                     jax.random.PRNGKey(rng_seed),
                                     compressor=self.compressor)
        else:
            # a resumed checkpoint may have been written on any rung (e.g.
            # the bf16 bottom); the session starts on the controller's
            # active rung, so align the parameter dtype here
            state = dict(state)
            state["params"] = cast_params(state["params"], self.rung.dtype)
        if self._mesh is not None:
            host = jax.tree_util.tree_map(
                lambda a: jax.device_get(a) if hasattr(a, "dtype") else a, state)
            state = shard_restore(host, self._mesh)
        else:
            state = jax.tree_util.tree_map(
                lambda a: jnp.asarray(a) if hasattr(a, "dtype") else a, state)

        losses: List[float] = []
        for step in range(start, steps):
            # 1. hard events: device loss forces a downgrade + remesh
            if self.fault_events is not None and self.elastic is not None:
                failed = tuple(self.fault_events(step, self.elastic.healthy_ids()))
                if failed:
                    self.elastic.mark_failed(failed)
                    prev = self.ctl.idx
                    self.ctl.force_downgrade("device-loss")
                    if self.ctl.idx != prev:
                        # the step hasn't run yet: only `step` steps finished
                        state = self._sync_rung(step, state, prev,
                                                completed=step)
                    new_mesh = self._mesh_for(self.rung)
                    if self._mesh_fingerprint(new_mesh) != self._mesh_key:
                        # no rung change (ladder bottom) but a lost device
                        # may hold shards: remesh is still mandatory
                        t0 = time.perf_counter()
                        state = self._remesh(step, state, new_mesh)
                        self.timeline.record_migration(
                            step=step, from_rung=self.rung.name,
                            to_rung=self.rung.name, reason="device-loss",
                            kind="remesh",
                            cost_s=round(time.perf_counter() - t0, 6),
                            cost_steps=1)
                        self._steps_on_rung = 0

            # 2. execute one step on the active rung
            rung = self.rung
            t0 = time.perf_counter()
            state, metrics = self._run_step(state, self.batch_fn(step))
            loss = float(metrics["loss"])  # blocks until the step is done
            dt = time.perf_counter() - t0
            warmup = self._steps_on_rung == 0
            self._steps_on_rung += 1

            # 3. what the monitor sees
            if self.latency_fn is not None:
                observed = float(self.latency_fn(step, rung, dt))
            elif self.trace is not None:
                observed = dt * self.trace.effective_slowdown(
                    step, rung.interference_sensitivity)
            else:
                observed = dt
            losses.append(loss)
            self.timeline.record_step(step=step, rung=rung.name,
                                      latency_s=round(dt, 6),
                                      observed_s=round(observed, 6),
                                      loss=loss, warmup=warmup)

            # 4. adapt
            if self.adaptive:
                feed = True
                if self.latency_fn is None:
                    if warmup:
                        feed = False  # first step on a rung pays compile
                    elif rung.name not in self._expected:
                        # calibrate this rung's clean latency from the wall
                        # measurement. Synthetic traces never slow the actual
                        # machine, so dt is clean even mid-burst; under real
                        # interference (no trace) a rung first visited while
                        # pressured calibrates high, which only delays
                        # detection until the post-clear upgrade re-visits it
                        self._expected[rung.name] = dt
                        self.ctl.calibrate(dt)
                if feed:
                    prev = self.ctl.idx
                    self.ctl.observe_step(observed)
                    state = self._sync_rung(step, state, prev,
                                            completed=step + 1)

            if self.log_every and (step % self.log_every == 0
                                   or step == steps - 1):
                print(f"step {step:5d} loss {loss:8.4f} ({dt * 1e3:.0f} ms) "
                      f"[{rung.name}]")
            if self.ckpt is not None and self.ckpt_every and \
                    (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, state)

        if self.ckpt is not None and losses:
            self.ckpt.save(steps, state)
        return SessionResult(losses=losses, timeline=self.timeline,
                             state=state, final_rung=self.rung.name,
                             controller=self.ctl)
