"""Rung: an *executable* ladder entry.

The Swan planner's pruned ladder (core/cost.py) is a list of ChoiceProfiles —
passive cost-model objects. A Rung is the runnable counterpart: the knobs a
live session can actually switch mid-training (microbatch, attention kernel,
parameter dtype, mesh shape) plus a lazily-compiled-and-cached jitted train
step built from launch/steps.py. ``rungs_from_ladder`` maps a ChoiceProfile
ladder onto Rungs so the planner's output becomes directly runnable;
``default_rung_ladder`` builds a sensible downgrade ladder when no planner ran
(the CLI path).

Migration compatibility: two Rungs with the same ``mesh_shape`` can exchange
state in place (dtype changes go through launch.steps.cast_params); differing
mesh shapes require a checkpoint round-trip (session.py owns that).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.cost import ChoiceProfile, ladder_sensitivities

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
           "float16": jnp.float16}


@dataclasses.dataclass
class Rung:
    """One executable execution choice. Fastest/costliest rungs sit at the
    top of a ladder; every field below is switchable at a migration."""
    name: str
    microbatch: int = 1
    attn_impl: str = "chunked"
    param_dtype: str = "float32"
    mesh_shape: Optional[Tuple[int, ...]] = None  # None = single-process jit
    chunk: int = 1024
    remat: str = "none"
    compression: str = "none"
    # fraction of a co-tenant's contention this rung still feels (1.0 = full
    # overlap with the contended resource; cheap rungs relinquish it)
    interference_sensitivity: float = 1.0
    # latency relative to the ladder head (used to scale calibrations onto
    # rungs that have never run) and an absolute planner estimate if one exists
    rel_latency: float = 1.0
    latency_estimate_s: Optional[float] = None

    def __post_init__(self):
        self._model = None
        self._model_key = None
        self._jitted = None
        self._jitted_key = None

    # -- identity ----------------------------------------------------------
    def signature(self) -> Tuple:
        return (self.microbatch, self.attn_impl, self.param_dtype,
                self.mesh_shape, self.chunk, self.remat, self.compression)

    @property
    def dtype(self):
        return _DTYPES[self.param_dtype]

    # -- construction ------------------------------------------------------
    @classmethod
    def from_mesh_choice(cls, choice, *, name: Optional[str] = None,
                         **overrides) -> "Rung":
        """Build a Rung from a core.choices.MeshChoice (or anything exposing
        ``rung_fields()``)."""
        fields = dict(choice.rung_fields())
        fields.update(overrides)
        return cls(name=name or getattr(choice, "name", "rung"), **fields)

    # -- executable surface ------------------------------------------------
    def build_model(self, cfg):
        """Model under this rung's kernel/dtype knobs (cached per config)."""
        from repro.models.registry import build_model
        key = (cfg.name, self.signature())
        if self._model is None or self._model_key != key:
            self._model = build_model(cfg, impl=self.attn_impl, chunk=self.chunk,
                                      remat=self.remat, param_dtype=self.dtype)
            self._model_key = key
        return self._model

    def train_step_fn(self, model, optimizer, *, lr: float = 0.05,
                      compressor=None):
        """The raw (unjitted) step — what dryrun lowers with explicit
        shardings and what ``jitted_step`` wraps for live execution."""
        from repro.launch.steps import build_train_step
        from repro.optim.compression import Compressor
        comp = compressor or Compressor(self.compression)
        return build_train_step(model, optimizer, microbatch=self.microbatch,
                                lr=lr, compressor=comp)

    def jitted_step(self, cfg, optimizer, *, lr: float = 0.05,
                    compressor=None):
        """Lazily-compiled cached jitted step: first call on a rung compiles,
        later calls (including after migrating away and back) reuse it."""
        key = (cfg.name, self.signature(), optimizer.name, float(lr),
               getattr(compressor, "scheme", self.compression))
        if self._jitted is None or self._jitted_key != key:
            model = self.build_model(cfg)
            self._jitted = jax.jit(self.train_step_fn(
                model, optimizer, lr=lr, compressor=compressor))
            self._jitted_key = key
        return self._jitted

    def invalidate(self):
        """Drop the compiled step (required after the device set changes —
        a remesh makes every cached executable stale)."""
        self._jitted = None
        self._jitted_key = None

    def profile(self, *, position: int = 0, n: int = 1) -> ChoiceProfile:
        """A ChoiceProfile view of this rung so SwanController (which walks
        ChoiceProfile ladders) can drive it directly."""
        lat = self.latency_estimate_s if self.latency_estimate_s is not None \
            else self.rel_latency
        return ChoiceProfile(choice=self, latency_s=lat, energy_j=lat,
                             power_w=1.0, cost_key=(n - position,))


def rungs_from_ladder(profiles: Sequence[ChoiceProfile], **overrides
                      ) -> List[Rung]:
    """Map a pruned ChoiceProfile ladder (fastest first, MeshChoice-backed)
    onto executable Rungs, preserving order; latency estimates come from the
    profiles and interference sensitivities from the cost model's ladder
    positions."""
    if not profiles:
        raise ValueError("empty ladder")
    sens = ladder_sensitivities(len(profiles))
    head_lat = profiles[0].latency_s
    out = []
    for i, p in enumerate(profiles):
        out.append(Rung.from_mesh_choice(
            p.choice, name=p.name,
            interference_sensitivity=sens[i],
            rel_latency=p.latency_s / max(head_lat, 1e-12),
            latency_estimate_s=p.latency_s, **overrides))
    return out


def default_rung_ladder(*, batch: int, microbatch: int = 1,
                        attn_impl: str = "chunked",
                        mesh_shape: Optional[Tuple[int, ...]] = None,
                        include_bf16: bool = True) -> List[Rung]:
    """Downgrade ladder for the CLI path (no planner run): each rung trades
    latency for relinquished burst compute — deeper gradient accumulation
    shrinks the per-microbatch working set, and the bottom rung additionally
    halves parameter memory traffic with bfloat16."""
    if microbatch < 1 or batch % microbatch:
        raise ValueError(f"microbatch {microbatch} does not divide batch "
                         f"{batch}; the accumulation reshape would fail")
    specs = [("full", microbatch, "float32", 1.00),
             ("accum", microbatch * 2, "float32", 1.15),
             ("lean", microbatch * 4, "bfloat16" if include_bf16 else "float32",
              1.35)]
    specs = [(n, mb, dt, rl) for n, mb, dt, rl in specs if batch % mb == 0]
    sens = ladder_sensitivities(len(specs))
    return [Rung(name=n, microbatch=mb, attn_impl=attn_impl, param_dtype=dt,
                 mesh_shape=mesh_shape, interference_sensitivity=s,
                 rel_latency=rl)
            for (n, mb, dt, rl), s in zip(specs, sens)]
