"""Chaos fault injection for SwanRuntime.

The robustness claims this repo makes — pause/resume is exact, a torn
checkpoint costs bounded progress, pool pressure degrades service instead of
crashing it — are only claims until something actually goes wrong. The
:class:`ChaosInjector` makes things go wrong *deterministically*: a seeded
schedule of faults drawn from every failure class the runtime handles,
applied through the same public surfaces a real fault would arrive through.
The runtime consults it at the top of each tick (``SwanRuntime(chaos=...)``)
and multiplies its ``latency_multiplier`` into every job's observed slowdown;
it never special-cases an injected fault, so each one exercises exactly the
recovery path the organic version would.

Fault classes (``ChaosEvent.kind``):

- ``device_loss``     — fail one healthy device in the shared elastic pool;
                        jobs remesh via their normal ``on_device_loss`` path.
- ``pool_pressure``   — a co-tenant grabs KV blocks out of a paged serve
                        engine's pool (``engine.hold_blocks``) for
                        ``duration`` ticks; admission degrades per policy
                        (shed / serialize), residents are never starved.
- ``ckpt_torn``       — simulate a crash mid-checkpoint-write: a torn file
                        (valid header, wrong payload) appears as the *newest*
                        step, plus the orphan ``.tmp`` such a crash leaves.
                        The next restore must skip it and fall back.
- ``thermal_spike``   — dump ``magnitude`` onto the shared die temperature;
                        the closed-loop throttle engages until migrations
                        shed enough heat.
- ``latency_spike``   — multiply every job's observed latency by
                        ``magnitude`` for ``duration`` ticks (a co-tenant
                        burst the trace didn't script).
- ``fg_burst``        — the user picks up the phone: inject a foreground
                        burst of ``duration`` ticks into the
                        ForegroundAppJob, which makes the runtime pause and
                        later resume every preemptible job.

Every applied fault is appended to ``injector.log`` (and its class to
``injector.applied``) so a harness can assert coverage; faults whose target
is absent (no elastic pool, no paged engine, no foreground job) are logged
as skipped rather than silently dropped.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

KINDS = ("device_loss", "pool_pressure", "ckpt_torn", "thermal_spike",
         "latency_spike", "fg_burst")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    tick: int
    kind: str
    duration: int = 1      # ticks (pool_pressure / latency_spike / fg_burst)
    magnitude: float = 2.0  # blocks | temp | latency multiplier (by kind)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}; "
                             f"one of {KINDS}")
        if self.duration < 1:
            raise ValueError(f"duration must be >= 1, got {self.duration}")


class ChaosInjector:
    """Applies a deterministic fault schedule to a running SwanRuntime."""

    def __init__(self, events: Sequence[ChaosEvent] = ()):
        self.events = sorted(events, key=lambda e: (e.tick, e.kind))
        self.log: List[Dict[str, Any]] = []
        self.applied: Set[str] = set()
        # latency spikes are pure intervals — precomputed so
        # latency_multiplier is a cheap read on every job every tick
        self._lat: List[Tuple[int, int, float]] = [
            (e.tick, e.tick + e.duration, float(e.magnitude))
            for e in self.events if e.kind == "latency_spike"]
        self._holds: List[Tuple[int, Any]] = []  # (release_tick, engine)
        self._by_tick: Dict[int, List[ChaosEvent]] = {}
        for e in self.events:
            self._by_tick.setdefault(e.tick, []).append(e)

    # -- deterministic random schedules --------------------------------------
    @classmethod
    def random(cls, seed: int, horizon: int, *,
               kinds: Sequence[str] = KINDS,
               events_per_kind: int = 2) -> "ChaosInjector":
        """A seeded schedule with ``events_per_kind`` of every fault class
        spread over ``[horizon//8, horizon)`` — late enough that each job has
        warmed up, deterministic for a given (seed, horizon, kinds)."""
        rng = np.random.default_rng(seed)
        lo = max(1, horizon // 8)
        events = []
        for kind in kinds:
            for _ in range(events_per_kind):
                tick = int(rng.integers(lo, max(lo + 1, horizon * 3 // 4)))
                dur = int(rng.integers(2, max(3, horizon // 8)))
                if kind == "thermal_spike":
                    mag = float(rng.uniform(0.8, 1.5))
                elif kind == "latency_spike":
                    mag = float(rng.uniform(1.5, 4.0))
                elif kind == "pool_pressure":
                    mag = float(rng.integers(3, 10))  # blocks
                else:
                    mag = float(rng.integers(0, 1 << 30))  # selector entropy
                events.append(ChaosEvent(tick=tick, kind=kind,
                                         duration=dur, magnitude=mag))
        return cls(events)

    # -- runtime hooks --------------------------------------------------------
    def latency_multiplier(self, tick: int) -> float:
        m = 1.0
        for a, b, mult in self._lat:
            if a <= tick < b:
                m *= mult
        return m

    def begin_tick(self, tick: int, runtime) -> None:
        # release pool holds whose interval ended
        due = [(t, e) for t, e in self._holds if t <= tick]
        if due:
            self._holds = [(t, e) for t, e in self._holds if t > tick]
            for _, engine in due:
                engine.release_held()
                self._log(tick, "pool_pressure", released=True)
        for event in self._by_tick.get(tick, ()):
            self._apply(tick, event, runtime)

    # -- application ----------------------------------------------------------
    def _log(self, tick: int, kind: str, **detail) -> None:
        self.log.append({"tick": tick, "kind": kind, **detail})

    def _apply(self, tick: int, e: ChaosEvent, runtime) -> None:
        handler = getattr(self, f"_apply_{e.kind}")
        handler(tick, e, runtime)

    def _apply_device_loss(self, tick: int, e: ChaosEvent, runtime) -> None:
        if runtime.elastic is None:
            self._log(tick, e.kind, skipped="no elastic pool")
            return
        healthy = list(runtime.elastic.healthy_ids())
        if len(healthy) <= 1:
            self._log(tick, e.kind, skipped="would kill the last device")
            return
        victim = healthy[int(e.magnitude) % len(healthy)]
        runtime.elastic.mark_failed((victim,))
        for job in runtime.jobs:
            if not job.done and not job.paused:
                job.on_device_loss(tick, (victim,))
        self.applied.add(e.kind)
        self._log(tick, e.kind, device=victim)

    def _apply_pool_pressure(self, tick: int, e: ChaosEvent, runtime) -> None:
        hit = False
        for job in runtime.jobs:
            engine = getattr(job, "engine", None)
            if engine is None or not hasattr(engine, "hold_blocks"):
                continue
            held = engine.hold_blocks(int(e.magnitude))
            if held or engine.kv is not None:
                hit = True
                self._holds.append((tick + e.duration, engine))
                self._log(tick, e.kind, job=job.name, blocks=held,
                          until=tick + e.duration)
        if hit:
            self.applied.add(e.kind)
        else:
            self._log(tick, e.kind, skipped="no paged serve engine")

    def _apply_ckpt_torn(self, tick: int, e: ChaosEvent, runtime) -> None:
        hit = False
        for job in runtime.jobs:
            mgr_fn = getattr(job, "_ckpt", None)
            if mgr_fn is None or job.done:
                continue
            mgr = mgr_fn()
            # the torn file must be the NEWEST step so restore_latest tries
            # it first — exactly where a crash mid-save would leave it
            steps = mgr.steps()
            step = (steps[-1] if steps else int(
                getattr(job, "_step_idx", 0))) + 1
            path = mgr._path(step)
            from repro.checkpoint.store import serialize_pytree
            blob = serialize_pytree({"step": step, "state": {"torn": True}})
            with open(path, "wb") as f:
                f.write(blob[:max(8, len(blob) // 2)])  # torn mid-write
            with open(path + ".tmp", "wb") as f:  # the orphan temp file
                f.write(b"\x00" * 16)
            hit = True
            self.applied.add(e.kind)
            self._log(tick, e.kind, job=job.name, step=step,
                      path=os.path.basename(path))
        if not hit:
            self._log(tick, e.kind, skipped="no checkpointing job")

    def _apply_thermal_spike(self, tick: int, e: ChaosEvent,
                             runtime) -> None:
        trace = runtime.trace
        if trace is None or not hasattr(trace, "temp"):
            self._log(tick, e.kind, skipped="no thermal trace")
            return
        trace.temp += float(e.magnitude)
        self.applied.add(e.kind)
        self._log(tick, e.kind, temp=round(trace.temp, 3))

    def _apply_latency_spike(self, tick: int, e: ChaosEvent,
                             runtime) -> None:
        # interval already active via latency_multiplier; log the onset
        self.applied.add(e.kind)
        self._log(tick, e.kind, mult=e.magnitude,
                  until=tick + e.duration)

    def _apply_fg_burst(self, tick: int, e: ChaosEvent, runtime) -> None:
        for job in runtime.jobs:
            if getattr(job, "is_foreground", False) and \
                    hasattr(job, "add_burst"):
                job.add_burst(tick, tick + e.duration)
                self.applied.add(e.kind)
                self._log(tick, e.kind, until=tick + e.duration)
                return
        self._log(tick, e.kind, skipped="no foreground job")

    # -- reporting ------------------------------------------------------------
    def skipped_kinds(self) -> Set[str]:
        return {entry["kind"] for entry in self.log if "skipped" in entry}

    def to_json(self) -> Dict[str, Any]:
        return {"events": [dataclasses.asdict(e) for e in self.events],
                "applied": sorted(self.applied),
                "log": self.log}


# ---------------------------------------------------------------------------
# Fleet-scale fault classes (injected through the FleetCoordinator)
# ---------------------------------------------------------------------------

FLEET_KINDS = ("client_churn", "update_dropped", "update_duplicated",
               "update_corrupt", "coordinator_crash")


class FleetChaos:
    """Coordinator-level fault injection for federated rounds.

    Where :class:`ChaosInjector` breaks a single SoC's runtime, this breaks
    the *fleet* around it — the network and the coordinator process:

    - ``client_churn``       — an invited client vanishes mid-round (user
                               closed the app / lost connectivity); it never
                               reports, the coordinator must degrade to a
                               smaller accepted set.
    - ``update_dropped``     — a finished client's update is lost in
                               delivery; same coordinator-side symptom as
                               churn but after the work (and energy) was
                               spent.
    - ``update_duplicated``  — at-least-once delivery re-sends an update;
                               the coordinator must dedup by client id or it
                               double-counts.
    - ``update_corrupt``     — bit-flip in transit; the payload no longer
                               matches its checksum and must be rejected.
    - ``coordinator_crash``  — the coordinator process dies mid-aggregation,
                               after ``crash_at[1]`` updates of round
                               ``crash_at[0]`` were accepted; resume must
                               neither lose nor double-count them.

    All decisions are stateless functions of ``(seed, round, client)`` so a
    crash-resumed coordinator sees the identical fault schedule.
    """

    def __init__(self, seed: int = 0, *, churn_prob: float = 0.0,
                 churn_rounds: Optional[Dict[int, float]] = None,
                 drop_prob: float = 0.0, dup_prob: float = 0.0,
                 corrupt_prob: float = 0.0,
                 crash_at: Optional[Tuple[int, int]] = None):
        self.seed = int(seed)
        self.churn_prob = float(churn_prob)
        self.churn_rounds = dict(churn_rounds or {})
        self.drop_prob = float(drop_prob)
        self.dup_prob = float(dup_prob)
        self.corrupt_prob = float(corrupt_prob)
        self.crash_at = tuple(crash_at) if crash_at is not None else None
        self._crash_fired = False
        self.log: List[Dict[str, Any]] = []
        self.applied: Set[str] = set()

    # -- schedule -------------------------------------------------------------
    def churn_fraction(self, rnd: int) -> float:
        return float(self.churn_rounds.get(int(rnd), self.churn_prob))

    def churn(self, rnd: int, cids: Sequence[int]) -> Set[int]:
        """Subset of the invited cohort that silently vanishes this round."""
        p = self.churn_fraction(rnd)
        if p <= 0.0 or not len(cids):
            return set()
        rng = np.random.default_rng((self.seed, int(rnd), 101))
        mask = rng.random(len(cids)) < p
        gone = {int(c) for c, m in zip(cids, mask) if m}
        if gone:
            self.applied.add("client_churn")
            self.log.append({"round": int(rnd), "kind": "client_churn",
                             "clients": sorted(gone)})
        return gone

    def delivery(self, rnd: int, cid: int) -> str:
        """Fate of one client's finished update: ok|dropped|duplicated|corrupt."""
        total = self.drop_prob + self.dup_prob + self.corrupt_prob
        if total <= 0.0:
            return "ok"
        rng = np.random.default_rng((self.seed, int(rnd), int(cid), 103))
        u = float(rng.random())
        if u < self.drop_prob:
            fate = "dropped"
        elif u < self.drop_prob + self.dup_prob:
            fate = "duplicated"
        elif u < total:
            fate = "corrupt"
        else:
            return "ok"
        self.applied.add(f"update_{fate}")
        self.log.append({"round": int(rnd), "kind": f"update_{fate}",
                         "client": int(cid)})
        return fate

    def corrupt_bytes(self, rnd: int, cid: int,
                      delta: np.ndarray) -> np.ndarray:
        """Flip one element so the payload no longer matches its checksum."""
        rng = np.random.default_rng((self.seed, int(rnd), int(cid), 107))
        out = np.array(delta, copy=True)
        out.flat[int(rng.integers(out.size))] += 1.0
        return out

    def crash_now(self, rnd: int, n_accepted: int) -> bool:
        """True exactly once: when round ``crash_at[0]`` has accepted
        ``crash_at[1]`` updates. The coordinator raises after its durable
        save, like a real process death."""
        if self.crash_at is None or self._crash_fired:
            return False
        r, n = self.crash_at
        if int(rnd) == int(r) and int(n_accepted) >= int(n):
            self._crash_fired = True
            self.applied.add("coordinator_crash")
            self.log.append({"round": int(rnd), "kind": "coordinator_crash",
                             "after_accepts": int(n_accepted)})
            return True
        return False

    # -- reporting ------------------------------------------------------------
    def to_json(self) -> Dict[str, Any]:
        return {"seed": self.seed,
                "probs": {"churn": self.churn_prob, "drop": self.drop_prob,
                          "dup": self.dup_prob, "corrupt": self.corrupt_prob},
                "churn_rounds": {str(k): v
                                 for k, v in self.churn_rounds.items()},
                "crash_at": list(self.crash_at) if self.crash_at else None,
                "applied": sorted(self.applied),
                "log": self.log}
