"""SwanRuntime: one event loop and one arbiter over every job on the SoC.

The paper's engine exists because many workloads contend for one SoC's
resources. Before this module the repo had two disjoint runtimes — the
training session's event loop and the serving engine's — each reacting to
its own view of the device. ``SwanRuntime`` owns the single loop:

- **shared event sources**: the InterferenceTrace / ThermalTrace / fault
  source advance once per tick. The thermal model integrates the **summed**
  power draw of every active job — the die does not care which job heated
  it — so one job's downgrade genuinely cools the machine for the others.
- **arbitrated migration**: each job's SwanController *proposes* ("down" /
  "up") from its own monitor, but the runtime commits at most one downgrade
  per tick — to the job that relinquishes the most contended resource per
  unit of goodput lost (priority-weighted, :meth:`SocJob.relinquish_score`)
  — instead of every pressured controller thrashing down independently.
  Upgrades are also serialized (one per tick) so re-adding power cannot
  re-trip the throttle in a single jump.
- **SLO-headroom arbitration**: a job carrying a latency SLO
  (``SocJob.slo_headroom``) changes the auction from relative goodput to
  absolute deadlines. A violator generates downgrade pressure even when its
  own monitor is quiet, is the *last* candidate to be downgraded further
  (its co-tenants shed first), and upgrades are held device-wide until
  every SLO is back inside its target.
- **foreground preemption**: while a :class:`ForegroundAppJob` burst is
  active, every preemptible job is *paused* — not downgraded. Background
  training checkpoints and releases its state on pause and resumes at the
  exact pre-pause step when the burst ends.
- **shared energy budget**: an optional ``core.energy.EnergyLoan`` is
  charged with the summed draw every tick; once the borrowed energy would
  push the battery below critical, the runtime walks the hungriest job
  down-ladder ("energy" migrations) and blocks upgrades until the budget
  recovers. A ``ChargingTrace`` repays the loan while the charger is
  plugged (and ``day_ticks`` applies the paper's daily surplus), so a
  recharging battery re-enables upgrades.
- **merged timeline**: per-job Timelines are merged into one job-tagged
  runtime timeline (``Timeline.merged``) for benchmarks and tests.

A single-job runtime reduces exactly to the old TrainSession loop —
``TrainSession.run`` is now a thin wrapper that builds one.

Chaos: a fault injector (``engine/chaos.py``) can be attached via
``chaos=``; it is consulted at the top of every tick (to inject device loss,
thermal spikes, pool pressure, foreground bursts, torn checkpoints) and its
``latency_multiplier`` rides on top of the shared trace's slowdown (latency
spikes). The runtime itself never special-cases a fault kind — every
injected fault exercises exactly the recovery path a real one would.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro import obs as _obs
from repro.engine.jobs import SocJob
from repro.engine.timeline import Timeline
from repro.obs.schema import versioned


@dataclasses.dataclass
class RuntimeResult:
    timeline: Timeline  # merged, job-tagged
    ticks: int
    work: Dict[str, float]  # goodput units per job
    virtual_time_s: float  # sum over ticks of the slowest job's observed time
    jobs: Dict[str, SocJob] = dataclasses.field(default_factory=dict)
    preemptions: int = 0  # foreground pauses committed by the runtime

    def summary(self) -> dict:
        return versioned({
            "ticks": self.ticks,
            "virtual_time_s": round(self.virtual_time_s, 6),
            "work": {k: round(v, 4) for k, v in self.work.items()},
            "preemptions": self.preemptions,
            "timeline": self.timeline.summary()})

    def to_json(self) -> dict:
        """Full machine-readable result: the summary plus the merged
        timeline's step/migration records, all through the shared
        ``repro.obs`` encoder (one ``schema_version`` to evolve)."""
        out = self.summary()
        out["timeline"] = self.timeline.to_json()
        return out


class SwanRuntime:
    def __init__(self, jobs: Sequence[SocJob], *, trace=None,
                 elastic=None, fault_events=None,
                 energy=None, battery_level: float = 1.0,
                 energy_unit_j: float = 1.0,
                 charging=None, day_ticks: Optional[int] = None,
                 chaos=None, verbose: bool = False, telemetry=None):
        if not jobs:
            raise ValueError("need at least one job")
        names = [j.name for j in jobs]
        if len(set(names)) != len(names):
            raise ValueError(f"job names must be unique, got {names}")
        self.jobs = list(jobs)
        self.trace = trace
        self.elastic = elastic
        self.fault_events = fault_events
        self.energy = energy  # core.energy.EnergyLoan (shared battery)
        self.battery_level = float(battery_level)
        self.energy_unit_j = float(energy_unit_j)  # joules per power unit/tick
        self.charging = charging  # engine.events.ChargingTrace
        self.day_ticks = day_ticks  # ticks per "day" for EnergyLoan.repay_daily
        self.chaos = chaos  # engine.chaos.ChaosInjector
        self.verbose = verbose
        self.work: Dict[str, float] = {j.name: 0.0 for j in self.jobs}
        self.virtual_time_s = 0.0
        self.ticks = 0
        self.preemptions = 0
        self._preempted: Set[str] = set()  # jobs paused BY the runtime
        # None -> follow the process-global telemetry (repro.obs), so a CLI
        # enabling it before run() is picked up without plumbing
        self._telemetry = telemetry

    @property
    def obs(self):
        # getattr: arbitration unit tests build bare instances via __new__
        tel = getattr(self, "_telemetry", None)
        return tel if tel is not None else _obs.get_telemetry()

    # -- telemetry -----------------------------------------------------------
    @staticmethod
    def _rung_name(job: SocJob) -> str:
        """Audit-only rung label; tolerant of minimal SocJob test doubles
        that skip the ladder surface."""
        rung = getattr(job, "active_rung", None)
        return getattr(rung, "name", "")

    def _soc_state(self) -> Dict:
        """Energy-loan + thermal context snapshot for audit records."""
        out: Dict = {}
        if self.energy is not None:
            out["energy"] = {
                "loan_j": round(float(self.energy.loan_j), 6),
                "available": bool(self.energy.available(self.battery_level)),
                "battery_level": self.battery_level,
            }
        tr = self.trace
        if tr is not None and hasattr(tr, "temp"):
            out["thermal"] = {"temp": round(float(tr.temp), 6),
                              "throttled": bool(getattr(tr, "throttled",
                                                        False))}
        return out

    def _decision_ctx(self, active: List[SocJob],
                      proposals: List[Tuple[SocJob, str]]) -> Dict:
        """Full scoring context at decision time — what the audit stores so
        "why did the arbiter pick that job" is answerable after the fact."""
        ctx = {
            "scores": {j.name: j.relinquish_score() for j in active},
            "slo_headroom": {j.name: j.slo_headroom() for j in active},
            "proposals": {j.name: p for j, p in proposals},
        }
        ctx.update(self._soc_state())
        return ctx

    def _publish_metrics(self, tick: int, active: List[SocJob]) -> None:
        tel = self.obs
        if not tel.enabled:
            return
        m = tel.metrics
        tr = self.trace
        if tr is not None and hasattr(tr, "temp"):
            m.gauge("thermal_temp_c", "shared die temperature").set(
                float(tr.temp))
            m.gauge("thermal_throttled", "1 while the die throttles").set(
                1.0 if getattr(tr, "throttled", False) else 0.0)
        if self.energy is not None:
            m.gauge("energy_loan_j", "outstanding borrowed energy").set(
                float(self.energy.loan_j))
            m.gauge("energy_available",
                    "1 while the loan budget allows full draw").set(
                1.0 if self.energy.available(self.battery_level) else 0.0)
            m.gauge("battery_level").set(float(self.battery_level))
        m.gauge("runtime_active_jobs").set(float(len(active)))
        m.gauge("runtime_preemptions_total").set(float(self.preemptions))
        for job in active:
            m.gauge("job_rung_idx", "active ladder position (0 = top)"
                    ).labels(job=job.name).set(float(job.rung_idx))
            m.gauge("job_work_total", "cumulative goodput units").labels(
                job=job.name).set(float(self.work[job.name]))
            job.publish_metrics(m)

    # -- shared event sources ------------------------------------------------
    def _advance_trace(self, tick: int, total_power: float) -> None:
        """Advance the shared trace one tick under the summed active-job
        power draw. ThermalTrace advances at most once per distinct step and
        heats with the *first* call's sensitivity — this call — so the die
        temperature integrates everything running, not any one job's view;
        per-job reads afterwards (:meth:`_slowdown_for`, same tick) only
        scale the throttle by each job's own sensitivity. InterferenceTrace
        is stateless so this is a no-op read."""
        if self.trace is not None:
            self.trace.effective_slowdown(tick, total_power)

    def _slowdown_for(self, tick: int, sensitivity: float) -> float:
        s = 1.0
        if self.trace is not None:
            s = self.trace.effective_slowdown(tick, sensitivity)
        if self.chaos is not None:
            s *= self.chaos.latency_multiplier(tick)
        return s

    # -- foreground preemption ----------------------------------------------
    def _preempt(self, tick: int) -> None:
        """Pause every preemptible job while a foreground burst is active;
        resume the ones *this runtime* paused once it clears (a job paused by
        the caller stays paused)."""
        unfinished = [j for j in self.jobs if not j.done]
        fg_active = any(j.is_foreground and j.demands_soc(tick)
                        for j in unfinished)
        for job in unfinished:
            if not job.preemptible:
                continue
            if fg_active and not job.paused:
                job.pause(tick)
                self._preempted.add(job.name)
                self.preemptions += 1
                self._audit_event(tick, job, "pause", rule="foreground")
                if self.verbose:
                    print(f"[swan] tick {tick}: {job.name} paused "
                          f"(foreground)")
            elif not fg_active and job.paused and \
                    job.name in self._preempted:
                job.resume(tick)
                self._preempted.discard(job.name)
                self._audit_event(tick, job, "resume", rule="foreground")
                if self.verbose:
                    print(f"[swan] tick {tick}: {job.name} resumed")

    def _audit_event(self, tick: int, job: SocJob, event: str, *,
                     rule: str = "", detail: str = "") -> None:
        tel = self.obs
        if not tel.enabled:
            return
        rung = self._rung_name(job)
        tel.audit.record(tick=tick, job=job.name, event=event, rule=rule,
                         from_rung=rung, to_rung=rung, detail=detail,
                         **self._soc_state())

    # -- energy --------------------------------------------------------------
    def _account_energy(self, tick: int, total_power: float,
                        active: List[SocJob]) -> Tuple[bool, bool]:
        """Charge this tick's draw to the shared EnergyLoan (and repay it
        while the charger is plugged / at day boundaries). Returns
        (pressed, downgraded): while the borrowed energy would push the
        battery below critical, upgrades are blocked and the hungriest job
        walks one rung toward the low-power end per tick until the ladders
        bottom out — that walk-down also consumes the tick's one-downgrade
        allowance."""
        if self.energy is None:
            return False, False
        self.energy.borrow(total_power * self.energy_unit_j)
        if self.charging is not None:
            rate = self.charging.rate(tick)
            if rate > 0.0:
                self.energy.repay(rate * self.energy_unit_j)
        if self.day_ticks and tick > 0 and tick % self.day_ticks == 0:
            self.energy.repay_daily()
        if self.energy.available(self.battery_level):
            return False, False
        cands = [j for j in active if j.can_downgrade()]
        if cands:
            hungriest = max(cands, key=lambda j: j.power_draw())
            self._commit(hungriest, "down", "energy", tick,
                         ctx=self._decision_ctx(active, [])
                         if self.obs.enabled else None)
        return True, bool(cands)

    # -- arbitration ---------------------------------------------------------
    def _arbitrate(self, tick: int, active: List[SocJob],
                   proposals: List[Tuple[SocJob, str]],
                   allow_upgrades: bool = True,
                   allow_downgrades: bool = True,
                   ctx: Optional[Dict] = None) -> None:
        violators = [j for j in active
                     if (h := j.slo_headroom()) is not None and h < 0.0]
        downs = [j for j, p in proposals if p == "down"]
        if downs or violators:
            if not allow_downgrades:
                return  # this tick's downgrade allowance is already spent
            # contention somewhere on the die (a pressured monitor, or an SLO
            # in violation): downgrade the ONE job whose next rung
            # relinquishes the most contended resource per unit of goodput
            # lost — but never a job already violating its SLO while a
            # co-tenant with headroom can shed instead (taking more from the
            # violator deepens the violation it was meant to fix)
            cands = [j for j in active if j.can_downgrade()]
            safe = [j for j in cands if j not in violators]
            pool = safe or cands
            if pool:
                best = max(pool, key=lambda j: j.relinquish_score())
                if best in downs:
                    reason = "interference"
                elif violators:
                    reason = "slo"
                else:
                    reason = "arbitration"
                self._commit(best, "down", reason, tick, ctx=ctx)
            return
        if not allow_upgrades:
            return
        ups = [j for j, p in proposals if p == "up"]
        # an upgrade re-adds power: hold it while any SLO is still violated
        # (checked above: reaching here means no violators) and never lift a
        # job into violating its own freshly-met SLO
        ups = [j for j in ups
               if (h := j.slo_headroom()) is None or h > 0.0]
        if ups:
            best = max(ups, key=lambda j: j.priority)
            self._commit(best, "up", "clear", tick, ctx=ctx)

    def _commit(self, job: SocJob, direction: str, reason: str,
                tick: int, ctx: Optional[Dict] = None) -> None:
        tel = self.obs
        from_rung = self._rung_name(job) if tel.enabled else ""
        rec = job.migrate(direction, reason, tick)
        if tel.enabled:
            # "commit": the migration applied; "veto": the arbiter chose this
            # job but its controller refused (ladder edge / cooldown). Either
            # way the full scoring context that decided it is preserved.
            tel.audit.record(
                tick=tick, job=job.name,
                event="commit" if rec is not None else "veto",
                direction=direction, rule=reason, from_rung=from_rung,
                to_rung=self._rung_name(job),
                **(ctx if ctx is not None else self._soc_state()))
            if rec is not None:
                tel.metrics.counter("runtime_migrations_total").labels(
                    job=job.name, direction=direction, reason=reason).inc()
        if rec is not None and self.verbose:
            print(f"[swan] tick {tick}: {job.name} {rec.from_rung} -> "
                  f"{rec.to_rung} ({reason})")

    # -- the loop ------------------------------------------------------------
    def run(self, until: int, *, start: int = 0) -> RuntimeResult:
        """Run ticks ``start .. until-1`` (stopping early once every job is
        done). One tick = one scheduling quantum for every active job."""
        for job in self.jobs:
            job.prepare()
        tel = self.obs
        for tick in range(start, until):
            with tel.span("runtime.tick", tick=tick):
                # 0. chaos injection + foreground preemption decide who runs
                if self.chaos is not None:
                    self.chaos.begin_tick(tick, self)
                self._preempt(tick)
                unfinished = [j for j in self.jobs if not j.done]
                if not unfinished:
                    break
                active = [j for j in unfinished if not j.paused]
                for job in active:
                    job.begin_tick(tick)
                # 1. hard events: device loss on the shared pool
                if self.fault_events is not None and self.elastic is not None:
                    failed = tuple(self.fault_events(
                        tick, self.elastic.healthy_ids()))
                    if failed:
                        self.elastic.mark_failed(failed)
                        for job in active:
                            job.on_device_loss(tick, failed)
                            self._audit_event(
                                tick, job, "device-loss", rule="device-loss",
                                detail=f"failed={sorted(failed)}")
                # 2. shared event sources tick once, under the summed draw
                total_power = sum(j.power_draw() for j in active)
                self._advance_trace(tick, total_power)
                # 3. energy budget
                energy_pressed, energy_walked = self._account_energy(
                    tick, total_power, active)
                # 4. one quantum per job; collect monitor proposals
                proposals: List[Tuple[SocJob, str]] = []
                tick_times: List[float] = []
                for job in active:
                    report = job.step(tick)
                    prop = job.observe(
                        tick, report,
                        self._slowdown_for(tick, job.sensitivity()))
                    self.work[job.name] += report.work
                    tick_times.append(report.observed_s if report.observed_s
                                      is not None else report.latency_s)
                    if prop is not None:
                        proposals.append((job, prop))
                    if tel.enabled:
                        # labeled by rung so the per-rung quantile table in
                        # launch.obs_report can separate the ladder's measured
                        # costs (e.g. per-draft-depth speculative latency)
                        tel.metrics.histogram(
                            "job_step_latency_s",
                            "wall latency of one scheduling quantum").labels(
                            job=job.name,
                            rung=job.active_rung.name).observe(
                            report.latency_s)
                if tick_times:
                    # jobs share the tick; its virtual duration is the slowest
                    self.virtual_time_s += max(tick_times)
                # 5. arbitrated migration (at most one down, one up per tick —
                # an energy walk-down counts as the tick's downgrade)
                ctx = self._decision_ctx(active, proposals) \
                    if tel.enabled else None
                if tel.enabled:
                    for j, p in proposals:
                        rung = self._rung_name(j)
                        tel.audit.record(tick=tick, job=j.name,
                                         event="propose", direction=p,
                                         rule="monitor", from_rung=rung,
                                         to_rung=rung, **ctx)
                self._arbitrate(tick, active, proposals,
                                allow_upgrades=not energy_pressed,
                                allow_downgrades=not energy_walked,
                                ctx=ctx)
                for job in active:
                    job.end_tick(tick)
                self._publish_metrics(tick, active)
                tel.snap(tick)
                self.ticks += 1
        # a burst running past the horizon must not strand paused jobs:
        # whoever the runtime paused is resumed before the loop closes
        for job in self.jobs:
            if job.paused and job.name in self._preempted:
                job.resume(until)
                self._preempted.discard(job.name)
        for job in self.jobs:
            job.finalize()
        return self.result()

    def result(self) -> RuntimeResult:
        merged = Timeline.merged({j.name: j.timeline for j in self.jobs})
        return RuntimeResult(timeline=merged, ticks=self.ticks,
                             work=dict(self.work),
                             virtual_time_s=self.virtual_time_s,
                             jobs={j.name: j for j in self.jobs},
                             preemptions=self.preemptions)
