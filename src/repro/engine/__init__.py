"""Adaptive SoC runtime (paper Fig. 4b as a live engine).

``rungs``    — executable training ladder entries (Rung) with cached jitted
               steps.
``jobs``     — the SocJob protocol (anything migratable the arbiter can
               schedule), serving rungs and ServeJob.
``runtime``  — SwanRuntime: the single event loop + arbiter over every job
               sharing the SoC (traces, thermals, faults, energy budget).
``events``   — interference traces + device-loss event sources.
``timeline`` — machine-readable migration/step history (job-tagged when
               merged across a runtime).
``session``  — TrainSession: the training job; standalone ``run()`` is a
               single-job runtime.
"""
from repro.engine.events import (Burst, DeviceLossEvent, FaultModelEvents,  # noqa: F401
                                 InterferenceTrace, ScriptedFaults,
                                 ThermalTrace)
from repro.engine.jobs import (ServeJob, ServeRung, SocJob,  # noqa: F401
                               StepReport, default_serve_ladder)
from repro.engine.rungs import (Rung, default_rung_ladder,  # noqa: F401
                                rungs_from_ladder)
from repro.engine.runtime import RuntimeResult, SwanRuntime  # noqa: F401
from repro.engine.session import SessionResult, TrainSession  # noqa: F401
from repro.engine.timeline import (MigrationRecord, StepRecord,  # noqa: F401
                                   Timeline)
