"""Adaptive training runtime (paper Fig. 4b as a live engine).

``rungs``    — executable ladder entries (Rung) with cached jitted steps.
``events``   — interference traces + device-loss event sources.
``timeline`` — machine-readable migration/step history.
``session``  — TrainSession: the event loop that migrates between Rungs
               mid-training without restarting.
"""
from repro.engine.events import (Burst, DeviceLossEvent, FaultModelEvents,  # noqa: F401
                                 InterferenceTrace, ScriptedFaults)
from repro.engine.rungs import (Rung, default_rung_ladder,  # noqa: F401
                                rungs_from_ladder)
from repro.engine.session import SessionResult, TrainSession  # noqa: F401
from repro.engine.timeline import (MigrationRecord, StepRecord,  # noqa: F401
                                   Timeline)
