"""Machine-readable history of an adaptive training run.

Every migration is recorded as (step, from/to rung, reason, kind, cost), and
every step as (rung, wall latency, observed latency, loss). The benchmark
harness (benchmarks/table3_interference.py) consumes this to plot adaptive vs
static step-time curves, and tests assert on it instead of scraping stdout.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional

from repro.obs.schema import encode_record, versioned


@dataclasses.dataclass
class MigrationRecord:
    step: int
    from_rung: str
    to_rung: str
    reason: str  # "interference" | "clear" | "device-loss" | "energy" | ...
    kind: str  # "in-place" (state carried over) | "remesh" (ckpt round-trip)
    cost_s: float = 0.0
    cost_steps: int = 0  # migration stall expressed in expected step times
    job: str = ""  # owning SocJob in a merged multi-job runtime timeline


@dataclasses.dataclass
class StepRecord:
    step: int
    rung: str
    latency_s: float  # wall time of the step
    observed_s: float  # latency fed to the interference monitor
    loss: float
    warmup: bool = False  # first step on a rung (includes compile)
    work: float = 0.0  # goodput units this step (samples trained / tokens out)
    job: str = ""  # owning SocJob in a merged multi-job runtime timeline


class Timeline:
    def __init__(self):
        self.migrations: List[MigrationRecord] = []
        self.steps: List[StepRecord] = []

    def record_migration(self, **kw) -> MigrationRecord:
        rec = MigrationRecord(**kw)
        self.migrations.append(rec)
        return rec

    def record_step(self, **kw) -> StepRecord:
        rec = StepRecord(**kw)
        self.steps.append(rec)
        return rec

    # -- merging (multi-job runtimes) ---------------------------------------
    @classmethod
    def merged(cls, tagged: dict) -> "Timeline":
        """Merge per-job timelines ({job_name: Timeline}) into one runtime
        timeline, tagging every record with its owning job and interleaving
        by step index."""
        out = cls()
        for name, tl in tagged.items():
            for s in tl.steps:
                out.steps.append(dataclasses.replace(s, job=name))
            for m in tl.migrations:
                out.migrations.append(dataclasses.replace(m, job=name))
        out.steps.sort(key=lambda s: (s.step, s.job))
        out.migrations.sort(key=lambda m: (m.step, m.job))
        return out

    def for_job(self, job: str) -> "Timeline":
        """Single-job view of a merged timeline."""
        out = Timeline()
        out.steps = [s for s in self.steps if s.job == job]
        out.migrations = [m for m in self.migrations if m.job == job]
        return out

    def jobs(self) -> List[str]:
        seen: List[str] = []
        for r in list(self.steps) + list(self.migrations):
            if r.job and r.job not in seen:
                seen.append(r.job)
        return seen

    # -- views -------------------------------------------------------------
    def step_times(self, *, observed: bool = False) -> List[float]:
        return [s.observed_s if observed else s.latency_s for s in self.steps]

    def rung_at(self, step: int) -> Optional[str]:
        for s in self.steps:
            if s.step == step:
                return s.rung
        return None

    def summary(self) -> dict:
        # a device-loss remesh at the ladder bottom records from == to;
        # that is a migration but not a rung downgrade
        downs = sum(1 for m in self.migrations
                    if m.reason != "clear" and m.from_rung != m.to_rung)
        ups = sum(1 for m in self.migrations if m.reason == "clear")
        steady = [s.latency_s for s in self.steps if not s.warmup]
        out = {
            "n_steps": len(self.steps),
            "n_migrations": len(self.migrations),
            "downgrades": downs,
            "upgrades": ups,
            "remesh_migrations": sum(1 for m in self.migrations
                                     if m.kind == "remesh"),
            "migration_cost_s": round(sum(m.cost_s for m in self.migrations), 6),
            "migration_cost_steps": sum(m.cost_steps for m in self.migrations),
            "mean_step_s": (sum(steady) / len(steady)) if steady else 0.0,
        }
        jobs = self.jobs()
        if jobs:  # merged multi-job timeline: per-job breakdown rides along
            out["jobs"] = {
                j: {"steps": sum(1 for s in self.steps if s.job == j),
                    "work": round(sum(s.work for s in self.steps if s.job == j), 4),
                    "migrations": sum(1 for m in self.migrations if m.job == j)}
                for j in jobs}
        return out

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict:
        # step/migration records go through the shared repro.obs encoder so
        # the whole telemetry plane evolves in one place (schema_version)
        return versioned({
            "migrations": [encode_record(m) for m in self.migrations],
            "steps": [encode_record(s) for s in self.steps],
            "summary": self.summary()})

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        return path

    @classmethod
    def from_json(cls, payload: dict) -> "Timeline":
        tl = cls()
        for m in payload.get("migrations", ()):
            tl.record_migration(**m)
        for s in payload.get("steps", ()):
            tl.record_step(**s)
        return tl
