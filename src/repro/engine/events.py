"""Event sources the TrainSession reacts to.

Three kinds, matching the paper's migration triggers:

- InterferenceTrace: synthetic co-tenant bursts (the ``--interference-trace``
  CLI flag). A burst multiplies the *observed* step latency the controller
  sees; how much of it a rung actually feels is scaled by that rung's
  ``interference_sensitivity`` — downgrading relinquishes the contended
  resource, so cheap rungs see a smaller multiplier (paper Fig. 4b / Table 3).
- ThermalTrace (paper §3.3): sustained-load throttling with its own
  hysteresis constants. Unlike a scripted burst it is *closed-loop*: heat
  accumulates with the active rung's power draw (proxied by its
  interference sensitivity), the throttle engages above ``trigger_temp``
  and — crucially — releases only below ``release_temp`` < trigger, so the
  slowdown persists until a downgrade actually sheds enough heat.
- Device-loss events (FaultModel-sampled or scripted): hard interference that
  routes through SwanController.force_downgrade and forces a remesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runtime.fault import FaultModel


@dataclasses.dataclass(frozen=True)
class Burst:
    start: int  # first slowed step (inclusive)
    stop: int  # first clean step again (exclusive)
    slowdown: float  # latency multiplier at full sensitivity

    def active(self, step: int) -> bool:
        return self.start <= step < self.stop


@dataclasses.dataclass(frozen=True)
class InterferenceTrace:
    bursts: Tuple[Burst, ...] = ()

    @classmethod
    def parse(cls, spec: str) -> "InterferenceTrace":
        """Parse ``"start:stop:slowdown[,start:stop:slowdown...]"``,
        e.g. ``"40:80:2.5,120:140:3"``."""
        bursts = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            fields = part.split(":")
            if len(fields) != 3:
                raise ValueError(f"bad burst {part!r}; want start:stop:slowdown")
            start, stop, slow = int(fields[0]), int(fields[1]), float(fields[2])
            if stop <= start or slow < 1.0:
                raise ValueError(f"bad burst {part!r}: need stop>start, slowdown>=1")
            bursts.append(Burst(start, stop, slow))
        return cls(tuple(sorted(bursts, key=lambda b: b.start)))

    def slowdown(self, step: int) -> float:
        """Full-sensitivity multiplier at ``step`` (max over active bursts)."""
        active = [b.slowdown for b in self.bursts if b.active(step)]
        return max(active) if active else 1.0

    def effective_slowdown(self, step: int, sensitivity: float) -> float:
        """Multiplier actually felt by a rung with the given sensitivity."""
        return 1.0 + (self.slowdown(step) - 1.0) * sensitivity

    def active(self, step: int) -> bool:
        return self.slowdown(step) > 1.0

    def to_json(self) -> List[dict]:
        return [dataclasses.asdict(b) for b in self.bursts]


@dataclasses.dataclass
class ThermalTrace:
    """Closed-loop thermal throttling (paper §3.3).

    A normalized die temperature integrates ``heat_rate * sensitivity``
    (the active rung's power draw) against a constant ``cool_rate`` each
    step. Hysteresis: the throttle engages when temperature crosses
    ``trigger_temp`` and releases only once it has fallen below
    ``release_temp`` — a downgraded rung whose heat generation drops under
    ``cool_rate`` therefore *recovers* after a cooling interval, while a
    rung that keeps burning stays throttled indefinitely. This is the
    dynamic a step-scripted burst cannot express: the slowdown's duration
    depends on what the controller migrates to.

    Stateful: ``effective_slowdown`` advances the simulation one step per
    call, in step order — exactly how TrainSession drives its trace.
    """
    heat_rate: float = 0.05     # temp gained per step at sensitivity 1.0
    cool_rate: float = 0.02     # temp shed per step, always
    slowdown: float = 2.5       # throttle multiplier at full sensitivity
    trigger_temp: float = 1.0   # throttle engages at/above this
    release_temp: float = 0.5   # ...and releases at/below this (hysteresis)
    temp: float = dataclasses.field(default=0.0, init=False)
    throttled: bool = dataclasses.field(default=False, init=False)
    _last_step: int = dataclasses.field(default=-1, init=False)

    def __post_init__(self):
        if self.heat_rate <= 0 or self.cool_rate <= 0:
            raise ValueError("heat_rate and cool_rate must be > 0")
        if self.slowdown < 1.0:
            raise ValueError(f"slowdown must be >= 1, got {self.slowdown}")
        if not 0 <= self.release_temp < self.trigger_temp:
            raise ValueError("need 0 <= release_temp < trigger_temp")

    @classmethod
    def parse(cls, spec: str) -> "ThermalTrace":
        """Parse ``"heat:cool:slowdown"`` or
        ``"heat:cool:slowdown:trigger:release"`` (the ``--thermal-trace``
        flag), e.g. ``"0.05:0.02:2.5"``."""
        fields = [f.strip() for f in spec.split(":")]
        if len(fields) not in (3, 5):
            raise ValueError(f"bad thermal spec {spec!r}; want "
                             f"heat:cool:slowdown[:trigger:release]")
        heat, cool, slow = (float(f) for f in fields[:3])
        kw = {}
        if len(fields) == 5:
            kw = {"trigger_temp": float(fields[3]),
                  "release_temp": float(fields[4])}
        return cls(heat_rate=heat, cool_rate=cool, slowdown=slow, **kw)

    def effective_slowdown(self, step: int, sensitivity: float) -> float:
        """Advance to ``step`` under the active rung's power draw; return the
        latency multiplier that rung observes.

        The thermal state advances at most once per distinct ``step`` (the
        first call's sensitivity is the power draw that heats the die), so
        re-evaluating the same step for several candidate rungs — the
        adaptive-vs-static curve pattern — reads the throttle without
        secretly re-heating it."""
        if step != self._last_step:
            self._last_step = step
            self.temp = max(0.0, self.temp
                            + self.heat_rate * sensitivity - self.cool_rate)
            if not self.throttled and self.temp >= self.trigger_temp:
                self.throttled = True
            elif self.throttled and self.temp <= self.release_temp:
                self.throttled = False
        if not self.throttled:
            return 1.0
        return 1.0 + (self.slowdown - 1.0) * sensitivity

    def active(self, step: int) -> bool:
        return self.throttled

    def to_json(self) -> dict:
        return {"heat_rate": self.heat_rate, "cool_rate": self.cool_rate,
                "slowdown": self.slowdown, "trigger_temp": self.trigger_temp,
                "release_temp": self.release_temp}


@dataclasses.dataclass(frozen=True)
class ChargingTrace:
    """Charger plug/unplug schedule: ``(start, stop, watts)`` intervals.

    While an interval is active the runtime repays the shared EnergyLoan at
    ``watts`` joules per tick (the same normalized units jobs borrow in), so
    a recharging battery walks the loan back under critical and re-enables
    rung upgrades — the recovery half of the paper's energy-loan accounting
    (§5.1), which ``repay_daily`` only models at day granularity.
    """
    intervals: Tuple[Tuple[int, int, float], ...] = ()

    @classmethod
    def parse(cls, spec: str) -> "ChargingTrace":
        """Parse ``"start:stop:watts[,start:stop:watts...]"``,
        e.g. ``"40:80:5"`` (the ``--charging-trace`` flag)."""
        out = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            fields = part.split(":")
            if len(fields) != 3:
                raise ValueError(f"bad charge interval {part!r}; want "
                                 f"start:stop:watts")
            start, stop, watts = int(fields[0]), int(fields[1]), float(fields[2])
            if stop <= start or watts <= 0:
                raise ValueError(f"bad charge interval {part!r}: need "
                                 f"stop>start, watts>0")
            out.append((start, stop, watts))
        return cls(tuple(sorted(out)))

    def rate(self, tick: int) -> float:
        """Charger watts at ``tick`` (0.0 = unplugged)."""
        return sum(w for a, b, w in self.intervals if a <= tick < b)

    def active(self, tick: int) -> bool:
        return self.rate(tick) > 0.0

    def to_json(self) -> List[dict]:
        return [{"start": a, "stop": b, "watts": w}
                for a, b, w in self.intervals]


@dataclasses.dataclass(frozen=True)
class DeviceLossEvent:
    step: int
    device_ids: Tuple[int, ...]


class ScriptedFaults:
    """Deterministic device-loss schedule: {step: (device ids to fail)}."""

    def __init__(self, schedule: Dict[int, Sequence[int]]):
        self.schedule = {int(k): tuple(v) for k, v in schedule.items()}

    def __call__(self, step: int, healthy_ids: Sequence[int]
                 ) -> Tuple[int, ...]:
        ids = self.schedule.get(step, ())
        return tuple(i for i in ids if i in set(healthy_ids))


class FaultModelEvents:
    """Adapter from runtime.fault.FaultModel's per-step sampling to the
    session's event callback."""

    def __init__(self, fault_model: FaultModel):
        self.fault_model = fault_model

    def __call__(self, step: int, healthy_ids: Sequence[int]
                 ) -> Tuple[int, ...]:
        healthy_ids = list(healthy_ids)
        mask = self.fault_model.step_failures(len(healthy_ids))
        return tuple(i for i, dead in zip(healthy_ids, mask) if dead)
