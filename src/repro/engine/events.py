"""Event sources the TrainSession reacts to.

Two kinds, matching the paper's two migration triggers:

- InterferenceTrace: synthetic co-tenant bursts (the ``--interference-trace``
  CLI flag). A burst multiplies the *observed* step latency the controller
  sees; how much of it a rung actually feels is scaled by that rung's
  ``interference_sensitivity`` — downgrading relinquishes the contended
  resource, so cheap rungs see a smaller multiplier (paper Fig. 4b / Table 3).
- Device-loss events (FaultModel-sampled or scripted): hard interference that
  routes through SwanController.force_downgrade and forces a remesh.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.runtime.fault import FaultModel


@dataclasses.dataclass(frozen=True)
class Burst:
    start: int  # first slowed step (inclusive)
    stop: int  # first clean step again (exclusive)
    slowdown: float  # latency multiplier at full sensitivity

    def active(self, step: int) -> bool:
        return self.start <= step < self.stop


@dataclasses.dataclass(frozen=True)
class InterferenceTrace:
    bursts: Tuple[Burst, ...] = ()

    @classmethod
    def parse(cls, spec: str) -> "InterferenceTrace":
        """Parse ``"start:stop:slowdown[,start:stop:slowdown...]"``,
        e.g. ``"40:80:2.5,120:140:3"``."""
        bursts = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            fields = part.split(":")
            if len(fields) != 3:
                raise ValueError(f"bad burst {part!r}; want start:stop:slowdown")
            start, stop, slow = int(fields[0]), int(fields[1]), float(fields[2])
            if stop <= start or slow < 1.0:
                raise ValueError(f"bad burst {part!r}: need stop>start, slowdown>=1")
            bursts.append(Burst(start, stop, slow))
        return cls(tuple(sorted(bursts, key=lambda b: b.start)))

    def slowdown(self, step: int) -> float:
        """Full-sensitivity multiplier at ``step`` (max over active bursts)."""
        active = [b.slowdown for b in self.bursts if b.active(step)]
        return max(active) if active else 1.0

    def effective_slowdown(self, step: int, sensitivity: float) -> float:
        """Multiplier actually felt by a rung with the given sensitivity."""
        return 1.0 + (self.slowdown(step) - 1.0) * sensitivity

    def active(self, step: int) -> bool:
        return self.slowdown(step) > 1.0

    def to_json(self) -> List[dict]:
        return [dataclasses.asdict(b) for b in self.bursts]


@dataclasses.dataclass(frozen=True)
class DeviceLossEvent:
    step: int
    device_ids: Tuple[int, ...]


class ScriptedFaults:
    """Deterministic device-loss schedule: {step: (device ids to fail)}."""

    def __init__(self, schedule: Dict[int, Sequence[int]]):
        self.schedule = {int(k): tuple(v) for k, v in schedule.items()}

    def __call__(self, step: int, healthy_ids: Sequence[int]
                 ) -> Tuple[int, ...]:
        ids = self.schedule.get(step, ())
        return tuple(i for i in ids if i in set(healthy_ids))


class FaultModelEvents:
    """Adapter from runtime.fault.FaultModel's per-step sampling to the
    session's event callback."""

    def __init__(self, fault_model: FaultModel):
        self.fault_model = fault_model

    def __call__(self, step: int, healthy_ids: Sequence[int]
                 ) -> Tuple[int, ...]:
        healthy_ids = list(healthy_ids)
        mask = self.fault_model.step_failures(len(healthy_ids))
        return tuple(i for i, dead in zip(healthy_ids, mask) if dead)
