"""SocJob: the unit of work the SwanRuntime arbiter schedules.

Swan's premise is that many workloads contend for one SoC; the engine's job
is to arbitrate between them. A ``SocJob`` is anything that can live under
that arbitration:

- it exposes a **rung ladder** (``rungs()``) — ordered fastest/costliest
  first, each rung carrying an ``interference_sensitivity`` (how much of a
  co-tenant's contention it still feels / how much contended resource it
  holds) and a ``rel_latency`` (goodput cost of running there);
- it executes one scheduling quantum at a time (``step(tick)`` ->
  :class:`StepReport`), reports what its monitor sees (``observe``), and can
  **migrate** between rungs without restarting (``migrate``).

Lifecycle: every job is in one of three states —

- ``RUNNING``: scheduled every tick;
- ``PAUSED``: preempted (a foreground app owns the SoC). A paused job is
  skipped entirely — no quantum, no power draw, no proposals. Pausing a
  :class:`~repro.engine.session.TrainSession` checkpoints and *releases* its
  state (the foreground app wants the memory); resuming restores it through
  the existing rung/checkpoint machinery at the exact pre-pause step;
- ``DRAINING``: winding down — a draining ServeJob stops admitting queued
  requests and is done once the residents retire.

Three implementations ship: ``engine.session.TrainSession`` (training; its
old event loop is now the single-job special case of the runtime's),
:class:`ServeJob` below, which wraps ``launch.serve.ContinuousBatchingEngine``
with a *serving* rung ladder — decode concurrency cap, attention impl, KV
dtype — so serving becomes migratable exactly like training, and
:class:`ForegroundAppJob`, the preemptor: an interactive app whose scripted
bursts pause every preemptible co-tenant outright (paper §3 — user
experience is an absolute constraint, not a goodput trade).

SLO: a ServeJob can carry a p99 token-latency target (``slo_p99_s``). The
runtime then arbitrates on **SLO headroom** instead of relative goodput: a
job in violation generates downgrade pressure on its co-tenants and is
itself the last candidate to be downgraded further.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.core.controller import SwanController
from repro.core.cost import ChoiceProfile, ladder_sensitivities
from repro.engine.timeline import MigrationRecord, Timeline

RUNNING = "RUNNING"
PAUSED = "PAUSED"
DRAINING = "DRAINING"


@dataclasses.dataclass
class StepReport:
    """What one scheduling quantum of a job produced. (Job completion is the
    ``SocJob.done`` property, polled by the runtime — not part of the
    report.)"""
    latency_s: float  # wall time of the quantum
    work: float = 0.0  # goodput units (samples trained / tokens emitted)
    loss: Optional[float] = None  # training jobs report their loss
    warmup: bool = False  # first quantum on a rung (compile tail)
    observed_s: Optional[float] = None  # filled in by observe()
    # paged serving: fraction of the KV block pool in use at this quantum
    # (None for jobs without a pool) — the arbiter-visible memory-pressure
    # signal that complements latency
    pool_pressure: Optional[float] = None


def trace_latency_fn(trace):
    """Deterministic ``latency_fn`` for benchmarks/tests: each rung's planner
    estimate scaled by the trace's slowdown at that rung's sensitivity —
    what a real measurement would observe, minus machine noise. Every rung
    needs ``latency_estimate_s``."""
    def fn(step, rung, dt):
        eff = trace.effective_slowdown(step, rung.interference_sensitivity) \
            if trace is not None else 1.0
        return rung.latency_estimate_s * eff
    return fn


class SocJob:
    """Base/protocol for runtime-schedulable jobs.

    Subclasses must provide ``name``, ``priority``, ``controller`` (a
    SwanController over the ladder), ``timeline``, ``rungs()``, ``done``,
    ``step``, ``observe`` and ``migrate``; the arbitration helpers below are
    derived. Rung entries only need ``name``, ``interference_sensitivity``
    and ``rel_latency`` attributes (``power_draw`` optional — defaults to the
    sensitivity, the same power proxy ThermalTrace integrates).
    """

    name: str = "job"
    priority: float = 1.0
    controller: SwanController
    timeline: Timeline
    state: str = RUNNING
    # a foreground burst may pause this job outright (background work)
    preemptible: bool = False
    # this job IS the foreground app: while it demands the SoC, the runtime
    # pauses every preemptible co-tenant
    is_foreground: bool = False

    # -- ladder --------------------------------------------------------------
    def rungs(self) -> Sequence[Any]:
        raise NotImplementedError

    @property
    def rung_idx(self) -> int:
        return self.controller.idx

    @property
    def active_rung(self):
        return self.rungs()[self.rung_idx]

    def sensitivity(self) -> float:
        return float(self.active_rung.interference_sensitivity)

    def power_draw(self) -> float:
        """Power this job's active rung draws (normalized units); the runtime
        sums this across jobs to heat the shared ThermalTrace and to charge
        the EnergyLoan."""
        p = getattr(self.active_rung, "power_draw", None)
        return float(p) if p is not None else self.sensitivity()

    def can_downgrade(self) -> bool:
        return self.controller.can_downgrade()

    def can_upgrade(self) -> bool:
        return self.controller.can_upgrade()

    def relinquish_score(self) -> float:
        """Arbitration score for downgrading this job one rung: contended
        resource relinquished per fraction of goodput lost, discounted by
        priority. Under pressure the runtime downgrades the argmax — the job
        that gives the co-tenants the most relief at the least cost."""
        rungs = self.rungs()
        i = self.rung_idx
        if i + 1 >= len(rungs):
            return float("-inf")
        a, b = rungs[i], rungs[i + 1]
        dsens = max(0.0, float(a.interference_sensitivity)
                    - float(b.interference_sensitivity))
        # goodput fraction lost stepping down: rate ~ 1/rel_latency. Floored
        # at 1% so a ladder that declares identical rel_latency (a "free"
        # downgrade) still scores on a scale a co-tenant's sensitivity gap
        # and priority can compete with, instead of winning every auction
        lost = max(0.01, 1.0 - float(a.rel_latency) / float(b.rel_latency))
        return dsens / (lost * max(float(self.priority), 1e-9))

    # -- SLO -----------------------------------------------------------------
    def slo_headroom(self) -> Optional[float]:
        """Fraction of the latency SLO still unspent (negative = violating;
        ``None`` = this job carries no SLO). The runtime arbitrates on this:
        a violator's co-tenants are downgraded first, and upgrades are held
        while any job is in violation."""
        return None

    # -- lifecycle -----------------------------------------------------------
    @property
    def done(self) -> bool:
        raise NotImplementedError

    @property
    def paused(self) -> bool:
        return self.state == PAUSED

    def pause(self, tick: int) -> None:
        """Preempt this job (foreground burst / explicit request). Idempotent;
        subclasses override :meth:`on_pause` to checkpoint and release
        resources."""
        if self.state == PAUSED:
            return
        self.on_pause(tick)
        self.state = PAUSED

    def resume(self, tick: int) -> None:
        """Undo :meth:`pause`; subclasses override :meth:`on_resume` to
        restore released state (the pre-pause step, exactly)."""
        if self.state != PAUSED:
            return
        self.on_resume(tick)
        self.state = RUNNING

    def drain(self, tick: int = 0) -> None:
        """Stop taking on new work; finish what is in flight."""
        if self.state == RUNNING:
            self.state = DRAINING

    def publish_metrics(self, metrics) -> None:
        """Export this job's gauges/counters into a ``repro.obs``
        MetricsRegistry (called once per tick by the runtime while
        telemetry is enabled). Default: nothing to export."""

    def on_pause(self, tick: int) -> None:
        """Checkpoint / release resources before the pause takes effect."""

    def on_resume(self, tick: int) -> None:
        """Reacquire resources released by :meth:`on_pause`."""

    def prepare(self) -> None:
        """Called once before the first tick (idempotent)."""

    def begin_tick(self, tick: int) -> None:
        """Called at the top of every runtime tick (before the power sum),
        for every unfinished, unpaused job."""

    def step(self, tick: int) -> StepReport:
        raise NotImplementedError

    def observe(self, tick: int, report: StepReport,
                slowdown: float) -> Optional[str]:
        """Digest one quantum: compute the observed latency (wall x the
        shared-trace slowdown for this job's sensitivity), record it, and
        return the monitor's proposal ("down" | "up" | None). The runtime
        arbitrates across jobs before anything is committed."""
        raise NotImplementedError

    def migrate(self, direction: str, reason: str,
                tick: int) -> Optional[MigrationRecord]:
        """Commit an arbitrated proposal: switch rungs and carry state."""
        raise NotImplementedError

    def on_device_loss(self, tick: int, failed: Sequence[int]) -> None:
        """Devices vanished from the shared pool. Mesh-backed jobs remesh;
        single-device jobs (serving) keep streaming."""

    def end_tick(self, tick: int) -> None:
        """Post-arbitration bookkeeping (logging, periodic checkpoints)."""

    def finalize(self) -> None:
        """Called once when the runtime loop ends."""

    # -- shared monitor policy ------------------------------------------------
    # (subclasses provide ``adaptive``, ``latency_fn`` and ``_expected``:
    # rung name -> calibrated clean latency)

    def _monitor_proposal(self, report: StepReport, rung,
                          dt: float, observed: float) -> Optional[str]:
        """Feed policy shared by every job: non-adaptive jobs never propose;
        in wall-clock mode the first step on a rung is discarded (it pays
        the compile/migration tail — and counts as the controller's
        post-migration skip, so a second, clean sample is not dropped too)
        and the rung's clean latency is calibrated from the first steady
        measurement."""
        if not self.adaptive:
            return None
        feed = True
        if self.latency_fn is None:
            if report.warmup:
                feed = False
                self.controller.note_external_skip()
            elif rung.name not in self._expected:
                # calibrate this rung's clean latency from the wall
                # measurement. Synthetic traces never slow the actual
                # machine, so dt is clean even mid-burst; under real
                # interference (no trace) a rung first visited while
                # pressured calibrates high, which only delays detection
                # until the post-clear upgrade re-visits it
                self._expected[rung.name] = dt
                self.controller.calibrate(dt)
        return self.controller.propose(observed) if feed else None

    def _recalibrate(self, from_rung, to_rung) -> Optional[float]:
        """Re-anchor the monitor after a migration: prefer the target rung's
        own calibration, else scale the departing rung's by the ladder's
        relative latencies. Returns the expectation installed (if any)."""
        expected = self._expected.get(to_rung.name)
        if expected is None:
            base = self._expected.get(from_rung.name)
            if base is not None and from_rung.rel_latency > 0:
                expected = base * (to_rung.rel_latency / from_rung.rel_latency)
        if expected is not None:
            self.controller.calibrate(expected)
        return expected


# ---------------------------------------------------------------------------
# serving rungs + ServeJob
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeRung:
    """One serving execution choice. ``None`` fields mean "the engine's
    as-built setting" (so upgrading back to the top rung restores it):

    - ``slot_cap``: max concurrently-resident requests (decode microbatch
      cap) — fewer resident sequences stream less KV per step, the decode
      analogue of shrinking the training microbatch;
    - ``attn_impl``: decode attention kernel override;
    - ``kv_dtype``: KV-cache dtype override ("bfloat16" halves cache
      traffic; token streams may differ from the f32 rungs);
    - ``draft_depth``: speculative-decoding depth override (0 turns
      speculation off). Emitted streams are invariant to depth, so this is
      the *cheapest* knob on the ladder — it trades only the speculative
      speedup, never a request's tokens — and sits above slot caps in the
      default downgrade order.
    """
    name: str
    slot_cap: Optional[int] = None
    attn_impl: Optional[str] = None
    kv_dtype: Optional[str] = None
    draft_depth: Optional[int] = None
    interference_sensitivity: float = 1.0
    rel_latency: float = 1.0  # aggregate tokens/s cost of this rung
    latency_estimate_s: Optional[float] = None
    power_draw: Optional[float] = None  # defaults to sensitivity

    def profile(self, *, position: int = 0, n: int = 1) -> ChoiceProfile:
        lat = self.latency_estimate_s if self.latency_estimate_s is not None \
            else self.rel_latency
        return ChoiceProfile(choice=self, latency_s=lat, energy_j=lat,
                             power_w=1.0, cost_key=(n - position,))


def default_serve_ladder(max_batch: int, *, include_bf16_kv: bool = True,
                         draft_depth: Optional[int] = None
                         ) -> List[ServeRung]:
    """Serving downgrade ladder: each rung halves decode concurrency (the
    contended-bandwidth knob) and the bottom rung additionally halves KV
    traffic with a bf16 cache. Rungs whose knobs collapse to an earlier
    rung's (tiny ``max_batch``) are dropped.

    When the engine speculates (``draft_depth`` > 0), draft-depth rungs are
    inserted *above* the slot caps: halve the depth, then switch speculation
    off, and only then start capping slots. Walking depth down costs only
    the speculative speedup — emitted streams are depth-invariant — while a
    slot cap costs admitted requests their latency, so speculation is
    always the first thing thermals take."""
    bf16 = "bfloat16" if include_bf16_kv else None
    if draft_depth:
        specs = [("serve-full", None, None, None, 1.0)]
        if draft_depth // 2 >= 1:
            specs.append(("serve-spec-half", None, None,
                          draft_depth // 2, 1.15))
        specs += [("serve-spec-off", None, None, 0, 1.3),
                  ("serve-capped", max(1, max_batch // 2), None, 0, 1.7),
                  ("serve-lean", max(1, max_batch // 4), bf16, 0, 2.2)]
    else:
        specs = [("serve-full", None, None, None, 1.0),
                 ("serve-capped", max(1, max_batch // 2), None, None, 1.4),
                 ("serve-lean", max(1, max_batch // 4), bf16, None, 1.9)]
    out: List[ServeRung] = []
    seen = set()
    for name, cap, kvd, depth, rel in specs:
        key = (cap if cap is None or cap < max_batch else None, kvd, depth)
        if key in seen:
            continue
        seen.add(key)
        out.append(ServeRung(name=name, slot_cap=cap, kv_dtype=kvd,
                             draft_depth=depth, rel_latency=rel))
    sens = ladder_sensitivities(len(out))
    for r, s in zip(out, sens):
        r.interference_sensitivity = s
    return out


class ServeJob(SocJob):
    """A ContinuousBatchingEngine under runtime arbitration.

    One tick = one engine step (admissions + one batched decode +
    retirements). Migrations apply the target rung's knobs to the live
    engine — resident sequences keep streaming across the switch.
    """

    def __init__(self, engine, requests: Sequence[Any] = (), *,
                 rungs: Optional[Sequence[ServeRung]] = None,
                 name: str = "serve", priority: float = 1.0,
                 adaptive: bool = True, upgrade_patience: int = 5,
                 latency_fn=None, verbose: bool = False,
                 slo_p99_s: Optional[float] = None, slo_window: int = 64,
                 slo_min_samples: int = 8):
        self.engine = engine
        self._requests = list(requests)
        self._rungs = list(rungs) if rungs is not None \
            else default_serve_ladder(
                engine.max_batch,
                draft_depth=getattr(engine, "draft_depth", 0))
        if not self._rungs:
            raise ValueError("need at least one serve rung")
        if latency_fn is not None and any(
                r.latency_estimate_s is None for r in self._rungs):
            raise ValueError("latency_fn mode needs latency_estimate_s on "
                             "every serve rung")
        self.name = name
        self.priority = float(priority)
        self.adaptive = adaptive and len(self._rungs) > 1
        self.latency_fn = latency_fn
        self.verbose = verbose
        n = len(self._rungs)
        profiles = [r.profile(position=i, n=n)
                    for i, r in enumerate(self._rungs)]
        self.controller = SwanController(profiles,
                                         upgrade_patience=upgrade_patience)
        self.timeline = Timeline()
        self._expected: Dict[str, float] = {}
        if latency_fn is not None:
            for r in self._rungs:
                self._expected[r.name] = r.latency_estimate_s
        self._steps_on_rung = 0
        self._step_idx = 0
        self._prepared = False
        # p99 token-latency SLO: every resident request receives one token
        # per engine step, so the step's observed latency IS each of those
        # tokens' latency; a sliding window of them estimates the p99
        self.slo_p99_s = slo_p99_s
        self.slo_min_samples = slo_min_samples
        self._slo_window: Deque[float] = collections.deque(maxlen=slo_window)
        self._slo_tokens = 0
        self._slo_attained = 0

    # -- SocJob surface ------------------------------------------------------
    def rungs(self) -> Sequence[ServeRung]:
        return self._rungs

    @property
    def done(self) -> bool:
        if not self._prepared:
            return False
        resident = any(u is not None for u in self.engine.slot_uid)
        # a sequence swapped to host memory is mid-stream, not finished —
        # draining included: it owns its admission and must resume
        swapped = bool(getattr(self.engine, "swapped", None))
        if self.state == DRAINING:
            return not resident and not swapped
        return not self.engine.queue and not resident and not swapped

    def drain(self, tick: int = 0) -> None:
        super().drain(tick)
        if self.state == DRAINING:
            self.engine.drain()

    # -- SLO -----------------------------------------------------------------
    def slo_headroom(self) -> Optional[float]:
        if self.slo_p99_s is None or \
                len(self._slo_window) < self.slo_min_samples:
            return None
        p99 = float(np.percentile(np.asarray(self._slo_window), 99.0))
        return (self.slo_p99_s - p99) / self.slo_p99_s

    def slo_stats(self) -> Dict[str, Any]:
        """Attainment = fraction of emitted tokens whose step latency met the
        SLO (the per-token view the paper's interactivity constraint cares
        about)."""
        head = self.slo_headroom()
        return {
            "slo_p99_s": self.slo_p99_s,
            "headroom": None if head is None else round(head, 4),
            "tokens": self._slo_tokens,
            "attained_tokens": self._slo_attained,
            "attainment": round(self._slo_attained / self._slo_tokens, 4)
            if self._slo_tokens else None,
        }

    def prepare(self) -> None:
        if self._prepared:
            return
        for req in self._requests:
            self.engine.submit(req)
        self._apply_rung(self.active_rung)
        self._prepared = True

    def step(self, tick: int) -> StepReport:
        t0 = time.perf_counter()
        emitted = self.engine.step()
        dt = time.perf_counter() - t0
        warmup = self._steps_on_rung == 0
        self._steps_on_rung += 1
        kv = getattr(self.engine, "kv", None)
        pressure = kv.pool.utilization() if kv is not None else None
        return StepReport(latency_s=dt, work=float(len(emitted)),
                          warmup=warmup, pool_pressure=pressure)

    def pool_stats(self) -> Optional[Dict[str, Any]]:
        """Block-pool accounting for paged engines (None under contig):
        the engine's pool/prefix/swap counters, for runtime dashboards."""
        kv = getattr(self.engine, "kv", None)
        if kv is None:
            return None
        st = self.engine.stats()
        keys = ("prefill_chunks", "prefill_chunks_skipped", "cow_copies",
                "table_rows_shipped", "table_uploads", "swapped",
                "swap_outs", "swap_ins")
        out = {k: st[k] for k in keys if k in st}
        out["pool"] = st["pool"]
        return out

    def publish_metrics(self, metrics) -> None:
        """Serving occupancy/SLO/prefix/swap gauges + block-pool accounting
        under one registry (ISSUE 9: absorbs ``engine.stats()`` and
        ``pool.stats()`` into the shared schema)."""
        st = self.engine.stats()
        lab = {"job": self.name}

        def g(name: str, help: str = ""):
            return metrics.gauge(name, help).labels(**lab)

        g("serve_tokens_out", "total generated tokens").set(
            float(st["tokens_out"]))
        g("serve_decode_steps").set(float(st["decode_steps"]))
        g("serve_occupancy", "live slots / slot cap").set(
            float(st["occupancy"]))
        g("serve_queue_depth").set(float(len(self.engine.queue)))
        g("serve_shed_total").set(float(st["shed"]))
        g("serve_timeouts_total").set(float(st["timeouts"]))
        g("serve_rejected_total").set(float(st["rejected"]))
        g("serve_draft_depth", "active speculative draft depth").set(
            float(st.get("draft_depth", 0)))
        if "spec_acceptance" in st:
            g("serve_spec_acceptance",
              "accepted/drafted ratio").set(float(st["spec_acceptance"]))
        head = self.slo_headroom()
        if head is not None:
            g("serve_slo_headroom").set(float(head))
        if self._slo_tokens:
            g("serve_slo_attainment").set(self._slo_attained /
                                          self._slo_tokens)
        kv = getattr(self.engine, "kv", None)
        if kv is not None:
            for k in ("prefill_chunks", "prefill_chunks_skipped",
                      "cow_copies", "table_rows_shipped", "swapped",
                      "swap_outs", "swap_ins"):
                if k in st:
                    g(f"serve_{k}").set(float(st[k]))
            kv.publish_metrics(metrics, stats=st["pool"], **lab)

    def observe(self, tick: int, report: StepReport,
                slowdown: float) -> Optional[str]:
        rung = self.active_rung
        dt = report.latency_s
        if self.latency_fn is not None:
            observed = float(self.latency_fn(self._step_idx, rung, dt))
        else:
            observed = dt * slowdown
        report.observed_s = observed
        if self.slo_p99_s is not None and report.work > 0:
            self._slo_window.append(observed)
            self._slo_tokens += int(report.work)
            if observed <= self.slo_p99_s:
                self._slo_attained += int(report.work)
        self.timeline.record_step(step=self._step_idx, rung=rung.name,
                                  latency_s=round(dt, 6),
                                  observed_s=round(observed, 6), loss=0.0,
                                  work=report.work, warmup=report.warmup)
        return self._monitor_proposal(report, rung, dt, observed)

    def end_tick(self, tick: int) -> None:
        # incremented here, not in observe(): a migration committed by the
        # arbiter between the two must be recorded at the step that caused
        # it (keeps serve and train migrations tick-aligned when merged)
        self._step_idx += 1

    def migrate(self, direction: str, reason: str,
                tick: int) -> Optional[MigrationRecord]:
        prev = self.controller.idx
        self.controller.commit(direction, reason)
        if self.controller.idx == prev:
            return None
        from_rung, to_rung = self._rungs[prev], self.active_rung
        t0 = time.perf_counter()
        self._apply_rung(to_rung)
        cost_s = time.perf_counter() - t0
        self._recalibrate(from_rung, to_rung)
        self._steps_on_rung = 0
        if self.verbose:
            print(f"[swan] {self.name}: migrate {from_rung.name} -> "
                  f"{to_rung.name} ({reason})")
        return self.timeline.record_migration(
            step=self._step_idx, from_rung=from_rung.name,
            to_rung=to_rung.name, reason=reason, kind="in-place",
            cost_s=round(cost_s, 6))

    def _apply_rung(self, rung: ServeRung) -> None:
        self.engine.set_slot_cap(rung.slot_cap)
        self.engine.set_kv_dtype(rung.kv_dtype)
        self.engine.set_attn_impl(rung.attn_impl)
        if hasattr(self.engine, "set_draft_depth"):
            self.engine.set_draft_depth(rung.draft_depth)

    def result(self) -> Dict[int, Any]:
        return self.engine.finished


# ---------------------------------------------------------------------------
# ForegroundAppJob: the preemptor
# ---------------------------------------------------------------------------


class ForegroundAppJob(SocJob):
    """An interactive foreground app (paper §3: on-device training must never
    hurt user experience). It produces no arbiter-accounted goodput — it
    *occupies* the SoC: while one of its bursts is active the runtime pauses
    every preemptible co-tenant outright (background training checkpoints and
    releases its state) instead of merely downgrading it, and the app's power
    draw keeps heating the shared ThermalTrace so co-tenants that stay up
    still feel it thermally.

    Bursts are ``(start, stop)`` tick intervals — scripted up front, or
    injected live (:meth:`add_burst`) by the chaos harness.
    """

    is_foreground = True
    preemptible = False

    def __init__(self, bursts: Sequence[Sequence[int]] = (), *,
                 name: str = "foreground", latency_s: float = 0.016,
                 power: float = 2.0, sensitivity: float = 1.0):
        self.name = name
        self.priority = 1e9  # absolute: expressed via preemption, not scores
        self.adaptive = False
        self.latency_fn = None
        self._bursts: List[List[int]] = [[int(a), int(b)] for a, b in bursts]
        self._latency_s = float(latency_s)
        rung = ServeRung(name="fg-active", interference_sensitivity=sensitivity,
                         rel_latency=1.0, latency_estimate_s=latency_s,
                         power_draw=power)
        self._rungs = [rung]
        self.controller = SwanController([rung.profile()])
        self.timeline = Timeline()
        self._expected: Dict[str, float] = {rung.name: latency_s}
        self._tick = -1

    # -- schedule ------------------------------------------------------------
    def add_burst(self, start: int, stop: int) -> None:
        if stop <= start:
            raise ValueError(f"bad burst [{start}, {stop})")
        self._bursts.append([int(start), int(stop)])

    def demands_soc(self, tick: int) -> bool:
        """True while the user is interacting — the runtime preempts
        preemptible co-tenants for exactly these ticks."""
        return any(a <= tick < b for a, b in self._bursts)

    # -- SocJob surface ------------------------------------------------------
    def rungs(self) -> Sequence[ServeRung]:
        return self._rungs

    def power_draw(self) -> float:
        # an idle foreground app draws nothing; only its bursts heat the die
        return super().power_draw() if self.demands_soc(self._tick) else 0.0

    def sensitivity(self) -> float:
        return super().sensitivity() if self.demands_soc(self._tick) else 0.0

    @property
    def done(self) -> bool:
        # done once past the last scripted burst; chaos may add more later,
        # which flips this back (the property is recomputed every tick)
        return not any(self._tick < b for _, b in self._bursts)

    def begin_tick(self, tick: int) -> None:
        self._tick = tick

    def step(self, tick: int) -> StepReport:
        self._tick = tick
        if not self.demands_soc(tick):
            return StepReport(latency_s=0.0, work=0.0)
        return StepReport(latency_s=self._latency_s, work=0.0)

    def observe(self, tick: int, report: StepReport,
                slowdown: float) -> Optional[str]:
        if report.latency_s > 0.0:
            observed = report.latency_s * slowdown
            report.observed_s = observed
            self.timeline.record_step(step=tick, rung="fg-active",
                                      latency_s=round(report.latency_s, 6),
                                      observed_s=round(observed, 6), loss=0.0,
                                      work=0.0)
        return None  # never proposes; never migrates

    def migrate(self, direction: str, reason: str,
                tick: int) -> Optional[MigrationRecord]:
        return None
