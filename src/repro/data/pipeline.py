"""Deterministic synthetic data pipelines (LM tokens / speech / images).

Per-host sharding: each host generates only its shard of the global batch from
a (seed, step, host) counter — no host ever materializes the global batch, no
inter-host data traffic, and restarts are reproducible from the step number
alone (checkpoint stores just ``step``). This is the standard TPU-pod input
pattern (per-host `jax.make_array_from_callback` feeding).

Content is a mixture of Zipf-distributed tokens with injected n-gram structure
so losses are non-degenerate (a pure-uniform stream gives no learnable signal
for the examples).
"""
from __future__ import annotations

import numpy as np


def _rng(seed: int, step: int, host: int = 0) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step, host]))


def synthetic_lm_batch(rng: np.random.Generator, batch: int, seq: int, vocab: int):
    """Zipf tokens + copied spans (gives in-context signal to learn)."""
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks ** 1.1
    probs /= probs.sum()
    toks = rng.choice(vocab, size=(batch, seq), p=probs).astype(np.int32)
    # inject copy structure: second half repeats a window of the first half
    if seq >= 8:
        w = seq // 4
        src = toks[:, :w]
        toks[:, seq // 2:seq // 2 + w] = src
    return {"tokens": toks}


def synthetic_cnn_batch(rng: np.random.Generator, batch: int, image: int,
                        channels: int, n_classes: int):
    """Class-conditional Gaussian blobs (linearly separable => loss decreases)."""
    labels = rng.integers(0, n_classes, size=(batch,)).astype(np.int32)
    base = rng.standard_normal((batch, image, image, channels)).astype(np.float32)
    # class signature pattern
    sig = np.zeros_like(base)
    xs = np.linspace(0, 2 * np.pi, image)
    for i, lbl in enumerate(labels):
        freq = 1 + (lbl % 7)
        sig[i, :, :, 0] = np.outer(np.sin(freq * xs), np.cos(freq * xs))
    return {"images": base * 0.3 + sig, "labels": labels}


def lm_batches(seed: int, batch: int, seq: int, vocab: int, *, host: int = 0,
               n_hosts: int = 1, start_step: int = 0):
    """Infinite iterator over this host's shard of the global LM batch."""
    assert batch % n_hosts == 0
    local = batch // n_hosts
    step = start_step
    while True:
        yield synthetic_lm_batch(_rng(seed, step, host), local, seq, vocab)
        step += 1


def cnn_batches(seed: int, batch: int, image: int, channels: int, n_classes: int,
                *, host: int = 0, n_hosts: int = 1, start_step: int = 0):
    assert batch % n_hosts == 0
    local = batch // n_hosts
    step = start_step
    while True:
        yield synthetic_cnn_batch(_rng(seed, step, host), local, image, channels, n_classes)
        step += 1


def make_batch(cfg, shape, *, seed: int = 0, step: int = 0, np_rng=None):
    """One global batch matching input_specs(cfg, shape) (for runtime tests)."""
    rng = np_rng or _rng(seed, step)
    if cfg.family == "cnn":
        return synthetic_cnn_batch(rng, shape.global_batch, cfg.image_size,
                                   cfg.in_channels, cfg.n_classes)
    b = synthetic_lm_batch(rng, shape.global_batch, shape.seq_len, cfg.vocab_size)
    if cfg.family == "vlm":
        b["image_embed"] = rng.standard_normal(
            (shape.global_batch, cfg.n_image_tokens, cfg.d_model)).astype(np.float32) * 0.02
    if cfg.family == "encdec":
        b["audio_embed"] = rng.standard_normal(
            (shape.global_batch, cfg.n_audio_frames, cfg.d_model)).astype(np.float32) * 0.02
    return b
