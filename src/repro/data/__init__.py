from repro.data.pipeline import (lm_batches, cnn_batches, make_batch,  # noqa: F401
                                 synthetic_lm_batch, synthetic_cnn_batch)
