"""Fleet runtime: thousands of FL client SoCs co-scheduled through SwanRuntime.

The device half (``fleet.job``) wraps one client's local training round as a
preemptible, checkpointable :class:`FLTrainJob` driven through a per-device
``SwanRuntime`` — battery/thermal/foreground events come from the client's
``BatteryTrace``. The coordinator half (``fleet.coordinator``) owns the round
lifecycle: over-provisioned invites, binding deadlines with a stale-update
window, bounded retry/backoff, checksum/dedup acceptance, and
crash-consistent aggregation through ``repro.checkpoint``.
"""
from repro.fleet.coordinator import (CoordinatorCrash, FleetConfig,
                                     FleetCoordinator, FleetResult,
                                     FleetRound, build_fleet_clients,
                                     run_fleet)
from repro.fleet.job import (ClientOutcome, FleetClient, FLRung, FLTrainJob,
                             run_client_round)

__all__ = [
    "ClientOutcome", "CoordinatorCrash", "FLRung", "FLTrainJob",
    "FleetClient", "FleetConfig", "FleetCoordinator", "FleetResult",
    "FleetRound", "build_fleet_clients", "run_client_round", "run_fleet",
]
