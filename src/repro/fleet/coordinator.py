"""FleetCoordinator: the round lifecycle over thousands of device sims.

A round is: select online clients → over-provisioned invite (StragglerPolicy)
→ run each invitee's :func:`~repro.fleet.job.run_client_round` → one bounded
retry/backoff wave for fast-detectable failures (churn, offline) → deliver
updates through the fleet fault model (dropped / duplicated / corrupted) →
accept in arrival order against a **binding** deadline plus a stale-update
window → aggregate → advance fleet time.

Crash consistency: before acceptance begins, the full arrival list is
persisted as a write-ahead log inside the coordinator's durable state
(``repro.checkpoint`` — checksummed, atomic, torn-write-safe), and the
partial aggregate + accepted set are re-persisted after *every* accepted
update. A coordinator crash mid-aggregation (:class:`CoordinatorCrash`,
injected by ``engine.chaos.FleetChaos``) therefore resumes from the WAL
without losing or double-counting a single accepted update — the final
aggregate is bitwise identical to a crash-free run's. Everything stochastic
(selection, device sims, fault schedule) is a stateless function of
``(seed, round, client)``, which is what makes that replay exact. (The Oort
selector keeps in-process utility state and is supported for ordinary runs,
but bitwise crash-parity is only guaranteed with ``selector="random"``.)
"""
from __future__ import annotations

import dataclasses
import math
import os
import zlib
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.fl.selection import OortSelector, random_selection
from repro.fl.simulator import DEVICE_MIX, TASK_CEILING, TASK_TAU
from repro.fl.traces import BatteryTrace, make_client_traces
from repro.fleet.job import ClientOutcome, FleetClient, run_client_round
from repro.runtime.fault import StragglerPolicy


class CoordinatorCrash(RuntimeError):
    """The coordinator process died mid-aggregation (chaos-injected). The
    durable state on disk is consistent; ``FleetCoordinator.resume`` picks
    the round back up."""


@dataclasses.dataclass
class FleetConfig:
    workload: str = "shufflenet-v2"
    n_clients: int = 2400
    clients_per_round: int = 25
    rounds: int = 6
    policy: str = "swan"  # swan | baseline
    selector: str = "random"  # random | oort
    local_steps: int = 16
    dim: int = 32
    seed: int = 0
    # round lifecycle
    deadline_factor: float = 3.0  # x fleet-median clean round wall
    round_deadline_s: float = 0.0  # explicit absolute deadline (0 = derive)
    stale_frac: float = 0.25  # stale window = frac x deadline
    over_provision: float = 1.3
    max_retries: int = 1  # retry waves for fast-detectable failures
    retry_backoff_s: float = 10.0
    agg_s: float = 30.0  # aggregation/communication time per round
    # device-sim knobs (consumed by fleet.job.run_client_round)
    fg_prob: float = 0.2
    fg_power: float = 1.2
    fg_latency_factor: float = 2.0
    heat_rate: float = 0.06
    cool_rate: float = 0.05
    thermal_slowdown: float = 2.2
    charge_rate: float = 2.0
    tick_slack: int = 16


@dataclasses.dataclass
class FleetRound:
    rnd: int
    t_min: float  # fleet clock at round END (minutes)
    accuracy: float
    online: int
    invited: int
    accepted: int
    accepted_on_time: int
    stale_accepted: int
    shortfall: int
    churned: int
    offline: int
    preempted: int
    straggled: int
    dropped: int
    duplicated: int
    dup_rejected: int
    corrupt_rejected: int
    late_rejected: int
    retries: int
    round_s: float
    deadline_s: float
    energy_j: float
    useful_samples: float
    agg_crc: int
    accepted_cids: List[int]
    by_class: Dict[str, int]
    by_class_energy: Dict[str, float]
    charging_accepted: int
    preemptions: int


@dataclasses.dataclass
class FleetResult:
    rounds: List[FleetRound]
    policy: str
    workload: str

    @property
    def final_accuracy(self) -> float:
        return self.rounds[-1].accuracy if self.rounds else 0.0

    def time_to_accuracy(self, target: float) -> Optional[float]:
        for r in self.rounds:
            if r.accuracy >= target:
                return r.t_min
        return None

    @property
    def wall_min(self) -> float:
        return self.rounds[-1].t_min if self.rounds else 0.0

    @property
    def goodput_samples_per_h(self) -> float:
        useful = sum(r.useful_samples for r in self.rounds)
        hours = self.wall_min / 60.0
        return useful / hours if hours > 0 else 0.0

    @property
    def slo_attainment(self) -> float:
        """Fraction of target aggregation slots filled by an on-time update —
        the round-level deadline SLO (accepted + shortfall = the round's
        target k)."""
        target = sum(r.accepted + r.shortfall for r in self.rounds)
        on_time = sum(r.accepted_on_time for r in self.rounds)
        return on_time / target if target else 0.0

    @property
    def total_energy_j(self) -> float:
        return sum(r.energy_j for r in self.rounds)

    def energy_by_class(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for r in self.rounds:
            for dev, j in r.by_class_energy.items():
                out[dev] = out.get(dev, 0.0) + j
        return out

    def accepted_by_class(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.rounds:
            for dev, n in r.by_class.items():
                out[dev] = out.get(dev, 0) + n
        return out

    def to_json(self) -> dict:
        return {
            "policy": self.policy,
            "workload": self.workload,
            "final_accuracy": round(self.final_accuracy, 6),
            "wall_min": round(self.wall_min, 3),
            "goodput_samples_per_h": round(self.goodput_samples_per_h, 3),
            "slo_attainment": round(self.slo_attainment, 4),
            "total_energy_j": round(self.total_energy_j, 1),
            "energy_by_class": {k: round(v, 1)
                                for k, v in self.energy_by_class().items()},
            "accepted_by_class": self.accepted_by_class(),
            "rounds": [dataclasses.asdict(r) for r in self.rounds],
        }


def build_fleet_clients(cfg: FleetConfig, *,
                        traces: Optional[Sequence[BatteryTrace]] = None
                        ) -> List[FleetClient]:
    """The 2400-client cohort: quality-filtered traces x 24 timezone shifts,
    the five-device mix, per-client sample counts from a stateless stream.
    Pass ``traces`` to reuse one generated trace set across policies (traces
    are never mutated)."""
    if traces is None:
        traces = make_client_traces(max(1, math.ceil(cfg.n_clients / 24)),
                                    seed=cfg.seed, tz_shifts=24)
    traces = list(traces)[:cfg.n_clients]
    if len(traces) < cfg.n_clients:
        raise ValueError(f"need {cfg.n_clients} traces, got {len(traces)}")
    clients = []
    for i in range(cfg.n_clients):
        rng = np.random.default_rng((cfg.seed, i, 7))
        clients.append(FleetClient(
            i, DEVICE_MIX[i % len(DEVICE_MIX)], traces[i], cfg.workload,
            policy=cfg.policy,
            n_samples=int(rng.lognormal(4.5, 1.0)) + 16))
    return clients


class FleetCoordinator:
    """Owns the round lifecycle and the durable round state."""

    def __init__(self, clients: Sequence[FleetClient], cfg: FleetConfig, *,
                 state_dir: str, chaos=None):
        from repro.checkpoint.manager import CheckpointManager
        self.clients: Dict[int, FleetClient] = {c.cid: c for c in clients}
        self.cfg = cfg
        self.chaos = chaos  # engine.chaos.FleetChaos
        self.straggler = StragglerPolicy(over_provision=cfg.over_provision,
                                         deadline_factor=cfg.deadline_factor)
        self.oort = OortSelector() if cfg.selector == "oort" else None
        self.mgr = CheckpointManager(os.path.join(state_dir, "coord"), keep=4)
        self._ckpt_root = os.path.join(state_dir, "pause")
        self._seq = 0
        self.state: Dict = {
            "round": 0, "t_min": 0.0, "samples_seen": 0.0, "last_day": 0,
            "global": np.zeros(cfg.dim, np.float32), "rounds": [],
            "inflight": None,
        }
        # one fleet-wide deadline, fixed up front: deadline_factor x the
        # fleet-median clean round wall under this policy's selected choice.
        # Deterministic across crash-resume (never depends on round state).
        if cfg.round_deadline_s > 0:
            self._deadline_s = float(cfg.round_deadline_s)
        else:
            walls = sorted(c.profiles[0].latency_s * cfg.local_steps
                           for c in self.clients.values())
            med = walls[len(walls) // 2] if walls else 1.0
            self._deadline_s = cfg.deadline_factor * med

    @classmethod
    def resume(cls, clients: Sequence[FleetClient], cfg: FleetConfig, *,
               state_dir: str, chaos=None) -> "FleetCoordinator":
        """Reload durable state after a coordinator crash. Pass the *same*
        client objects (their device sims for the in-flight round already
        ran — only aggregation is replayed) and the same chaos instance (a
        fresh one with the same ``crash_at`` would just crash again)."""
        co = cls(clients, cfg, state_dir=state_dir, chaos=chaos)
        restored = co.mgr.restore_latest()
        if restored is not None:
            seq, state = restored
            co._seq = int(seq)
            co.state = state
        return co

    # -- durable state --------------------------------------------------------
    def _save(self) -> None:
        self._seq += 1
        self.mgr.save(self._seq, self.state)

    # -- the lifecycle --------------------------------------------------------
    def run(self, rounds: Optional[int] = None) -> FleetResult:
        rounds = self.cfg.rounds if rounds is None else int(rounds)
        if self.state["inflight"] is not None:
            self._finish_round()  # crash recovery: complete the WAL'd round
        while int(self.state["round"]) < rounds:
            self._run_round(int(self.state["round"]))
        return self.result()

    def deadline_s(self) -> float:
        return self._deadline_s

    def _run_round(self, rnd: int) -> None:
        with obs.get_telemetry().span("fleet.round", rnd=rnd):
            self._run_round_inner(rnd)

    def _run_round_inner(self, rnd: int) -> None:
        cfg, st = self.cfg, self.state
        t = float(st["t_min"])
        day = int(t // 1440)
        if day != int(st["last_day"]):
            for c in self.clients.values():
                c.end_of_day()
            st["last_day"] = day
        online = [c.cid for c in self.clients.values() if c.available(t)]
        deadline = self._deadline_s
        stale_s = cfg.stale_frac * deadline
        if not online:
            self._record_empty_round(rnd, t, deadline)
            return
        k = min(cfg.clients_per_round, len(online))
        invite_n = min(self.straggler.n_to_invite(k), len(online))
        rng = np.random.default_rng((cfg.seed, rnd, 211))
        if self.oort is not None:
            chosen = self.oort.select(rng, online, invite_n, deadline)
        else:
            chosen = random_selection(rng, online, invite_n)
        chosen = [int(c) for c in chosen]
        gone = self.chaos.churn(rnd, chosen) if self.chaos is not None \
            else set()
        outcomes: List[ClientOutcome] = []
        arrival_off: List[float] = []
        for cid in chosen:
            if cid in gone:
                c = self.clients[cid]
                outcomes.append(ClientOutcome(
                    cid=cid, status="churn", latency_s=0.0, energy_j=0.0,
                    n_samples=c.n_samples, device=c.device,
                    charging=c.charging(t)))
                arrival_off.append(0.0)
                continue
            with obs.get_telemetry().span("fleet.invite", rnd=rnd, cid=cid):
                outcomes.append(run_client_round(self.clients[cid], rnd, t,
                                                 cfg,
                                                 ckpt_root=self._ckpt_root))
            arrival_off.append(0.0)
        # bounded retry waves: churn/offline are detectable before the
        # deadline (missing heartbeat); stragglers and foreground preemptions
        # are only discovered at the deadline, too late to replace
        retries = 0
        tried = set(chosen)
        wave_members = list(range(len(outcomes)))
        for wave in range(1, cfg.max_retries + 1):
            failed_fast = [i for i in wave_members
                           if outcomes[i].status in ("churn", "offline")]
            pool = [c for c in online if c not in tried]
            if not failed_fast or not pool:
                break
            rrng = np.random.default_rng((cfg.seed, rnd, 223, wave))
            repl = random_selection(rrng, pool,
                                    min(len(failed_fast), len(pool)))
            backoff = wave * cfg.retry_backoff_s
            wave_members = []
            for cid in (int(c) for c in repl):
                tried.add(cid)
                retries += 1
                wave_members.append(len(outcomes))
                with obs.get_telemetry().span("fleet.invite", rnd=rnd,
                                              cid=cid, wave=wave):
                    outcomes.append(run_client_round(
                        self.clients[cid], rnd, t + backoff / 60.0, cfg,
                        ckpt_root=self._ckpt_root))
                arrival_off.append(backoff)
        # delivery: the network loses, re-sends, and corrupts updates
        counters = {"churned": 0, "offline": 0, "preempted": 0,
                    "straggled": 0, "dropped": 0, "duplicated": 0,
                    "dup_rejected": 0, "corrupt_rejected": 0,
                    "late_rejected": 0, "preemptions": 0}
        by_class_energy: Dict[str, float] = {}
        energy = 0.0
        arrivals: List[Dict] = []
        for o, off in zip(outcomes, arrival_off):
            energy += o.energy_j
            by_class_energy[o.device] = \
                by_class_energy.get(o.device, 0.0) + o.energy_j
            counters["preemptions"] += o.preemptions
            if o.status == "churn":
                counters["churned"] += 1
            elif o.status in ("offline", "preempted", "straggler"):
                counters[o.status if o.status != "straggler"
                         else "straggled"] += 1
            if o.delta is None:
                continue
            fate = self.chaos.delivery(rnd, o.cid) \
                if self.chaos is not None else "ok"
            if fate == "dropped":
                counters["dropped"] += 1
                continue
            delta = o.delta
            if fate == "corrupt":
                delta = self.chaos.corrupt_bytes(rnd, o.cid, delta)
            entry = {"cid": o.cid, "arrival_s": float(off + o.latency_s),
                     "delta": np.asarray(delta, np.float32),
                     "n_samples": int(o.n_samples),
                     "checksum": int(o.checksum), "device": o.device,
                     "charging": int(o.charging)}
            arrivals.append(entry)
            if fate == "duplicated":
                counters["duplicated"] += 1
                arrivals.append({**entry,
                                 "arrival_s": entry["arrival_s"] + 1.0})
        arrivals.sort(key=lambda a: (a["arrival_s"], a["cid"]))
        # WAL: everything acceptance needs is durable BEFORE it begins
        st["inflight"] = {
            "rnd": rnd, "t_start": t, "online": len(online),
            "invited": len(chosen) + retries, "k": k,
            "deadline_s": deadline, "stale_s": stale_s,
            "arrivals": arrivals, "next_idx": 0,
            "accepted_cids": [], "accepted_on_time": 0, "stale_accepted": 0,
            "last_accept_s": 0.0,
            "agg": np.zeros(cfg.dim, np.float64), "weight": 0.0,
            "useful_samples": 0.0, "counters": counters,
            "by_class": {}, "by_class_energy": by_class_energy,
            "charging_accepted": 0, "retries": retries, "energy_j": energy,
        }
        self._save()
        self._finish_round()

    def _finish_round(self) -> None:
        """Acceptance + aggregation from the durable in-flight state. Safe to
        re-enter after a crash at any accepted-update boundary: the cursor,
        partial aggregate and accepted set were persisted together."""
        cfg, st = self.cfg, self.state
        infl = st["inflight"]
        rnd = int(infl["rnd"])
        k = int(infl["k"])
        deadline = float(infl["deadline_s"])
        stale_s = float(infl["stale_s"])
        arrivals = infl["arrivals"]
        counters = infl["counters"]
        accepted = set(int(c) for c in infl["accepted_cids"])
        i = int(infl["next_idx"])
        while i < len(arrivals):
            a = arrivals[i]
            i += 1
            infl["next_idx"] = i
            if len(accepted) >= k:
                continue  # capacity reached; drain the cursor
            arrival = float(a["arrival_s"])
            if arrival > deadline + stale_s:
                counters["late_rejected"] += 1
                continue
            cid = int(a["cid"])
            if cid in accepted:
                counters["dup_rejected"] += 1
                continue
            delta = np.asarray(a["delta"], np.float32)
            if zlib.crc32(np.ascontiguousarray(delta).tobytes()) != \
                    int(a["checksum"]):
                counters["corrupt_rejected"] += 1
                continue
            with obs.get_telemetry().span("fleet.accept", rnd=rnd, cid=cid):
                n = int(a["n_samples"])
                infl["agg"] = np.asarray(infl["agg"], np.float64) \
                    + delta.astype(np.float64) * n
                infl["weight"] = float(infl["weight"]) + n
                infl["useful_samples"] = \
                    float(infl["useful_samples"]) + n * 0.2
                accepted.add(cid)
                infl["accepted_cids"] = sorted(accepted)
                if arrival <= deadline:
                    infl["accepted_on_time"] = \
                        int(infl["accepted_on_time"]) + 1
                else:
                    infl["stale_accepted"] = int(infl["stale_accepted"]) + 1
                infl["last_accept_s"] = max(float(infl["last_accept_s"]),
                                            arrival)
                dev = a["device"]
                infl["by_class"][dev] = int(infl["by_class"].get(dev, 0)) + 1
                infl["charging_accepted"] = \
                    int(infl["charging_accepted"]) + int(a["charging"])
                self._save()  # accepted set + partial aggregate are one atom
            if self.chaos is not None and \
                    self.chaos.crash_now(rnd, len(accepted)):
                raise CoordinatorCrash(
                    f"injected crash: round {rnd} after "
                    f"{len(accepted)} accepted updates")
        self._finalize_round()

    def _finalize_round(self) -> None:
        cfg, st = self.cfg, self.state
        infl = st["inflight"]
        rnd = int(infl["rnd"])
        k = int(infl["k"])
        deadline = float(infl["deadline_s"])
        stale_s = float(infl["stale_s"])
        weight = float(infl["weight"])
        n_accepted = len(infl["accepted_cids"])
        if weight > 0:
            upd = np.asarray(infl["agg"], np.float64) / weight
            st["global"] = (np.asarray(st["global"], np.float64)
                            + upd).astype(np.float32)
        st["samples_seen"] = float(st["samples_seen"]) \
            + float(infl["useful_samples"])
        ceiling = TASK_CEILING[cfg.workload]
        tau = TASK_TAU[cfg.workload]
        acc = ceiling * (1.0 - math.exp(-float(st["samples_seen"]) / tau))
        if n_accepted >= k and k > 0:
            round_s = float(infl["last_accept_s"])
        else:
            round_s = deadline + stale_s  # waited out the whole window
        t_end = float(infl["t_start"]) + round_s / 60.0 + cfg.agg_s / 60.0
        if self.oort is not None:
            loss = max(0.1, 2.3 * (1 - float(st["samples_seen"])
                                   / (float(st["samples_seen"]) + tau)))
            for cid in infl["accepted_cids"]:
                self.oort.report(int(cid), loss,
                                 self.clients[int(cid)].n_samples, round_s)
        rec = FleetRound(
            rnd=rnd, t_min=t_end, accuracy=acc,
            online=int(infl["online"]), invited=int(infl["invited"]),
            accepted=n_accepted,
            accepted_on_time=int(infl["accepted_on_time"]),
            stale_accepted=int(infl["stale_accepted"]),
            shortfall=max(0, k - n_accepted),
            churned=int(infl["counters"]["churned"]),
            offline=int(infl["counters"]["offline"]),
            preempted=int(infl["counters"]["preempted"]),
            straggled=int(infl["counters"]["straggled"]),
            dropped=int(infl["counters"]["dropped"]),
            duplicated=int(infl["counters"]["duplicated"]),
            dup_rejected=int(infl["counters"]["dup_rejected"]),
            corrupt_rejected=int(infl["counters"]["corrupt_rejected"]),
            late_rejected=int(infl["counters"]["late_rejected"]),
            retries=int(infl["retries"]), round_s=round_s,
            deadline_s=deadline, energy_j=float(infl["energy_j"]),
            useful_samples=float(infl["useful_samples"]),
            agg_crc=zlib.crc32(
                np.ascontiguousarray(st["global"]).tobytes()),
            accepted_cids=[int(c) for c in infl["accepted_cids"]],
            by_class={str(d): int(n)
                      for d, n in infl["by_class"].items()},
            by_class_energy={str(d): float(j)
                             for d, j in infl["by_class_energy"].items()},
            charging_accepted=int(infl["charging_accepted"]),
            preemptions=int(infl["counters"]["preemptions"]))
        st["rounds"].append(dataclasses.asdict(rec))
        st["t_min"] = t_end
        st["round"] = rnd + 1
        st["inflight"] = None
        self._save()
        tel = obs.get_telemetry()
        if tel.enabled:
            m = tel.metrics
            lab = {"policy": cfg.policy}
            m.gauge("fleet_round", "last finalized round").labels(
                **lab).set(float(rnd))
            m.gauge("fleet_round_goodput_samples",
                    "useful samples this round").labels(**lab).set(
                rec.useful_samples)
            m.gauge("fleet_accuracy").labels(**lab).set(rec.accuracy)
            m.counter("fleet_accepted_total").labels(**lab).inc(n_accepted)
            m.counter("fleet_invited_total").labels(**lab).inc(rec.invited)
            m.histogram("fleet_round_s", "wall-clock round length").labels(
                **lab).observe(rec.round_s)
            tel.snap(f"fleet-round-{rnd}")

    def _record_empty_round(self, rnd: int, t: float,
                            deadline: float) -> None:
        st = self.state
        t_end = t + 10.0
        rec = FleetRound(
            rnd=rnd, t_min=t_end,
            accuracy=TASK_CEILING[self.cfg.workload]
            * (1.0 - math.exp(-float(st["samples_seen"])
                              / TASK_TAU[self.cfg.workload])),
            online=0, invited=0, accepted=0, accepted_on_time=0,
            stale_accepted=0, shortfall=0, churned=0, offline=0,
            preempted=0, straggled=0, dropped=0, duplicated=0,
            dup_rejected=0, corrupt_rejected=0, late_rejected=0,
            retries=0, round_s=0.0, deadline_s=deadline, energy_j=0.0,
            useful_samples=0.0,
            agg_crc=zlib.crc32(
                np.ascontiguousarray(st["global"]).tobytes()),
            accepted_cids=[], by_class={}, by_class_energy={},
            charging_accepted=0, preemptions=0)
        st["rounds"].append(dataclasses.asdict(rec))
        st["t_min"] = t_end
        st["round"] = rnd + 1
        self._save()

    def result(self) -> FleetResult:
        rounds = [FleetRound(**d) for d in self.state["rounds"]]
        return FleetResult(rounds=rounds, policy=self.cfg.policy,
                           workload=self.cfg.workload)


def run_fleet(cfg: FleetConfig, *, state_dir: str, chaos=None,
              clients: Optional[Sequence[FleetClient]] = None,
              traces: Optional[Sequence[BatteryTrace]] = None
              ) -> FleetResult:
    """Build the cohort (unless given) and run the configured rounds."""
    if clients is None:
        clients = build_fleet_clients(cfg, traces=traces)
    coord = FleetCoordinator(clients, cfg, state_dir=state_dir, chaos=chaos)
    return coord.run()
