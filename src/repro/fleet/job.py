"""The device half of the fleet: one FL client round as a SocJob.

``repro/fl`` modeled a client round as a closed-form latency formula; here the
round actually *runs* through the arbiter. :class:`FLTrainJob` wraps a
client's local training as a preemptible, checkpointable
:class:`~repro.engine.jobs.SocJob`: its ladder is the client's Swan plan
(pruned ``ChoiceProfile`` ladder, or the single greedy profile under the
baseline policy), a foreground-app burst pauses it outright through the PR-6
checkpoint-and-release path, and the closed-loop ``ThermalTrace`` /
``EnergyLoan`` machinery sees the summed power of everything on the die.

Determinism is load-bearing: every source of randomness (model-update
contributions, foreground bursts) is a stateless function of
``(seed, cid, round, step)``, so a crash-resumed coordinator replays the
identical fleet, and a paused-and-resumed job produces a bitwise-identical
update to an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import zlib
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import energy as E
from repro.core.controller import SwanController
from repro.core.cost import ChoiceProfile, ladder_sensitivities
from repro.core.planner import explore_soc
from repro.core.profiler import greedy_baseline_profile
from repro.engine.events import ChargingTrace, ThermalTrace
from repro.engine.jobs import ForegroundAppJob, SocJob, StepReport
from repro.engine.runtime import SwanRuntime
from repro.engine.timeline import MigrationRecord, Timeline
from repro.fl.traces import BatteryTrace

# power_w -> the runtime's normalized power units (ThermalTrace heat /
# EnergyLoan charge are calibrated against sensitivities around 1.0)
POWER_NORM = 4.0


@dataclasses.dataclass(frozen=True)
class FLRung:
    """One execution choice of a client's ladder, as an arbiter-visible rung."""
    name: str
    interference_sensitivity: float
    rel_latency: float  # vs the top rung (goodput cost of running here)
    latency_estimate_s: float  # clean per-local-step wall time
    power_draw: float  # normalized units (power_w / POWER_NORM)
    energy_j: float  # per local step


def fl_rungs(profiles: Sequence[ChoiceProfile]) -> List[FLRung]:
    sens = ladder_sensitivities(len(profiles))
    base = profiles[0].latency_s
    return [FLRung(name=p.name, interference_sensitivity=s,
                   rel_latency=p.latency_s / base,
                   latency_estimate_s=p.latency_s,
                   power_draw=p.power_w / POWER_NORM,
                   energy_j=p.energy_j)
            for p, s in zip(profiles, sens)]


@functools.lru_cache(maxsize=None)
def _swan_ladder(device: str, workload: str):
    return tuple(explore_soc(device, workload).ladder)


@functools.lru_cache(maxsize=None)
def _baseline_profile(device: str, workload: str) -> ChoiceProfile:
    return greedy_baseline_profile(E.SOC_MODELS[device], workload)


class FleetClient:
    """Persistent per-device state across rounds: battery trace, energy loan,
    execution-choice ladder and the rung the controller last settled on."""

    def __init__(self, cid: int, device: str, trace: BatteryTrace,
                 workload: str, *, policy: str = "swan",
                 n_samples: int = 200):
        self.cid = int(cid)
        self.device = device
        self.trace = trace
        self.workload = workload
        self.policy = policy
        self.n_samples = int(n_samples)
        self.model = E.SOC_MODELS[device]
        self.loan = E.EnergyLoan(
            battery_j=self.model.battery_j,
            daily_charge_j=0.55 * self.model.battery_j,
            daily_usage_j=0.5 * self.model.battery_j)
        if policy == "swan":
            self.profiles: List[ChoiceProfile] = list(
                _swan_ladder(device, workload))
        else:  # PyTorch-greedy baseline (§5.1): one non-adaptive choice
            self.profiles = [_baseline_profile(device, workload)]
        self.rungs = fl_rungs(self.profiles)
        self.rung_idx = 0  # carried across rounds (controller warm start)

    def available(self, minute: float) -> bool:
        """The paper's isActive: loan headroom + (charging or level > 0.35)."""
        level, state = self.trace.at(minute)
        if not self.loan.available(level):
            return False
        return state >= 0 or level > 0.35

    def charging(self, minute: float) -> bool:
        return self.trace.at(minute)[1] > 0

    def end_of_day(self) -> None:
        self.loan.repay_daily()


class FLTrainJob(SocJob):
    """One client's local round under SwanRuntime arbitration.

    One tick = one local step. The model-update contribution of step ``i`` is
    a stateless function of ``(seed, cid, round, i)`` — no RNG object to
    checkpoint — so pause/exact-resume only needs the accumulated delta and
    the step counter. ``on_pause`` checkpoints and releases the delta
    (checksummed, torn-write-safe via ``repro.checkpoint``); ``on_resume``
    restores it at the exact pre-pause step.
    """

    preemptible = True

    def __init__(self, client: FleetClient, *, rnd: int, local_steps: int,
                 dim: int, seed: int, ckpt_dir: str,
                 name: str = "fl-train", upgrade_patience: int = 3):
        self.client = client
        self.rnd = int(rnd)
        self.local_steps = int(local_steps)
        self.dim = int(dim)
        self.seed = int(seed)
        self.name = name
        self.priority = 1.0
        self._rungs = client.rungs
        profiles = client.profiles
        self.adaptive = client.policy == "swan" and len(profiles) > 1
        self.latency_fn = None
        self.controller = SwanController(profiles,
                                         upgrade_patience=upgrade_patience)
        start = min(max(int(client.rung_idx), 0), len(profiles) - 1)
        if start:
            self.controller.idx = start
            self.controller.monitor.rebase(profiles[start].latency_s)
        self.timeline = Timeline()
        self._expected: Dict[str, float] = {
            r.name: r.latency_estimate_s for r in self._rungs}
        self._delta: Optional[np.ndarray] = np.zeros(dim, np.float32)
        self._local_step = 0
        self._energy_j = 0.0
        self._steps_on_rung = 0
        self._done_tick: Optional[int] = None
        self._ckpt_dir = ckpt_dir
        self._mgr = None
        self.pauses = 0

    # -- SocJob surface ------------------------------------------------------
    def rungs(self) -> Sequence[FLRung]:
        return self._rungs

    @property
    def done(self) -> bool:
        return self._local_step >= self.local_steps

    @property
    def energy_j(self) -> float:
        return self._energy_j

    @property
    def done_tick(self) -> Optional[int]:
        return self._done_tick

    def _contribution(self, step: int) -> np.ndarray:
        rng = np.random.default_rng(
            (self.seed, self.client.cid, self.rnd, step, 11))
        return (0.01 * rng.standard_normal(self.dim)).astype(np.float32)

    def step(self, tick: int) -> StepReport:
        assert self._delta is not None, "stepped while paused/released"
        rung = self.active_rung
        self._delta = self._delta + self._contribution(self._local_step)
        self._local_step += 1
        self._energy_j += rung.energy_j
        warmup = self._steps_on_rung == 0
        self._steps_on_rung += 1
        return StepReport(latency_s=rung.latency_estimate_s, work=1.0,
                          warmup=warmup)

    def observe(self, tick: int, report: StepReport,
                slowdown: float) -> Optional[str]:
        rung = self.active_rung
        dt = report.latency_s
        observed = dt * slowdown
        report.observed_s = observed
        self.timeline.record_step(step=tick, rung=rung.name,
                                  latency_s=round(dt, 6),
                                  observed_s=round(observed, 6), loss=0.0,
                                  work=report.work, warmup=report.warmup)
        return self._monitor_proposal(report, rung, dt, observed)

    def migrate(self, direction: str, reason: str,
                tick: int) -> Optional[MigrationRecord]:
        prev = self.controller.idx
        self.controller.commit(direction, reason)
        if self.controller.idx == prev:
            return None
        from_rung, to_rung = self._rungs[prev], self.active_rung
        self._recalibrate(from_rung, to_rung)
        self._steps_on_rung = 0
        return self.timeline.record_migration(
            step=tick, from_rung=from_rung.name, to_rung=to_rung.name,
            reason=reason, kind="in-place", cost_s=0.0)

    def end_tick(self, tick: int) -> None:
        if self.done and self._done_tick is None:
            self._done_tick = tick

    # -- pause / exact resume (PR-6 path) ------------------------------------
    def _ckpt(self):
        if self._mgr is None:
            from repro.checkpoint.manager import CheckpointManager
            self._mgr = CheckpointManager(self._ckpt_dir, keep=2)
        return self._mgr

    def on_pause(self, tick: int) -> None:
        mgr = self._ckpt()
        mgr.save(self._local_step, {"delta": self._delta,
                                    "energy_j": self._energy_j})
        self._delta = None  # the foreground app wants the memory
        self.pauses += 1
        rung = self.active_rung.name
        self.timeline.record_migration(step=tick, from_rung=rung,
                                       to_rung=rung, reason="pause",
                                       kind="pause", cost_s=0.0)

    def on_resume(self, tick: int) -> None:
        restored = self._ckpt().restore_latest()
        if restored is None:
            raise RuntimeError(
                f"{self.name}: no readable checkpoint to resume from")
        step, state = restored
        self._local_step = int(step)
        self._delta = np.asarray(state["delta"], dtype=np.float32)
        self._energy_j = float(state["energy_j"])
        rung = self.active_rung.name
        self.timeline.record_migration(step=tick, from_rung=rung,
                                       to_rung=rung, reason="resume",
                                       kind="pause", cost_s=0.0)

    # -- the finished update --------------------------------------------------
    def update_payload(self):
        """(delta, crc32) of the finished round — the checksum travels with
        the update so the coordinator can reject in-flight corruption."""
        if not self.done or self._delta is None:
            raise RuntimeError("round not finished")
        delta = np.array(self._delta, copy=True)
        return delta, zlib.crc32(delta.tobytes())


# ---------------------------------------------------------------------------
# one client round, end to end
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ClientOutcome:
    """What the coordinator hears back from one invited device (or doesn't:
    ``status`` offline/preempted/straggler means no update arrived)."""
    cid: int
    status: str  # ok | offline | preempted | straggler | churn
    latency_s: float  # device wall time spent (arrival offset added by caller)
    energy_j: float
    n_samples: int
    device: str
    charging: bool
    delta: Optional[np.ndarray] = None
    checksum: Optional[int] = None
    preemptions: int = 0
    migrations: int = 0
    rung: str = ""


def _round_wall_s(timeline: Timeline, done_tick: Optional[int]) -> float:
    """Wall time of the round from the merged timeline: jobs share each tick,
    so a tick lasts as long as its slowest job's observed quantum; sum over
    the ticks up to the training job's completion."""
    per: Dict[int, float] = {}
    for s in timeline.steps:
        if done_tick is not None and s.step > done_tick:
            continue
        v = s.observed_s if s.observed_s is not None else s.latency_s
        per[s.step] = max(per.get(s.step, 0.0), v)
    return float(sum(per.values()))


def run_client_round(client: FleetClient, rnd: int, t_min: float, cfg, *,
                     ckpt_root: str) -> ClientOutcome:
    """Drive one client's local round through its own SwanRuntime.

    ``cfg`` carries the device-sim knobs (``local_steps``, ``dim``, ``seed``,
    ``fg_prob``, ``fg_power``, ``fg_latency_factor``, ``heat_rate``,
    ``cool_rate``, ``thermal_slowdown``, ``charge_rate``, ``tick_slack``) —
    any object with those attributes works (``FleetConfig`` does).

    The runtime sees the trace-derived device condition at invite time:
    battery level + charging state feed the EnergyLoan, a per-round
    closed-loop ThermalTrace integrates the summed draw, and a
    (seed, cid, round)-deterministic foreground burst may pause the job
    outright mid-round. Mid-round dropout is detected afterwards by probing
    the trace across the round's wall time.
    """
    level, bstate = client.trace.at(t_min)
    rungs = client.rungs
    top_lat = rungs[0].latency_estimate_s
    job = FLTrainJob(client, rnd=rnd, local_steps=cfg.local_steps,
                     dim=cfg.dim, seed=cfg.seed,
                     ckpt_dir=os.path.join(ckpt_root,
                                           f"c{client.cid}_r{rnd}"))
    jobs: List[SocJob] = [job]
    cap = cfg.local_steps + cfg.tick_slack
    rng = np.random.default_rng((cfg.seed, client.cid, int(rnd), 5))
    if float(rng.random()) < cfg.fg_prob:
        start = int(rng.integers(2, max(3, cfg.local_steps)))
        dur = int(rng.integers(2, 7))
        jobs.append(ForegroundAppJob(
            [(start, start + dur)],
            latency_s=cfg.fg_latency_factor * top_lat, power=cfg.fg_power))
    thermal = ThermalTrace(heat_rate=cfg.heat_rate, cool_rate=cfg.cool_rate,
                           slowdown=cfg.thermal_slowdown)
    charging = ChargingTrace(((0, cap, cfg.charge_rate),)) \
        if bstate > 0 else None
    runtime = SwanRuntime(jobs, trace=thermal, energy=client.loan,
                          battery_level=level,
                          energy_unit_j=POWER_NORM * top_lat,
                          charging=charging)
    res = runtime.run(cap)
    if client.policy == "swan":
        client.rung_idx = min(job.controller.idx, len(rungs) - 1)
    migrations = len(job.timeline.migrations) - 2 * job.pauses
    wall = _round_wall_s(res.timeline, job.done_tick)
    base = dict(cid=client.cid, energy_j=job.energy_j,
                n_samples=client.n_samples, device=client.device,
                charging=bool(bstate > 0), preemptions=res.preemptions,
                migrations=max(0, migrations), rung=job.active_rung.name)
    if not job.done:
        status = "preempted" if res.preemptions else "straggler"
        return ClientOutcome(status=status, latency_s=wall, **base)
    # mid-round dropout: the trace may take the device offline while it runs
    for frac in (0.5, 1.0):
        probe = t_min + (wall / 60.0) * frac
        lvl, st = client.trace.at(probe)
        if not (client.loan.available(lvl) and (st >= 0 or lvl > 0.35)):
            return ClientOutcome(status="offline", latency_s=wall * frac,
                                 **base)
    delta, crc = job.update_payload()
    return ClientOutcome(status="ok", latency_s=wall, delta=delta,
                         checksum=crc, **base)
