"""deepseek-moe-16b [moe] — arXiv:2401.06066 (hf tier).

28L d_model=2048 16H (MHA, kv=16), fine-grained MoE: 2 shared + 64 routed
top-6 experts with d_ff=1408; layer 0 dense with d_ff=10944 (hf config).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_k_dense=1,
    dense_d_ff=10944,
    activation="silu",
    norm="rmsnorm",
    rope_theta=10000.0,
    source="arXiv:2401.06066; hf",
)
