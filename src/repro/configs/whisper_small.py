"""whisper-small [audio enc-dec] — arXiv:2212.04356 (unverified tier).

12L (enc+dec) d_model=768 12H (kv=12) d_ff=3072 vocab=51865. Conv frontend is a
STUB: input_specs() supplies precomputed audio-frame embeddings (B, 1500, 768).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    n_encoder_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    use_bias=True,
    pos_embedding="sinusoidal",
    n_audio_frames=1500,
    source="arXiv:2212.04356; unverified",
)
