"""The paper's own training workloads (§5): ResNet34, MobileNetV2, ShuffleNetV2.

ResNet-34 on GoogleSpeech (35 classes, spectrogram treated as 1-channel 32x32
image per FedScale's preprocessing); MobileNetV2 / ShuffleNetV2 on OpenImage
(600 classes). MobileNet/ShuffleNet are the depthwise-convolution-heavy models
whose multi-core cache-thrashing motivates Swan's pruning (paper §3.1, O2).
"""
from repro.configs.base import ModelConfig

RESNET34 = ModelConfig(
    name="resnet34",
    family="cnn",
    cnn_kind="resnet",
    cnn_stages=(3, 4, 6, 3),
    cnn_widths=(64, 128, 256, 512),
    n_classes=35,
    in_channels=1,
    image_size=32,
    source="arXiv:1512.03385 (paper §5: GoogleSpeech)",
)

MOBILENET_V2 = ModelConfig(
    name="mobilenet-v2",
    family="cnn",
    cnn_kind="mobilenet",
    cnn_stages=(1, 2, 3, 4, 3, 3, 1),
    cnn_widths=(16, 24, 32, 64, 96, 160, 320),
    n_classes=600,
    in_channels=3,
    image_size=32,
    source="arXiv:1801.04381 (paper §5: OpenImage)",
)

SHUFFLENET_V2 = ModelConfig(
    name="shufflenet-v2",
    family="cnn",
    cnn_kind="shufflenet",
    cnn_stages=(4, 8, 4),
    cnn_widths=(116, 232, 464),
    n_classes=600,
    in_channels=3,
    image_size=32,
    source="arXiv:1807.11164 (paper §5: OpenImage)",
)
