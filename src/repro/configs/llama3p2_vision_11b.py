"""llama-3.2-vision-11b [vlm] — hf:meta-llama/Llama-3.2-11B-Vision (unverified).

40L text backbone with cross-attn image layers every 5th block. The vision
frontend is a STUB: input_specs() supplies precomputed patch embeddings
(B, 1601, d_model) already projected into the text width.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    activation="silu",
    norm="rmsnorm",
    rope_theta=500000.0,
    cross_attn_every=5,
    n_image_tokens=1601,
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
)
