"""Assigned input shapes. ``decode_*``/``long_*`` lower serve_step, not train_step."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable(config, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; else the documented skip reason."""
    if config.family == "cnn":
        if shape.mode != "train":
            return False, "CNN workloads have no LM decode/prefill step"
        return True, ""
    if shape.name == "long_500k" and not config.sub_quadratic:
        return False, "quadratic attention at 512k context (per-spec skip for full-attention archs)"
    return True, ""
