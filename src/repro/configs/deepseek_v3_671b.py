"""deepseek-v3-671b [moe] — arXiv:2412.19437 (hf tier).

61L d_model=7168 128H MLA (q_lora=1536, kv_lora=512, nope=128, rope=64,
v=128); MoE: 1 shared + 256 routed top-8 with d_ff=2048; first 3 layers dense
(d_ff=18432); 1 MTP module.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    moe_d_ff=2048,
    vocab_size=129280,
    n_experts=256,
    n_shared_experts=1,
    top_k=8,
    first_k_dense=3,
    dense_d_ff=18432,
    use_mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_mtp_modules=1,
    activation="silu",
    norm="rmsnorm",
    rope_theta=10000.0,
    source="arXiv:2412.19437; hf",
)
