"""command-r-35b [dense] — hf:CohereForAI/c4ai-command-r-v01 (unverified tier).

GQA, no-bias, layernorm (Cohere uses non-standard LN w/o bias)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    activation="silu",
    norm="layernorm",
    use_bias=False,
    tie_embeddings=True,
    rope_theta=8000000.0,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
