"""zamba2-2.7b [hybrid] — arXiv:2411.15242 (hf tier).

54 Mamba2 layers d_model=2560, ssm_state=64, plus a SHARED attention+MLP block
(32H, kv=32, d_ff=10240) applied every 6 layers with params reused across
applications (concat[hidden, embed] -> 2d -> d input projection, per Zamba2).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv_width=4,
    ssm_n_groups=1,
    shared_attn_every=6,
    activation="gelu",
    norm="rmsnorm",
    source="arXiv:2411.15242; hf",
)
