"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>`` resolution."""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.configs.shapes import SHAPES, InputShape, applicable  # noqa: F401

from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.zamba2_2p7b import CONFIG as _zamba2
from repro.configs.llama3p2_1b import CONFIG as _llama1b
from repro.configs.granite3_2b import CONFIG as _granite
from repro.configs.command_r_35b import CONFIG as _commandr
from repro.configs.nemotron4_15b import CONFIG as _nemotron
from repro.configs.llama3p2_vision_11b import CONFIG as _llamav
from repro.configs.deepseek_moe_16b import CONFIG as _dsmoe
from repro.configs.deepseek_v3_671b import CONFIG as _dsv3
from repro.configs.rwkv6_7b import CONFIG as _rwkv6
from repro.configs.cnn_paper import MOBILENET_V2, RESNET34, SHUFFLENET_V2

# The 10 assigned architectures (dry-run + roofline cells).
ASSIGNED: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _whisper, _zamba2, _llama1b, _granite, _commandr,
        _nemotron, _llamav, _dsmoe, _dsv3, _rwkv6,
    )
}

# The paper's own workloads (local + FL evaluation).
PAPER_MODELS: dict[str, ModelConfig] = {
    c.name: c for c in (RESNET34, MOBILENET_V2, SHUFFLENET_V2)
}

REGISTRY: dict[str, ModelConfig] = {**ASSIGNED, **PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
