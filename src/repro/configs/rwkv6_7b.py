"""rwkv6-7b (Finch) [ssm] — arXiv:2404.05892 (hf tier).

32L d_model=4096, attention-free time-mix with data-dependent decay
(64 heads x 64), channel-mix d_ff=14336.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # wkv heads (d_model / 64)
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    activation="relu2",  # rwkv channel-mix uses squared relu
    norm="layernorm",
    pos_embedding="none",
    source="arXiv:2404.05892; hf",
)
