"""Unified model configuration.

One dataclass covers every assigned architecture family (dense / MoE / SSM /
hybrid / enc-dec / VLM / CNN). Fields irrelevant to a family keep their
defaults; `family` drives which blocks the registry assembles.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | cnn

    # --- transformer backbone ---
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0  # 0 -> d_model // n_heads
    activation: str = "silu"  # silu | gelu | relu2
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    use_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10000.0
    pos_embedding: str = "rope"  # rope | sinusoidal | learned | none
    max_position: int = 1 << 20

    # --- MoE ---
    n_experts: int = 0  # routed experts (0 -> dense MLP)
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # per-expert hidden (fine-grained)
    first_k_dense: int = 0  # leading layers with dense MLP
    dense_d_ff: int = 0  # hidden for those dense layers (0 -> d_ff)
    router_aux_coef: float = 0.001

    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MTP (deepseek-v3) ---
    n_mtp_modules: int = 0

    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_n_groups: int = 1
    shared_attn_every: int = 0  # zamba2: apply the shared attn block every N layers

    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500  # stub conv frontend output length

    # --- VLM ---
    cross_attn_every: int = 0  # insert cross-attn layer every N decoder layers
    n_image_tokens: int = 1601  # stub vision frontend output length

    # --- CNN (paper's own workloads) ---
    cnn_stages: Tuple[int, ...] = ()
    cnn_widths: Tuple[int, ...] = ()
    n_classes: int = 0
    image_size: int = 32
    in_channels: int = 3
    cnn_kind: str = ""  # resnet | mobilenet | shufflenet

    # --- notes ---
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.family != "cnn" and self.n_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived properties ------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / linear-attn)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return self.family != "cnn"

    # -- parameter accounting (used for MODEL_FLOPS = 6*N*D) ---------------
    def _attn_params(self) -> int:
        d, hd = self.d_model, self.head_dim
        if self.use_mla:
            q = self.d_model * self.q_lora_rank + self.q_lora_rank * self.n_heads * (
                self.qk_nope_head_dim + self.qk_rope_head_dim)
            kv = self.d_model * (self.kv_lora_rank + self.qk_rope_head_dim)
            kv += self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
            o = self.n_heads * self.v_head_dim * d
            return q + kv + o
        nq, nkv = self.n_heads, max(self.n_kv_heads, 1)
        return d * nq * hd + 2 * d * nkv * hd + nq * hd * d

    def _mlp_params(self, ff: int) -> int:
        mult = 2 if self.activation == "relu2" else 3  # gated MLPs have 3 mats
        return mult * self.d_model * ff

    def _ssm_params(self) -> int:
        d_inner = self.ssm_expand * self.d_model
        # mamba2-ish: in_proj (z,x,B,C,dt), conv, out_proj
        p = self.d_model * (2 * d_inner + 2 * self.ssm_n_groups * self.ssm_state)
        p += d_inner * self.ssm_conv_width + d_inner * self.d_model + 2 * d_inner
        return p

    def _rwkv_params(self) -> int:
        d = self.d_model
        tmix = 4 * d * d + d * self.d_ff // 2  # r,k,v,o + lora-ish decay (approx)
        cmix = 2 * d * self.d_ff
        return tmix + cmix

    def param_count(self) -> int:
        """Total parameters (embedding included)."""
        if self.family == "cnn":
            return self._cnn_param_count()
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            per_layer = self._rwkv_params() if "rwkv" in self.name else self._ssm_params()
            total = self.n_layers * per_layer
        elif self.family == "hybrid":
            total = self.n_layers * self._ssm_params()
            if self.shared_attn_every:
                shared = self._attn_params() + self._mlp_params(self.d_ff) + 2 * d * d
                total += shared  # params shared across applications
        else:
            attn = self._attn_params()
            total = 0
            for layer in range(self.n_layers):
                if self.is_moe and layer >= self.first_k_dense:
                    ff = (self.n_experts + self.n_shared_experts) * self._mlp_params(self.moe_d_ff)
                    ff += d * self.n_experts  # router
                else:
                    ff = self._mlp_params(self.dense_d_ff or self.d_ff)
                total += attn + ff
            if self.family == "encdec":
                # encoder stack + decoder cross-attn
                total += self.n_encoder_layers * (attn + self._mlp_params(self.d_ff))
                total += self.n_layers * attn  # cross-attn per decoder layer
            if self.family == "vlm" and self.cross_attn_every:
                total += (self.n_layers // self.cross_attn_every) * self._attn_params()
        return total + emb

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top-k routed)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = self._attn_params()
        total = 0
        for layer in range(self.n_layers):
            if layer >= self.first_k_dense:
                ff = (self.top_k + self.n_shared_experts) * self._mlp_params(self.moe_d_ff)
                ff += d * self.n_experts
            else:
                ff = self._mlp_params(self.dense_d_ff or self.d_ff)
            total += attn + ff
        return total + emb

    def _cnn_param_count(self) -> int:
        # rough but adequate for FLOPs accounting in the SoC model
        total, cin = 0, self.in_channels
        for w, n in zip(self.cnn_widths, self.cnn_stages):
            for _ in range(n):
                if self.cnn_kind == "resnet":
                    total += 2 * 9 * w * w + (cin != w) * cin * w
                else:  # depthwise-separable families
                    total += 9 * w + cin * w + w * w
                cin = w
        total += cin * self.n_classes
        return total

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        kw.update(
            n_layers=min(self.n_layers, 2) or self.n_layers,
            d_model=min(self.d_model, 64) if self.d_model else 0,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=min(self.d_ff, 128) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 256) if self.vocab_size else 0,
            head_dim=16 if self.n_heads else 0,
            n_experts=min(self.n_experts, 8) if self.n_experts else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            top_k=min(self.top_k, 2) if self.top_k else 0,
            moe_d_ff=min(self.moe_d_ff, 32) if self.moe_d_ff else 0,
            first_k_dense=min(self.first_k_dense, 1),
            dense_d_ff=min(self.dense_d_ff, 128) if self.dense_d_ff else 0,
            q_lora_rank=min(self.q_lora_rank, 32) if self.q_lora_rank else 0,
            kv_lora_rank=min(self.kv_lora_rank, 16) if self.kv_lora_rank else 0,
            qk_nope_head_dim=16 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=8 if self.qk_rope_head_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            n_audio_frames=32,
            cross_attn_every=2 if self.cross_attn_every else 0,
            n_image_tokens=16,
            n_mtp_modules=min(self.n_mtp_modules, 1),
            cnn_stages=tuple(min(s, 1) for s in self.cnn_stages),
            cnn_widths=tuple(min(w, 16) for w in self.cnn_widths),
            n_classes=min(self.n_classes, 10) if self.n_classes else 0,
            image_size=min(self.image_size, 16) if self.image_size else 0,
        )
        return ModelConfig(**kw)
