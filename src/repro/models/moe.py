"""Mixture-of-Experts layer (fine-grained, shared + routed top-k, capacity drop).

TPU-native EP design (DESIGN.md §5): activations are replicated across the
``tp``/``ep`` mesh axis (they are only batch-sharded), so expert *dispatch is
communication-free* — each EP rank locally gathers the tokens routed to its
resident experts — and *combine is a single psum* over the EP axis, the same
collective a TP MLP would need anyway. No all-to-all. Over-capacity tokens are
dropped per expert (Switch-style); capacity_factor configures the slack.

Two execution paths:
  - mesh path: shard_map manual over (pod, data, ep) axes;
  - local path: identical math on one device (smoke tests / no mesh).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models.common import activate, dense_init
from repro.models.sharding import get_rules, resolve


def moe_params(key, cfg, dtype=jnp.float32):
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / (d ** 0.5)
    p = {
        "router": dense_init(ks[0], d, E, dtype),
        "experts": {
            "w_gate": (jax.random.normal(ks[1], (E, d, ff)) * scale).astype(dtype),
            "w_up": (jax.random.normal(ks[2], (E, d, ff)) * scale).astype(dtype),
            "w_down": (jax.random.normal(ks[3], (E, ff, d)) * (1.0 / ff ** 0.5)).astype(dtype),
        },
    }
    if cfg.n_shared_experts:
        sff = cfg.n_shared_experts * ff
        kg, ku, kd = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kg, d, sff, dtype),
            "w_up": dense_init(ku, d, sff, dtype),
            "w_down": dense_init(kd, sff, d, dtype),
        }
    return p


def _route(x_flat, router_w, cfg):
    """Token-choice top-k routing. Returns dense gates (T,E) and aux loss."""
    logits = (x_flat @ router_w).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(probs, cfg.top_k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)  # renorm
    T = x_flat.shape[0]
    gates = jnp.zeros_like(probs).at[jnp.arange(T)[:, None], top_i].set(top_g)
    # switch-style load-balance aux: E * sum_e f_e * P_e
    f = (gates > 0).astype(jnp.float32).mean(0)  # fraction routed to e
    pmean = probs.mean(0)
    aux = cfg.n_experts * jnp.sum(f * pmean) * cfg.router_aux_coef
    return gates, aux


def _expert_compute(xb, w_gate, w_up, w_down, activation):
    """xb: (E_loc, C, d) -> (E_loc, C, d)."""
    h = activate(jnp.einsum("ecd,edf->ecf", xb, w_gate), activation)
    h = h * jnp.einsum("ecd,edf->ecf", xb, w_up)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _moe_local(x_flat, gates, experts, cfg, capacity: int, e_offset: int, e_local: int,
               activation: str):
    """Compute routed output for experts [e_offset, e_offset+e_local).

    Per local expert, select its top-``capacity`` tokens by gate value
    (over-capacity tokens are dropped), run the expert MLP, and scatter-add
    the gated results back to token order. All ops are local to the shard.
    """
    T, d = x_flat.shape
    my_gates = jax.lax.dynamic_slice_in_dim(gates, e_offset, e_local, axis=1)  # (T,E_loc)
    cap = min(capacity, T)
    sel_g, sel_i = jax.lax.top_k(my_gates.T, cap)  # (E_loc, C)
    xb = jnp.take(x_flat, sel_i.reshape(-1), axis=0).reshape(e_local, cap, d)
    yb = _expert_compute(xb, experts["w_gate"], experts["w_up"], experts["w_down"], activation)
    yb = yb * sel_g[..., None].astype(yb.dtype)  # gate==0 rows contribute nothing
    y = jnp.zeros((T, d), yb.dtype).at[sel_i.reshape(-1)].add(yb.reshape(-1, d))
    return y


def _capacity(T: int, cfg, capacity_factor: float, min_capacity: int) -> int:
    cap = math.ceil(T * cfg.top_k / cfg.n_experts * capacity_factor)
    return min(T, max(min_capacity, cap))


def apply_moe(p, x, cfg, capacity_factor: float = 1.25, mesh=None,
              activation: Optional[str] = None, min_capacity: int = 4):
    """x: (B,S,d) -> (y, aux_loss). Over-capacity tokens are dropped
    (Switch-style); min_capacity keeps small decode batches drop-free."""
    act = activation or cfg.activation
    B_, S, d = x.shape
    rules = get_rules()
    ep_axes = rules.get("ep")
    if isinstance(ep_axes, str):
        ep_axes = (ep_axes,)
    if mesh is None:
        amesh = compat.get_abstract_mesh()
        mesh = None if (amesh is None or amesh.empty) else amesh
    ep_axes = tuple(a for a in (ep_axes or ()) if mesh is not None and a in mesh.axis_names)

    def shared_out(x_flat):
        if "shared" not in p:
            return 0.0
        h = activate(x_flat @ p["shared"]["w_gate"], act)
        h = h * (x_flat @ p["shared"]["w_up"])
        return h @ p["shared"]["w_down"]

    if mesh is None or not ep_axes:
        # single-shard path
        x_flat = x.reshape(B_ * S, d)
        gates, aux = _route(x_flat, p["router"], cfg)
        capacity = _capacity(B_ * S, cfg, capacity_factor, min_capacity)
        y = _moe_local(x_flat, gates, p["experts"], cfg, capacity, 0, cfg.n_experts, act)
        y = y + shared_out(x_flat)
        return y.reshape(B_, S, d).astype(x.dtype), aux

    # --- mesh path: manual over (batch axes) x (ep axes) -------------------
    batch_axes = rules.get("batch") or ()
    if isinstance(batch_axes, str):
        batch_axes = (batch_axes,)
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    manual = batch_axes + ep_axes
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    ep_size = 1
    for a in ep_axes:
        ep_size *= sizes[a]
    dp_size = 1
    for a in batch_axes:
        dp_size *= sizes[a]
    E = cfg.n_experts
    assert E % ep_size == 0, f"n_experts={E} must divide ep={ep_size}"
    e_local = E // ep_size
    T_local = (B_ // dp_size) * S
    capacity = _capacity(T_local, cfg, capacity_factor, min_capacity)

    x_spec = P(batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None))
    # ---- wide-EP path (deepseek-v3-class; choice.wide_ep binds "ep" to
    # ("model","data")): experts live sharded over the FULL grid and TOKENS
    # move (all-gather over the data overlap + reduce-scatter back) instead of
    # weights — no full-d weight materialization, no per-layer FSDP gather of
    # ~650B expert parameters.
    token_axes = tuple(a for a in ep_axes if a in batch_axes)
    pure_ep = tuple(a for a in ep_axes if a not in batch_axes)
    if token_axes and E % ep_size == 0:
        return _apply_moe_wide_ep(p, x, cfg, mesh, rules, batch_axes, pure_ep,
                                  token_axes, sizes, capacity_factor,
                                  min_capacity, act, x_spec)

    ep_spec0 = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    # fsdp axes the expert weights are STORED sharded on (d / ff dims). The
    # in_specs must match the stored sharding exactly: a mismatched spec makes
    # XLA reshard the whole STACKED weight tensor at the enclosing scan
    # boundary (observed: ~40GB live for deepseek-v3). The FSDP all-gather
    # happens inside, per layer, so only one layer's weights are ever full.
    rules = get_rules()
    fsdp_axes = rules.get("fsdp")
    if isinstance(fsdp_axes, str):
        fsdp_axes = (fsdp_axes,)
    fsdp_axes = tuple(a for a in (fsdp_axes or ()) if a in mesh.axis_names
                      and a not in ep_axes)
    fsdp_spec = (fsdp_axes if len(fsdp_axes) > 1 else
                 (fsdp_axes[0] if fsdp_axes else None))
    manual = tuple(dict.fromkeys(batch_axes + ep_axes + fsdp_axes))
    expert_specs = {
        "w_gate": P(ep_spec0, fsdp_spec), "w_up": P(ep_spec0, fsdp_spec),
        "w_down": P(ep_spec0, None, fsdp_spec),
    }
    shared_spec = {k: P(fsdp_spec, ep_spec0) if k != "w_down" else P(ep_spec0, fsdp_spec)
                   for k in p.get("shared", {})}
    in_specs = (x_spec, P(), expert_specs)
    args = (x, p["router"], p["experts"])
    if "shared" in p:
        in_specs = in_specs + (shared_spec,)
        args = args + (p["shared"],)

    def fn(x_loc, router_w, experts_loc, *maybe_shared):
        Bl, Sl, _ = x_loc.shape
        x_flat = x_loc.reshape(Bl * Sl, d)
        gates, aux = _route(x_flat, router_w, cfg)
        ep_index = 0
        for a in ep_axes:
            ep_index = ep_index * sizes[a] + jax.lax.axis_index(a)
        if fsdp_axes:  # per-layer FSDP unshard of this rank's experts
            experts_loc = {
                "w_gate": jax.lax.all_gather(experts_loc["w_gate"], fsdp_axes,
                                             axis=1, tiled=True),
                "w_up": jax.lax.all_gather(experts_loc["w_up"], fsdp_axes,
                                           axis=1, tiled=True),
                "w_down": jax.lax.all_gather(experts_loc["w_down"], fsdp_axes,
                                             axis=2, tiled=True),
            }
        y = _moe_local(x_flat, gates, experts_loc, cfg, capacity,
                       ep_index * e_local, e_local, act)
        if maybe_shared:
            sh = maybe_shared[0]
            if fsdp_axes:
                sh = {"w_gate": jax.lax.all_gather(sh["w_gate"], fsdp_axes, axis=0, tiled=True),
                      "w_up": jax.lax.all_gather(sh["w_up"], fsdp_axes, axis=0, tiled=True),
                      "w_down": jax.lax.all_gather(sh["w_down"], fsdp_axes, axis=1, tiled=True)}
            h = activate(x_flat @ sh["w_gate"], act)
            h = h * (x_flat @ sh["w_up"])
            y = y + h @ sh["w_down"]
        y = jax.lax.psum(y, ep_axes)  # combine across expert shards
        aux = jax.lax.pmean(aux, manual)
        return y.reshape(Bl, Sl, d).astype(x_loc.dtype), aux

    y, aux = compat.shard_map(
        fn, mesh=mesh, in_specs=in_specs,
        out_specs=(x_spec, P()), check_vma=False,
        axis_names=set(manual))(*args)
    return y, aux


def _apply_moe_wide_ep(p, x, cfg, mesh, rules, batch_axes, ep_axes, fsdp_axes,
                       sizes, capacity_factor, min_capacity, act, x_spec):
    """Wide expert parallelism: experts sharded over (ep x fsdp) axes jointly.

    Each device owns E/(ep*fsdp) complete experts (full d x ff). Tokens are
    all-gathered over the fsdp(data) axes, every device computes its own
    experts' top-capacity tokens, and results return via reduce-scatter over
    data + psum over the ep axis. Collectives move activations (O(T*d)), not
    weights (O(E*d*ff)) — the right trade at deepseek-v3 scale.
    """
    B_, S, d = x.shape
    E = cfg.n_experts
    wide_axes = ep_axes + fsdp_axes
    wide_size = 1
    for a in wide_axes:
        wide_size *= sizes[a]
    e_local = E // wide_size
    dp_size = 1
    for a in batch_axes:
        dp_size *= sizes[a]
    fsdp_size = 1
    for a in fsdp_axes:
        fsdp_size *= sizes[a]
    T_local = (B_ // dp_size) * S
    T_wide = T_local * fsdp_size
    capacity = _capacity(T_wide, cfg, capacity_factor, min_capacity)
    ep_spec0 = ep_axes if len(ep_axes) > 1 else ep_axes[0]
    wide_spec = wide_axes if len(wide_axes) > 1 else wide_axes[0]

    expert_specs = {"w_gate": P(wide_spec), "w_up": P(wide_spec),
                    "w_down": P(wide_spec)}
    fsdp_spec = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
    shared_spec = {k: P(fsdp_spec, ep_spec0) if k != "w_down" else P(ep_spec0, fsdp_spec)
                   for k in p.get("shared", {})}
    in_specs = (x_spec, P(), expert_specs)
    args = (x, p["router"], p["experts"])
    if "shared" in p:
        in_specs = in_specs + (shared_spec,)
        args = args + (p["shared"],)
    manual = tuple(dict.fromkeys(batch_axes + ep_axes + fsdp_axes))

    def fn(x_loc, router_w, experts_loc, *maybe_shared):
        Bl, Sl, _ = x_loc.shape
        x_flat = x_loc.reshape(Bl * Sl, d)
        gates, aux = _route(x_flat, router_w, cfg)
        # gather tokens + gates across the data shards
        x_wide = jax.lax.all_gather(x_flat, fsdp_axes, axis=0, tiled=True)
        g_wide = jax.lax.all_gather(gates, fsdp_axes, axis=0, tiled=True)
        # global expert index of this device's slice
        idx = 0
        for a in wide_axes:
            idx = idx * sizes[a] + jax.lax.axis_index(a)
        y_wide = _moe_local(x_wide, g_wide, experts_loc, cfg, capacity,
                            idx * e_local, e_local, act)
        # combine: reduce-scatter tokens back to their data shard, then sum
        # expert contributions across the ep axis
        y = jax.lax.psum_scatter(y_wide, fsdp_axes, scatter_dimension=0, tiled=True)
        y = jax.lax.psum(y, ep_axes)
        if maybe_shared:
            sh = maybe_shared[0]
            sh = {"w_gate": jax.lax.all_gather(sh["w_gate"], fsdp_axes, axis=0, tiled=True),
                  "w_up": jax.lax.all_gather(sh["w_up"], fsdp_axes, axis=0, tiled=True),
                  "w_down": jax.lax.all_gather(sh["w_down"], fsdp_axes, axis=1, tiled=True)}
            h = activate(x_flat @ sh["w_gate"], act)
            h = h * (x_flat @ sh["w_up"])
            y = y + jax.lax.psum(h @ sh["w_down"], ep_axes)
        aux = jax.lax.pmean(aux, manual)
        return y.reshape(Bl, Sl, d).astype(x_loc.dtype), aux

    y, aux = compat.shard_map(
        fn, mesh=mesh, in_specs=in_specs,
        out_specs=(x_spec, P()), check_vma=False,
        axis_names=set(manual))(*args)
    return y, aux
