"""Whisper-style encoder-decoder backbone.

The conv/audio frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings (B, n_audio_frames, d_model) from input_specs().
Encoder: bidirectional self-attn; decoder: causal self-attn + cross-attn.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.attention import (gqa_decode, gqa_forward, gqa_params,
                                    init_gqa_cache)
from repro.models.common import (apply_mlp, apply_norm, cross_entropy,
                                 embed_tokens, mlp_params, norm_params,
                                 sinusoidal_positions)
from repro.models.sharding import shard
from repro.models.transformer import REMAT_POLICIES, _maybe_remat


def init_encdec(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 6)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": norm_params(cfg, dtype), "ln2": norm_params(cfg, dtype),
                "attn": gqa_params(k1, cfg, dtype), "mlp": mlp_params(k2, cfg, dtype=dtype)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": norm_params(cfg, dtype), "ln2": norm_params(cfg, dtype),
                "ln3": norm_params(cfg, dtype),
                "self_attn": gqa_params(k1, cfg, dtype),
                "cross_attn": gqa_params(k2, cfg, dtype, cross=True),
                "mlp": mlp_params(k3, cfg, dtype=dtype)}

    return {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(ks[1], cfg.n_encoder_layers)),
        "enc_ln_f": norm_params(cfg, dtype),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(ks[2], cfg.n_layers)),
        "dec_ln_f": norm_params(cfg, dtype),
    }


def encode(params, cfg, audio_embed, *, impl="chunked", chunk=1024, remat="none"):
    S = audio_embed.shape[1]
    h = audio_embed + sinusoidal_positions(S, cfg.d_model, audio_embed.dtype)[None]
    h = shard(h, "batch", "seq", None)

    def block(lp, hh):
        a = gqa_forward(lp["attn"], apply_norm(lp["ln1"], hh, cfg.norm), cfg,
                        causal=False, impl=impl, chunk=chunk)
        hh = hh + a
        m = apply_mlp(lp["mlp"], apply_norm(lp["ln2"], hh, cfg.norm), cfg.activation)
        return shard(hh + m, "batch", "seq", None)

    block = _maybe_remat(block, remat)

    def body(carry, lp):
        return block(lp, carry), None

    h, _ = jax.lax.scan(body, h, params["enc_layers"])
    return apply_norm(params["enc_ln_f"], h, cfg.norm)


def _dec_block(lp, hh, enc_h, cfg, impl, chunk, return_kv=False):
    a = gqa_forward(lp["self_attn"], apply_norm(lp["ln1"], hh, cfg.norm), cfg,
                    causal=True, impl=impl, chunk=chunk, return_kv=return_kv)
    if return_kv:
        a, self_kv = a
    hh = hh + a
    c = gqa_forward(lp["cross_attn"], apply_norm(lp["ln2"], hh, cfg.norm), cfg,
                    kv_x=enc_h, causal=False, impl=impl, chunk=chunk, return_kv=return_kv)
    if return_kv:
        c, cross_kv = c
    hh = hh + c
    m = apply_mlp(lp["mlp"], apply_norm(lp["ln3"], hh, cfg.norm), cfg.activation)
    hh = shard(hh + m, "batch", "seq", None)
    if return_kv:
        return hh, {"self": self_kv, "cross": cross_kv}
    return hh


def forward_encdec(params, cfg, tokens, audio_embed, *, impl="chunked", chunk=1024,
                   remat="none", return_cache=False):
    enc_h = encode(params, cfg, audio_embed, impl=impl, chunk=chunk, remat=remat)
    B, S = tokens.shape
    h = embed_tokens(params["embed"], tokens)
    h = h + sinusoidal_positions(S, cfg.d_model, h.dtype)[None]
    block = _maybe_remat(functools.partial(
        _dec_block, enc_h=enc_h, cfg=cfg, impl=impl, chunk=chunk,
        return_kv=return_cache), remat)

    def body(carry, lp):
        if return_cache:
            h2, kv = block(lp, carry)
            return h2, kv
        return block(lp, carry), None

    h, kvs = jax.lax.scan(body, h, params["dec_layers"])
    h = apply_norm(params["dec_ln_f"], h, cfg.norm)
    if return_cache:
        h = h[:, -1:]  # prefill: last-position logits only
    w = shard(params["embed"], "tp", None).T  # vocab-sharded head (see transformer._logits)
    logits = h @ w.astype(h.dtype)
    logits = shard(logits, "batch", "seq", "tp")
    if return_cache:
        return logits, kvs
    return logits


def loss_encdec(params, cfg, batch, *, impl="chunked", chunk=1024, remat="none"):
    tokens = batch["tokens"]
    logits = forward_encdec(params, cfg, tokens, batch["audio_embed"],
                            impl=impl, chunk=chunk, remat=remat)
    return cross_entropy(logits[:, :-1], tokens[:, 1:])


def init_cache_encdec(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    L = cfg.n_layers
    self_c = init_gqa_cache(cfg, batch, max_len, dtype)
    cross_shape = (L, batch, cfg.n_audio_frames, cfg.n_kv_heads, cfg.head_dim)
    return {
        "self": jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (L,) + a.shape).copy(), self_c),
        "cross": {"k": jnp.zeros(cross_shape, dtype), "v": jnp.zeros(cross_shape, dtype)},
    }


def decode_step_encdec(params, cfg, cache, tokens, cache_len):
    """One-token decoder step against a prepared cross-KV cache."""
    h = embed_tokens(params["embed"], tokens)
    pos_table = sinusoidal_positions(cache["self"]["k"].shape[2], cfg.d_model, h.dtype)
    h = h + jax.lax.dynamic_slice_in_dim(pos_table, cache_len, 1, 0)[None]

    def body(carry, xs):
        hh = carry
        lp, sc, cc = xs
        x = apply_norm(lp["ln1"], hh, cfg.norm)
        a, sc_new = gqa_decode(lp["self_attn"], x, sc, cache_len, cfg)
        hh = hh + a
        x = apply_norm(lp["ln2"], hh, cfg.norm)
        c, _ = gqa_decode(lp["cross_attn"], x, None, cache_len, cfg,
                          cross_kv=(cc["k"], cc["v"]))
        hh = hh + c
        m = apply_mlp(lp["mlp"], apply_norm(lp["ln3"], hh, cfg.norm), cfg.activation)
        return hh + m, sc_new

    h, new_self = jax.lax.scan(body, h, (params["dec_layers"], cache["self"], cache["cross"]))
    h = apply_norm(params["dec_ln_f"], h, cfg.norm)
    w = shard(params["embed"], "tp", None).T
    logits = h @ w.astype(h.dtype)
    return logits, {"self": new_self, "cross": cache["cross"]}
