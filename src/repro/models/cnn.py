"""The paper's CNN workloads: ResNet34, MobileNetV2, ShuffleNetV2 (NHWC).

MobileNet/ShuffleNet are depthwise-convolution-heavy — the op class whose
multi-core cache-thrashing motivates Swan's choice pruning (paper §3.1). The
depthwise convs route through kernels/ops.py so the Pallas TPU kernel is used
when impl="pallas" (interpret-mode on CPU), else the jnp reference.

Normalization uses channel GroupNorm instead of BatchNorm (functional, no
running stats); FLOP/byte profile is equivalent for throughput studies
(DESIGN.md §8).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


def conv2d(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=groups)


def depthwise_conv2d(x, w, stride=1, impl="jnp"):
    """x: (B,H,W,C), w: (kh,kw,1,C)."""
    if impl == "pallas" and stride == 1:
        from repro.kernels import ops as kops
        return kops.depthwise_conv(x, w[:, :, 0, :])
    return conv2d(x, w, stride=stride, groups=x.shape[-1])


def _gn(p, x, groups=8):
    B, H, W, C = x.shape
    g = min(groups, C)
    while C % g:
        g -= 1
    xf = x.astype(jnp.float32).reshape(B, H, W, g, C // g)
    mu = xf.mean((1, 2, 4), keepdims=True)
    var = ((xf - mu) ** 2).mean((1, 2, 4), keepdims=True)
    xf = ((xf - mu) * jax.lax.rsqrt(var + 1e-5)).reshape(B, H, W, C)
    return (xf * p["scale"] + p["bias"]).astype(x.dtype)


def _conv_init(key, kh, kw, cin, cout, dtype=jnp.float32):
    fan = kh * kw * cin
    return (jax.random.normal(key, (kh, kw, cin, cout)) * (2.0 / fan) ** 0.5).astype(dtype)


def _norm_init(c, dtype=jnp.float32):
    return {"scale": jnp.ones((c,), dtype), "bias": jnp.zeros((c,), dtype)}


# --------------------------- ResNet34 --------------------------------------

def _init_resnet(key, cfg, dtype):
    ks = iter(jax.random.split(key, 200))
    p = {"stem": {"w": _conv_init(next(ks), 3, 3, cfg.in_channels, cfg.cnn_widths[0], dtype),
                  "n": _norm_init(cfg.cnn_widths[0], dtype)}, "stages": []}
    cin = cfg.cnn_widths[0]
    for w, n in zip(cfg.cnn_widths, cfg.cnn_stages):
        stage = []
        for b in range(n):
            blk = {"w1": _conv_init(next(ks), 3, 3, cin, w, dtype), "n1": _norm_init(w, dtype),
                   "w2": _conv_init(next(ks), 3, 3, w, w, dtype), "n2": _norm_init(w, dtype)}
            if cin != w:
                blk["proj"] = _conv_init(next(ks), 1, 1, cin, w, dtype)
            stage.append(blk)
            cin = w
        p["stages"].append(stage)
    p["fc"] = (jax.random.normal(next(ks), (cin, cfg.n_classes)) * 0.01).astype(dtype)
    return p


def _apply_resnet(p, x, cfg, impl):
    x = jax.nn.relu(_gn(p["stem"]["n"], conv2d(x, p["stem"]["w"])))
    for si, stage in enumerate(p["stages"]):
        for bi, blk in enumerate(stage):
            stride = 2 if (si > 0 and bi == 0) else 1
            h = jax.nn.relu(_gn(blk["n1"], conv2d(x, blk["w1"], stride=stride)))
            h = _gn(blk["n2"], conv2d(h, blk["w2"]))
            skip = x
            if "proj" in blk:
                skip = conv2d(x, blk["proj"], stride=stride)
            elif stride != 1:
                skip = x[:, ::stride, ::stride]
            x = jax.nn.relu(h + skip)
    x = x.mean((1, 2))
    return x @ p["fc"]


# --------------------------- MobileNetV2 ------------------------------------

_MBN_STRIDES = (1, 2, 2, 2, 1, 2, 1)


def _init_mobilenet(key, cfg, dtype):
    ks = iter(jax.random.split(key, 300))
    stem_c = 32
    p = {"stem": {"w": _conv_init(next(ks), 3, 3, cfg.in_channels, stem_c, dtype),
                  "n": _norm_init(stem_c, dtype)}, "stages": []}
    cin = stem_c
    for w, n, s in zip(cfg.cnn_widths, cfg.cnn_stages, _MBN_STRIDES):
        stage = []
        for b in range(n):
            exp = cin * 6 if cin != 16 else cin
            blk = {"we": _conv_init(next(ks), 1, 1, cin, exp, dtype), "ne": _norm_init(exp, dtype),
                   "wd": _conv_init(next(ks), 3, 3, 1, exp, dtype), "nd": _norm_init(exp, dtype),
                   "wp": _conv_init(next(ks), 1, 1, exp, w, dtype), "np_": _norm_init(w, dtype)}
            stage.append(blk)
            cin = w
        p["stages"].append(stage)
    head_c = 1280
    p["head"] = {"w": _conv_init(next(ks), 1, 1, cin, head_c, dtype), "n": _norm_init(head_c, dtype)}
    p["fc"] = (jax.random.normal(next(ks), (head_c, cfg.n_classes)) * 0.01).astype(dtype)
    return p


def _apply_mobilenet(p, x, cfg, impl):
    x = jax.nn.relu6(_gn(p["stem"]["n"], conv2d(x, p["stem"]["w"], stride=1)))
    for si, stage in enumerate(p["stages"]):
        for bi, blk in enumerate(stage):
            stride = _MBN_STRIDES[si] if bi == 0 else 1
            h = jax.nn.relu6(_gn(blk["ne"], conv2d(x, blk["we"])))
            h = jax.nn.relu6(_gn(blk["nd"], depthwise_conv2d(h, blk["wd"],
                                                             stride=stride, impl=impl)))
            h = _gn(blk["np_"], conv2d(h, blk["wp"]))
            if stride == 1 and x.shape[-1] == h.shape[-1]:
                h = h + x
            x = h
    x = jax.nn.relu6(_gn(p["head"]["n"], conv2d(x, p["head"]["w"])))
    return x.mean((1, 2)) @ p["fc"]


# --------------------------- ShuffleNetV2 -----------------------------------

def _channel_shuffle(x, groups=2):
    B, H, W, C = x.shape
    return x.reshape(B, H, W, groups, C // groups).swapaxes(3, 4).reshape(B, H, W, C)


def _init_shufflenet(key, cfg, dtype):
    ks = iter(jax.random.split(key, 300))
    stem_c = 24
    p = {"stem": {"w": _conv_init(next(ks), 3, 3, cfg.in_channels, stem_c, dtype),
                  "n": _norm_init(stem_c, dtype)}, "stages": []}
    cin = stem_c
    for w, n in zip(cfg.cnn_widths, cfg.cnn_stages):
        stage = []
        for b in range(n):
            if b == 0:  # downsample unit: both branches convolved, concat doubles
                half = w // 2
                blk = {"l_wd": _conv_init(next(ks), 3, 3, 1, cin, dtype), "l_nd": _norm_init(cin, dtype),
                       "l_wp": _conv_init(next(ks), 1, 1, cin, half, dtype), "l_np": _norm_init(half, dtype),
                       "r_w1": _conv_init(next(ks), 1, 1, cin, half, dtype), "r_n1": _norm_init(half, dtype),
                       "r_wd": _conv_init(next(ks), 3, 3, 1, half, dtype), "r_nd": _norm_init(half, dtype),
                       "r_wp": _conv_init(next(ks), 1, 1, half, w - half, dtype), "r_np": _norm_init(w - half, dtype)}
            else:
                half = w // 2
                blk = {"r_w1": _conv_init(next(ks), 1, 1, half, half, dtype), "r_n1": _norm_init(half, dtype),
                       "r_wd": _conv_init(next(ks), 3, 3, 1, half, dtype), "r_nd": _norm_init(half, dtype),
                       "r_wp": _conv_init(next(ks), 1, 1, half, half, dtype), "r_np": _norm_init(half, dtype)}
            stage.append(blk)
            cin = w
        p["stages"].append(stage)
    head_c = 1024
    p["head"] = {"w": _conv_init(next(ks), 1, 1, cin, head_c, dtype), "n": _norm_init(head_c, dtype)}
    p["fc"] = (jax.random.normal(next(ks), (head_c, cfg.n_classes)) * 0.01).astype(dtype)
    return p


def _apply_shufflenet(p, x, cfg, impl):
    x = jax.nn.relu(_gn(p["stem"]["n"], conv2d(x, p["stem"]["w"], stride=1)))
    for stage in p["stages"]:
        for blk in stage:
            if "l_wd" in blk:  # downsample unit
                left = _gn(blk["l_nd"], depthwise_conv2d(x, blk["l_wd"], stride=2, impl=impl))
                left = jax.nn.relu(_gn(blk["l_np"], conv2d(left, blk["l_wp"])))
                r = jax.nn.relu(_gn(blk["r_n1"], conv2d(x, blk["r_w1"])))
                r = _gn(blk["r_nd"], depthwise_conv2d(r, blk["r_wd"], stride=2, impl=impl))
                r = jax.nn.relu(_gn(blk["r_np"], conv2d(r, blk["r_wp"])))
                x = jnp.concatenate([left, r], -1)
            else:
                half = x.shape[-1] // 2
                left, r = x[..., :half], x[..., half:]
                r = jax.nn.relu(_gn(blk["r_n1"], conv2d(r, blk["r_w1"])))
                r = _gn(blk["r_nd"], depthwise_conv2d(r, blk["r_wd"], impl=impl))
                r = jax.nn.relu(_gn(blk["r_np"], conv2d(r, blk["r_wp"])))
                x = jnp.concatenate([left, r], -1)
            x = _channel_shuffle(x)
    x = jax.nn.relu(_gn(p["head"]["n"], conv2d(x, p["head"]["w"])))
    return x.mean((1, 2)) @ p["fc"]


# --------------------------- public API -------------------------------------

_INITS = {"resnet": _init_resnet, "mobilenet": _init_mobilenet, "shufflenet": _init_shufflenet}
_APPLYS = {"resnet": _apply_resnet, "mobilenet": _apply_mobilenet, "shufflenet": _apply_shufflenet}


def init_cnn(key, cfg, dtype=jnp.float32):
    return _INITS[cfg.cnn_kind](key, cfg, dtype)


def forward_cnn(params, cfg, images, impl="jnp"):
    return _APPLYS[cfg.cnn_kind](params, images, cfg, impl)


def loss_cnn(params, cfg, batch, impl="jnp"):
    logits = forward_cnn(params, cfg, batch["images"], impl=impl).astype(jnp.float32)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, -1)
    gold = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
    return (logz - gold).mean()
