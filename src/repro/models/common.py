"""Shared layer primitives (pure JAX, functional params-as-dicts)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.sharding import shard


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * s).astype(dtype)


def norm_params(cfg, dtype=jnp.float32):
    p = {"scale": jnp.ones((cfg.d_model,), dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((cfg.d_model,), dtype)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        return (xf * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
    xf = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = xf * p["scale"].astype(jnp.float32)
    if "bias" in p:
        out = out + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def activate(x, kind: str):
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(kind)


def mlp_params(key, cfg, d_ff: Optional[int] = None, dtype=jnp.float32):
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], cfg.d_model, ff, dtype),
         "w_down": dense_init(ks[1], ff, cfg.d_model, dtype)}
    if cfg.activation != "relu2":  # gated (SwiGLU / GeGLU)
        p["w_gate"] = dense_init(ks[2], cfg.d_model, ff, dtype)
    return p


def apply_mlp(p, x, activation: str):
    h = activate(x @ p.get("w_gate", p["w_up"]), activation)
    if "w_gate" in p:
        h = h * (x @ p["w_up"])
    h = shard(h, "batch", "seq", "tp")
    return h @ p["w_down"]


# --- rotary embeddings ------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd) rotated pairwise; positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int, dtype=jnp.float32):
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d_model)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1).astype(dtype)[:, :d_model]


def embed_tokens(embed, tokens):
    """Sharded-friendly embedding lookup via one-hot-free take."""
    out = jnp.take(embed, tokens, axis=0)
    return shard(out, "batch", "seq", None)


def cross_entropy(logits, labels, mask=None):
    """Mean next-token CE in fp32; labels == -1 are ignored.

    The gold logit is extracted with a masked reduction (iota compare) rather
    than take_along_axis: a gather over a vocab-sharded logits tensor forces
    SPMD to replicate it ("involuntary full rematerialization"), while the
    masked reduce partitions cleanly (per-shard partial + all-reduce).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    onehot = vocab_iota == jnp.maximum(labels, 0)[..., None]
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    valid = (labels >= 0) if mask is None else mask & (labels >= 0)
    nll = jnp.where(valid, nll, 0.0)
    return nll.sum() / jnp.maximum(valid.sum(), 1)
