"""Unified Model API over all families.

``build_model(cfg)`` returns a ``Model`` whose functions are pure (params and
batch in, arrays out) so they can be jitted/AOT-lowered with ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import cnn, encdec, transformer


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable[[Any], Any]  # rng -> params
    forward: Callable[[Any, dict], Any]  # (params, batch) -> logits
    loss: Callable[[Any, dict], Any]  # (params, batch) -> scalar
    prefill: Optional[Callable] = None  # (params, batch) -> (logits, cache)
    init_cache: Optional[Callable] = None  # (batch, max_len, dtype) -> cache
    decode_step: Optional[Callable] = None  # (params, cache, tokens, cache_len) -> (logits, cache)
    # paged KV layout (dense/moe only): pools + block tables instead of slabs
    init_paged_cache: Optional[Callable] = None  # (num_blocks, block_size, dtype) -> pools
    paged_decode_step: Optional[Callable] = None  # (params, pools, tokens, cache_len, block_table) -> (logits, pools)
    # chunked paged prefill: ingest one block-sized prompt chunk straight
    # into the pools (write=False recomputes against prefix-hit blocks)
    paged_prefill_step: Optional[Callable] = None  # (params, pools, tokens, start, block_table, last_pos, write) -> (logits, pools)
    # speculative verify (dense/moe GQA only): score a (B, S) draft window
    # in one pass; returns (B, S, V) logits + the cache with the window's
    # KV written (rollback is the caller's cache_len bookkeeping)
    spec_decode_step: Optional[Callable] = None  # (params, cache, tokens, cache_len) -> (logits, cache)
    paged_spec_decode_step: Optional[Callable] = None  # (params, pools, tokens, cache_len, block_table) -> (logits, pools)
    # the exact build_model kwargs this model was constructed with, so a
    # single-knob rebuild (e.g. serve.set_attn_impl) preserves the rest
    build_kwargs: dict = dataclasses.field(default_factory=dict)


def rebuild_model(model: "Model", **overrides) -> "Model":
    """Rebuild a model changing only the given build_model kwargs."""
    kw = dict(model.build_kwargs)
    kw.update(overrides)
    return build_model(model.cfg, **kw)


def build_model(cfg: ModelConfig, *, impl: str = "chunked", chunk: int = 1024,
                remat: str = "none", param_dtype=jnp.float32,
                moe_cf: float = 1.25) -> Model:
    kw = dict(impl=impl, chunk=chunk, remat=remat, param_dtype=param_dtype,
              moe_cf=moe_cf)
    if cfg.family == "cnn":
        return Model(
            cfg=cfg,
            init=lambda key: cnn.init_cnn(key, cfg, param_dtype),
            forward=lambda p, b: cnn.forward_cnn(p, cfg, b["images"],
                                                 impl="pallas" if impl == "pallas" else "jnp"),
            loss=lambda p, b: cnn.loss_cnn(p, cfg, b,
                                           impl="pallas" if impl == "pallas" else "jnp"),
            build_kwargs=kw,
        )

    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg, param_dtype),
            forward=lambda p, b: encdec.forward_encdec(
                p, cfg, b["tokens"], b["audio_embed"], impl=impl, chunk=chunk, remat=remat),
            loss=lambda p, b: encdec.loss_encdec(p, cfg, b, impl=impl, chunk=chunk, remat=remat),
            prefill=lambda p, b: encdec.forward_encdec(
                p, cfg, b["tokens"], b["audio_embed"], impl=impl, chunk=chunk,
                return_cache=True),
            init_cache=lambda batch, max_len, dtype=jnp.bfloat16: encdec.init_cache_encdec(
                cfg, batch, max_len, dtype),
            decode_step=lambda p, cache, tokens, cache_len: encdec.decode_step_encdec(
                p, cfg, cache, tokens, cache_len),
            build_kwargs=kw,
        )

    def fwd(p, b):
        logits, aux, h = transformer.forward_decoder(
            p, cfg, b["tokens"], image_embed=b.get("image_embed"),
            audio_embed=b.get("audio_embed"), impl=impl, chunk=chunk, remat=remat,
            moe_cf=moe_cf)
        return logits

    return Model(
        cfg=cfg,
        init=lambda key: transformer.init_decoder(key, cfg, param_dtype),
        forward=fwd,
        loss=lambda p, b: transformer.loss_decoder(p, cfg, b, impl=impl, chunk=chunk,
                                                   remat=remat, moe_cf=moe_cf),
        prefill=lambda p, b: transformer.prefill_decoder(
            p, cfg, b["tokens"], image_embed=b.get("image_embed"),
            audio_embed=b.get("audio_embed"), impl=impl, chunk=chunk, moe_cf=moe_cf,
            last_pos=b.get("last_pos")),
        init_cache=lambda batch, max_len, dtype=jnp.bfloat16: transformer.init_cache_decoder(
            cfg, batch, max_len, dtype),
        decode_step=lambda p, cache, tokens, cache_len: transformer.decode_step_decoder(
            p, cfg, cache, tokens, cache_len, impl=impl, moe_cf=moe_cf),
        init_paged_cache=(
            (lambda num_blocks, block_size, dtype=jnp.bfloat16:
             transformer.init_paged_cache_decoder(cfg, num_blocks, block_size, dtype))
            if cfg.family in ("dense", "moe") else None),
        paged_decode_step=(
            (lambda p, cache, tokens, cache_len, block_table:
             transformer.decode_step_decoder(p, cfg, cache, tokens, cache_len,
                                             impl=impl, moe_cf=moe_cf,
                                             block_table=block_table))
            if cfg.family in ("dense", "moe") else None),
        paged_prefill_step=(
            (lambda p, cache, tokens, start, block_table, last_pos=None,
                    write=True:
             transformer.paged_prefill_step_decoder(
                 p, cfg, cache, tokens, start, block_table,
                 last_pos=last_pos, write=write, moe_cf=moe_cf))
            if cfg.family in ("dense", "moe") else None),
        spec_decode_step=(
            (lambda p, cache, tokens, cache_len:
             transformer.spec_decode_step_decoder(p, cfg, cache, tokens,
                                                  cache_len, impl=impl,
                                                  moe_cf=moe_cf))
            if cfg.family in ("dense", "moe") and not cfg.use_mla else None),
        paged_spec_decode_step=(
            (lambda p, cache, tokens, cache_len, block_table:
             transformer.spec_decode_step_decoder(p, cfg, cache, tokens,
                                                  cache_len, impl=impl,
                                                  moe_cf=moe_cf,
                                                  block_table=block_table))
            if cfg.family in ("dense", "moe") and not cfg.use_mla else None),
        build_kwargs=kw,
    )
