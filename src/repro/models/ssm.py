"""State-space blocks: Mamba2 (chunked SSD) and RWKV6 (data-dependent decay).

Both provide a full-sequence train/prefill path and an O(1)-state decode step,
which is what makes the ``long_500k`` cell sub-quadratic.

Mamba2 recurrence (scalar-per-head A, groups share B/C):
    h_t = exp(A * dt_t) h_{t-1} + dt_t * B_t x_t        y_t = C_t . h_t + D x_t
computed in chunks of L: intra-chunk quadratic form + inter-chunk state carry
(the SSD algorithm), so the HLO is matmul-dominated instead of a length-S loop.

RWKV6 recurrence (per-channel data-dependent decay w_t, bonus u):
    y_t = r_t . (S_{t-1} + diag(u) k_t^T v_t)           S_t = diag(w_t) S_{t-1} + k_t^T v_t
also computed in the chunked linear-attention form.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------

MAMBA_HEAD_DIM = 64


def mamba2_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    hd = MAMBA_HEAD_DIM
    nh = d_inner // hd
    g, N = cfg.ssm_n_groups, cfg.ssm_state
    conv_ch = d_inner + 2 * g * N
    return d_inner, hd, nh, g, N, conv_ch


def mamba2_params(key, cfg, dtype=jnp.float32):
    d_inner, hd, nh, g, N, conv_ch = mamba2_dims(cfg)
    d_in_proj = 2 * d_inner + 2 * g * N + nh
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], cfg.d_model, d_in_proj, dtype),
        "w_conv": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch)) * 0.1).astype(dtype),
        "A_log": jnp.zeros((nh,), dtype),
        "dt_bias": jnp.full((nh,), math.log(math.e - 1), dtype),  # softplus^-1(1)
        "D": jnp.ones((nh,), dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[2], d_inner, cfg.d_model, dtype),
    }


def _causal_conv1d(u, w):
    """Depthwise causal conv. u: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    for i in range(W):
        out = out + pad[:, i:i + u.shape[1], :] * w[i]
    return out


def _mamba_project(p, x, cfg):
    d_inner, hd, nh, g, N, conv_ch = mamba2_dims(cfg)
    zxbcdt = x @ p["w_in"]
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:d_inner + conv_ch]
    dt = zxbcdt[..., d_inner + conv_ch:]
    return z, xBC, dt


def _mamba_split(xBC, cfg, B_, S):
    d_inner, hd, nh, g, N, _ = mamba2_dims(cfg)
    xs = xBC[..., :d_inner].reshape(B_, S, nh, hd)
    Bm = xBC[..., d_inner:d_inner + g * N].reshape(B_, S, g, N)
    Cm = xBC[..., d_inner + g * N:].reshape(B_, S, g, N)
    return xs, Bm, Cm


def _mamba_out(p, y, z, cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    B_, S = y.shape[:2]
    y = y.reshape(B_, S, d_inner) * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    yf = yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-5)
    y = (yf * p["norm_scale"].astype(jnp.float32)).astype(y.dtype)
    return y @ p["w_out"]


def mamba2_forward(p, x, cfg, chunk: int = 256, return_state: bool = False):
    """Chunked SSD scan. x: (B,S,d) -> (B,S,d)."""
    B_, S, _ = x.shape
    d_inner, hd, nh, g, N, conv_ch = mamba2_dims(cfg)
    hpg = nh // g
    z, xBC_raw, dt_raw = _mamba_project(p, x, cfg)
    xBC = jax.nn.silu(_causal_conv1d(xBC_raw, p["w_conv"]))
    xs, Bm, Cm = _mamba_split(xBC, cfg, B_, S)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    loga = dt * A[None, None, :]  # (B,S,nh), negative

    L = min(chunk, S)
    n_chunks = -(-S // L)
    pad = n_chunks * L - S
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(a):
        r = a.reshape((B_, n_chunks, L) + a.shape[2:])
        return jnp.moveaxis(r, 1, 0)

    xs_c, Bm_c, Cm_c, dt_c, la_c = map(to_chunks, (xs, Bm, Cm, dt, loga))
    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(h, xs_):
        # h: (B, g, hpg, N, hd) fp32 state at chunk start
        xb, Bb, Cb, dtb, lab = xs_
        xb = xb.astype(jnp.float32).reshape(B_, L, g, hpg, hd)
        Bb = Bb.astype(jnp.float32)
        Cb = Cb.astype(jnp.float32)
        cum = jnp.cumsum(lab, axis=1)  # (B,L,nh)
        cum_h = cum.reshape(B_, L, g, hpg)
        # intra-chunk: y_t += sum_{s<=t} (C_t.B_s) exp(cum_t-cum_s) dt_s x_s
        dots = jnp.einsum("btgn,bsgn->bgts", Cb, Bb)
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,t,s,nh)
        decay = jnp.where(causal[None, :, :, None], decay, -jnp.inf)
        w = jnp.exp(decay) * dtb[:, None, :, :]
        wg = w.reshape(B_, L, L, g, hpg)
        y_intra = jnp.einsum("bgts,btsgh,bsghd->btghd", dots, wg, xb)
        # inter-chunk: y_t += C_t . h * exp(cum_t)
        y_inter = jnp.einsum("btgn,bghnd,btgh->btghd", Cb, h, jnp.exp(cum_h))
        # state: h' = h*exp(cum_L) + sum_s exp(cum_L-cum_s) dt_s B_s x_s
        wlast = jnp.exp(cum[:, -1:, :] - cum) * dtb  # (B,L,nh)
        dstate = jnp.einsum("bsgn,bsgh,bsghd->bghnd",
                            Bb, wlast.reshape(B_, L, g, hpg), xb)
        h_new = h * jnp.exp(cum_h[:, -1])[..., None, None] + dstate
        y = (y_intra + y_inter).reshape(B_, L, nh, hd)
        return h_new, y

    h0 = jnp.zeros((B_, g, hpg, N, hd), jnp.float32)
    # checkpoint each chunk: backward recomputes the intra-chunk quadratics
    # instead of saving O(L^2) decay/score residuals per chunk
    h_fin, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                             (xs_c, Bm_c, Cm_c, dt_c, la_c))
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, n_chunks * L, nh, hd)[:, :S]
    y = y + xs[:, :S].astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    out = _mamba_out(p, y.astype(x.dtype), z, cfg)
    if return_state:
        W = cfg.ssm_conv_width
        conv_tail = xBC_raw[:, -(W - 1):] if S >= W - 1 else jnp.pad(
            xBC_raw, ((0, 0), (W - 1 - S, 0), (0, 0)))
        state = {"h": h_fin.reshape(B_, nh, N, hd), "conv": conv_tail}
        return out, state
    return out


def init_mamba_state(cfg, batch: int, dtype=jnp.float32):
    d_inner, hd, nh, g, N, conv_ch = mamba2_dims(cfg)
    return {
        "h": jnp.zeros((batch, nh, N, hd), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
    }


def mamba2_decode(p, x, state, cfg):
    """One-token step. x: (B,1,d)."""
    B_ = x.shape[0]
    d_inner, hd, nh, g, N, conv_ch = mamba2_dims(cfg)
    hpg = nh // g
    z, xBC_raw, dt_raw = _mamba_project(p, x, cfg)
    xBC_t = xBC_raw[:, 0]
    conv_buf = jnp.concatenate([state["conv"].astype(xBC_t.dtype), xBC_t[:, None]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", conv_buf, p["w_conv"])
    xBC = jax.nn.silu(conv_out)[:, None]
    xs, Bm, Cm = _mamba_split(xBC, cfg, B_, 1)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt * A[None])  # (B,nh)
    xs_f = xs[:, 0].astype(jnp.float32).reshape(B_, g, hpg, hd)
    Bf = Bm[:, 0].astype(jnp.float32)
    Cf = Cm[:, 0].astype(jnp.float32)
    h = state["h"].reshape(B_, g, hpg, N, hd)
    dstate = jnp.einsum("bgn,bgh,bghd->bghnd", Bf, dt.reshape(B_, g, hpg), xs_f)
    h = h * a.reshape(B_, g, hpg)[..., None, None] + dstate
    y = jnp.einsum("bgn,bghnd->bghd", Cf, h).reshape(B_, 1, nh, hd)
    y = y + xs.astype(jnp.float32) * p["D"].astype(jnp.float32)[None, None, :, None]
    new_state = {"h": h.reshape(B_, nh, N, hd), "conv": conv_buf[:, 1:]}
    return _mamba_out(p, y.astype(x.dtype), z, cfg), new_state


# ---------------------------------------------------------------------------
# RWKV6 (Finch)
# ---------------------------------------------------------------------------

_DECAY_RANK = 64


def rwkv6_params(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 11)
    return {
        "tmix": {
            "w_r": dense_init(ks[0], d, d, dtype),
            "w_k": dense_init(ks[1], d, d, dtype),
            "w_v": dense_init(ks[2], d, d, dtype),
            "w_g": dense_init(ks[3], d, d, dtype),
            "w_o": dense_init(ks[4], d, d, dtype),
            "w_decay_a": dense_init(ks[5], d, _DECAY_RANK, dtype),
            "w_decay_b": dense_init(ks[6], _DECAY_RANK, d, dtype),
            "decay_base": jnp.full((d,), -6.0, dtype),
            "u": (jax.random.normal(ks[7], (H, hd)) * 0.1).astype(dtype),
            "mix": jnp.full((5, d), 0.5, dtype),  # r,k,v,g,w token-shift coefs
            "ln_scale": jnp.ones((d,), dtype),
        },
        "cmix": {
            "w_kc": dense_init(ks[8], d, cfg.d_ff, dtype),
            "w_vc": dense_init(ks[9], cfg.d_ff, d, dtype),
            "w_rc": dense_init(ks[10], d, d, dtype),
            "mix": jnp.full((2, d), 0.5, dtype),
        },
    }


def _token_shift(x, prev=None):
    """Shift right by one along seq; ``prev`` supplies position -1 for decode."""
    if prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if x.shape[1] == 1:
        return prev[:, None]
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _rwkv_tmix_inputs(p, x, xx, cfg):
    H, hd = cfg.n_heads, cfg.head_dim
    B_, S, d = x.shape
    mix = p["mix"]
    mx = [x + (xx - x) * mix[i] for i in range(5)]
    r = (mx[0] @ p["w_r"]).reshape(B_, S, H, hd)
    k = (mx[1] @ p["w_k"]).reshape(B_, S, H, hd)
    v = (mx[2] @ p["w_v"]).reshape(B_, S, H, hd)
    g = mx[3] @ p["w_g"]
    wraw = p["decay_base"].astype(jnp.float32) + (
        jnp.tanh(mx[4] @ p["w_decay_a"]) @ p["w_decay_b"]).astype(jnp.float32)
    logw = -jnp.exp(wraw)  # (B,S,d) log-decay, negative
    return r, k, v, g, logw.reshape(B_, S, H, hd)


def _rwkv_out(p, y, g, cfg):
    B_, S = y.shape[:2]
    H, hd = cfg.n_heads, cfg.head_dim
    yf = y.astype(jnp.float32)  # per-head groupnorm
    mu = yf.mean(-1, keepdims=True)
    var = ((yf - mu) ** 2).mean(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + 1e-5)
    yf = yf.reshape(B_, S, H * hd) * p["ln_scale"].astype(jnp.float32)
    return (yf.astype(g.dtype) * jax.nn.silu(g)) @ p["w_o"]


def rwkv6_tmix(p, x, cfg, state=None, chunk: int = 128, return_state: bool = False):
    """Full-sequence WKV, chunked linear-attention form. x: (B,S,d)."""
    B_, S, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    xx = _token_shift(x)
    r, k, v, g, logw = _rwkv_tmix_inputs(p, x, xx, cfg)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    u = p["u"].astype(jnp.float32)

    L = min(chunk, S)
    n_chunks = -(-S // L)
    pad = n_chunks * L - S
    if pad:
        rf, kf, vf = (jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0))) for a in (rf, kf, vf))
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def to_chunks(a):
        return jnp.moveaxis(a.reshape(B_, n_chunks, L, H, hd), 1, 0)

    rc, kc, vc, wc = map(to_chunks, (rf, kf, vf, logw))
    strict_causal = jnp.tril(jnp.ones((L, L), bool), k=-1)

    def step(Sstate, xs_):
        rb, kb, vb, lw = xs_  # (B,L,H,hd)
        cum = jnp.cumsum(lw, axis=1)
        cum_excl = cum - lw  # sum_{i<=t-1}
        r_dec = rb * jnp.exp(cum_excl)
        k_dec = kb * jnp.exp(-cum)
        # intra: scores[t,s] = sum_c r_t k_s exp(cum_{t-1}-cum_s), s<t; diag via u
        scores = jnp.einsum("blhk,bmhk->bhlm", r_dec, k_dec)
        scores = jnp.where(strict_causal[None, None], scores, 0.0)
        diag = jnp.einsum("blhk,blhk->blh", rb, kb * u[None, None])
        y_intra = jnp.einsum("bhlm,bmhv->blhv", scores, vb) + diag[..., None] * vb
        # inter: y_t += r_t exp(cum_{t-1}) . S
        y_inter = jnp.einsum("blhk,bhkv->blhv", r_dec, Sstate)
        # state: S' = diag(exp(cum_L)) S + sum_s exp(cum_L-cum_s) k_s v_s
        wlast = jnp.exp(cum[:, -1][:, None] - cum)
        S_new = Sstate * jnp.exp(cum[:, -1])[..., None] + jnp.einsum(
            "blhk,blhv->bhkv", kb * wlast, vb)
        return S_new, y_inter + y_intra

    S0 = state if state is not None else jnp.zeros((B_, H, hd, hd), jnp.float32)
    S_fin, ys = jax.lax.scan(jax.checkpoint(step), S0, (rc, kc, vc, wc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, n_chunks * L, H, hd)[:, :S]
    out = _rwkv_out(p, y.astype(x.dtype), g, cfg)
    if return_state:
        return out, S_fin, x[:, -1]
    return out


def init_rwkv_state(cfg, batch: int, dtype=jnp.float32):
    H, hd = cfg.n_heads, cfg.head_dim
    return {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "prev_t": jnp.zeros((batch, cfg.d_model), dtype),
        "prev_c": jnp.zeros((batch, cfg.d_model), dtype),
    }


def rwkv6_tmix_step(p, x, state, prev_x, cfg):
    """One-token decode. x: (B,1,d); state: (B,H,hd,hd)."""
    H, hd = cfg.n_heads, cfg.head_dim
    xx = _token_shift(x, prev=prev_x)
    r, k, v, g, logw = _rwkv_tmix_inputs(p, x, xx, cfg)
    rf, kf, vf = (a[:, 0].astype(jnp.float32) for a in (r, k, v))
    wf = jnp.exp(logw[:, 0])
    u = p["u"].astype(jnp.float32)
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    y = jnp.einsum("bhk,bhkv->bhv", rf, state + u[None, :, :, None] * kv)
    S_new = state * wf[..., None] + kv
    out = _rwkv_out(p, y[:, None].astype(x.dtype), g, cfg)
    return out, S_new, x[:, 0]


def rwkv6_cmix(p, x, prev=None):
    xx = _token_shift(x, prev=prev)
    mix = p["mix"]
    xk = x + (xx - x) * mix[0]
    xr = x + (xx - x) * mix[1]
    kk = jax.nn.relu(xk @ p["w_kc"])
    kk = kk * kk
    return jax.nn.sigmoid(xr @ p["w_rc"]) * (kk @ p["w_vc"]), x[:, -1]
