"""Decoder-only LM assembly for dense / MoE / VLM / SSM / hybrid families.

Layers are stacked along a leading axis and driven by ``lax.scan`` so the HLO
(and compile time) is independent of depth; non-uniform structure (first-k
dense MoE layers, cross-attn every Nth block, zamba's shared block) is handled
by scanning over uniform *groups*.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.attention import (attn_params, gqa_decode, gqa_decode_paged,
                                    gqa_decode_spec, gqa_decode_spec_paged,
                                    gqa_forward, gqa_params, gqa_prefill_paged,
                                    init_gqa_cache, init_gqa_pool,
                                    init_mla_cache, init_mla_pool, mla_decode,
                                    mla_decode_paged, mla_forward,
                                    mla_prefill_paged)
from repro.models.common import (apply_mlp, apply_norm, cross_entropy,
                                 dense_init, embed_tokens, mlp_params,
                                 norm_params)
from repro.models.moe import apply_moe, moe_params
from repro.models.sharding import shard

REMAT_POLICIES = {
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "full": jax.checkpoint_policies.nothing_saveable,
}


def _maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    return jax.checkpoint(fn, policy=REMAT_POLICIES[remat])


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def init_dense_layer(key, cfg, dtype, moe: bool = False, d_ff: Optional[int] = None):
    k1, k2 = jax.random.split(key)
    p = {"ln1": norm_params(cfg, dtype), "ln2": norm_params(cfg, dtype),
         "attn": attn_params(k1, cfg, dtype)}
    if moe:
        p["moe"] = moe_params(k2, cfg, dtype)
    else:
        p["mlp"] = mlp_params(k2, cfg, d_ff=d_ff, dtype=dtype)
    return p


def init_cross_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": norm_params(cfg, dtype), "ln2": norm_params(cfg, dtype),
        "attn": gqa_params(k1, cfg, dtype, cross=True),
        "mlp": mlp_params(k2, cfg, dtype=dtype),
        "gate_attn": jnp.zeros((), dtype),
        "gate_mlp": jnp.zeros((), dtype),
    }


def init_rwkv_layer(key, cfg, dtype):
    p = ssm.rwkv6_params(key, cfg, dtype)
    p["ln1"] = norm_params(cfg, dtype)
    p["ln2"] = norm_params(cfg, dtype)
    return p


def init_mamba_layer(key, cfg, dtype):
    return {"ln": norm_params(cfg, dtype), "mamba": ssm.mamba2_params(key, cfg, dtype)}


def init_shared_attn_block(key, cfg, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "w_in": dense_init(k1, 2 * d, d, dtype),
        "ln1": norm_params(cfg, dtype), "ln2": norm_params(cfg, dtype),
        "attn": gqa_params(k2, cfg, dtype),
        "mlp": mlp_params(k3, cfg, dtype=dtype),
        "w_out_proj": dense_init(k4, d, d, dtype),
    }


# ---------------------------------------------------------------------------
# block applications (train / prefill)
# ---------------------------------------------------------------------------


def dense_block(p, h, cfg, positions, impl, chunk, return_kv=False, moe_cf=1.25):
    """Standard pre-norm block. Returns (h, aux[, kv_cache_entry])."""
    x = apply_norm(p["ln1"], h, cfg.norm)
    kv = None
    if cfg.use_mla:
        if return_kv:
            a, kv = mla_forward(p["attn"], x, cfg, positions=positions, impl=impl,
                                chunk=chunk, return_cache=True)
        else:
            a = mla_forward(p["attn"], x, cfg, positions=positions, impl=impl, chunk=chunk)
    else:
        if return_kv:
            a, kv = gqa_forward(p["attn"], x, cfg, positions=positions, impl=impl,
                                chunk=chunk, return_kv=True)
        else:
            a = gqa_forward(p["attn"], x, cfg, positions=positions, impl=impl, chunk=chunk)
    h = shard(h + a, "batch", "seq", None)
    x = apply_norm(p["ln2"], h, cfg.norm)
    if "moe" in p:
        m, aux = apply_moe(p["moe"], x, cfg, capacity_factor=moe_cf)
    else:
        m, aux = apply_mlp(p["mlp"], x, cfg.activation), jnp.zeros((), jnp.float32)
    h = shard(h + m, "batch", "seq", None)
    return (h, aux, kv) if return_kv else (h, aux)


def cross_block(p, h, cfg, kv_x, return_kv=False):
    """Gated cross-attention block (llama-3.2-vision style)."""
    x = apply_norm(p["ln1"], h, cfg.norm)
    if return_kv:
        a, kv = gqa_forward(p["attn"], x, cfg, kv_x=kv_x, causal=False, return_kv=True)
    else:
        a = gqa_forward(p["attn"], x, cfg, kv_x=kv_x, causal=False)
    h = h + jnp.tanh(p["gate_attn"]) * a
    m = apply_mlp(p["mlp"], apply_norm(p["ln2"], h, cfg.norm), cfg.activation)
    h = h + jnp.tanh(p["gate_mlp"]) * m
    h = shard(h, "batch", "seq", None)
    return (h, kv) if return_kv else h


def rwkv_block(p, h, cfg):
    t = ssm.rwkv6_tmix(p["tmix"], apply_norm(p["ln1"], h, cfg.norm), cfg)
    h = h + t
    c, _ = ssm.rwkv6_cmix(p["cmix"], apply_norm(p["ln2"], h, cfg.norm))
    return shard(h + c, "batch", "seq", None)


def mamba_block(p, h, cfg):
    m = ssm.mamba2_forward(p["mamba"], apply_norm(p["ln"], h, cfg.norm), cfg)
    return shard(h + m, "batch", "seq", None)


def shared_attn_apply(p, h, emb0, cfg, impl, chunk, positions, return_kv=False):
    u = jnp.concatenate([h, emb0], axis=-1) @ p["w_in"]
    x = apply_norm(p["ln1"], u, cfg.norm)
    if return_kv:
        a, kv = gqa_forward(p["attn"], x, cfg, positions=positions, impl=impl,
                            chunk=chunk, return_kv=True)
    else:
        a = gqa_forward(p["attn"], x, cfg, positions=positions, impl=impl, chunk=chunk)
    u = u + a
    u = u + apply_mlp(p["mlp"], apply_norm(p["ln2"], u, cfg.norm), cfg.activation)
    out = h + u @ p["w_out_proj"]
    return (out, kv) if return_kv else out


# ---------------------------------------------------------------------------
# model init
# ---------------------------------------------------------------------------


def init_decoder(key, cfg, dtype=jnp.float32):
    keys = jax.random.split(key, 8)
    params = {"embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dtype),
              "ln_f": norm_params(cfg, dtype)}
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)

    if cfg.family == "ssm":
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: init_rwkv_layer(k, cfg, dtype))(lkeys)
    elif cfg.family == "hybrid":
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: init_mamba_layer(k, cfg, dtype))(lkeys)
        params["shared"] = init_shared_attn_block(keys[3], cfg, dtype)
    elif cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: init_dense_layer(k, cfg, dtype))(lkeys)
        ckeys = jax.random.split(keys[3], n_cross)
        params["cross_layers"] = jax.vmap(lambda k: init_cross_layer(k, cfg, dtype))(ckeys)
    elif cfg.is_moe:
        kd = cfg.first_k_dense
        if kd:
            dkeys = jax.random.split(keys[2], kd)
            params["dense_layers"] = jax.vmap(
                lambda k: init_dense_layer(k, cfg, dtype, d_ff=cfg.dense_d_ff or cfg.d_ff))(dkeys)
        mkeys = jax.random.split(keys[3], cfg.n_layers - kd)
        params["layers"] = jax.vmap(lambda k: init_dense_layer(k, cfg, dtype, moe=True))(mkeys)
        if cfg.n_mtp_modules:
            k1, k2 = jax.random.split(keys[4])
            params["mtp"] = {
                "proj": dense_init(k1, 2 * cfg.d_model, cfg.d_model, dtype),
                "block": init_dense_layer(k2, cfg, dtype, moe=False, d_ff=cfg.dense_d_ff or cfg.d_ff),
                "ln": norm_params(cfg, dtype),
            }
    else:  # dense
        lkeys = jax.random.split(keys[2], cfg.n_layers)
        params["layers"] = jax.vmap(lambda k: init_dense_layer(k, cfg, dtype))(lkeys)
    return params


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------


def _logits(params, cfg, h):
    h = apply_norm(params["ln_f"], h, cfg.norm)
    if cfg.tie_embeddings:
        # Reshard the (d-sharded) lookup table to vocab-sharded before the
        # head matmul: contraction over a tp-sharded d would otherwise make
        # XLA build full-vocab partial logits + a logits-sized all-reduce.
        w = shard(params["embed"], "tp", None).T
    else:
        w = params["lm_head"]
    logits = h @ w.astype(h.dtype)
    return shard(logits, "batch", "seq", "tp")


def forward_decoder(params, cfg, tokens, *, image_embed=None, audio_embed=None,
                    impl="chunked", chunk=1024, remat="none", return_cache=False,
                    moe_cf=1.25):
    """Returns (logits, aux) or (logits, aux, cache) when return_cache."""
    B, S = tokens.shape
    h = embed_tokens(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    aux = jnp.zeros((), jnp.float32)
    caches = None

    if cfg.family == "ssm":
        assert not return_cache, "use prefill_decoder for SSM caches"
        block = _maybe_remat(functools.partial(rwkv_block, cfg=cfg), remat)

        def body(carry, lp):
            return block(lp, carry), None

        h, _ = jax.lax.scan(body, h, params["layers"])
    elif cfg.family == "hybrid":
        assert not return_cache, "use prefill_decoder for hybrid caches"
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["layers"])
        emb0 = h
        mblock = _maybe_remat(functools.partial(mamba_block, cfg=cfg), remat)

        def group(carry, glp):
            hh = shared_attn_apply(params["shared"], carry, emb0, cfg, impl,
                                   chunk, positions)

            def inner(c, lp):
                return mblock(lp, c), None

            hh, _ = jax.lax.scan(inner, hh, glp)
            return hh, None

        h, _ = jax.lax.scan(group, h, stacked)
    elif cfg.family == "vlm":
        every = cfg.cross_attn_every
        n_cross = cfg.n_layers // every
        self_stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((n_cross, every) + a.shape[1:]), params["layers"])
        block = _maybe_remat(
            functools.partial(dense_block, cfg=cfg, positions=positions, impl=impl,
                              chunk=chunk, return_kv=return_cache), remat)

        def group(carry, xs):
            hh, aux_c = carry
            slp, clp = xs

            def inner(c, lp):
                h2, a2 = c
                if return_cache:
                    h3, a3, kv = block(lp, h2)
                    return (h3, a2 + a3), kv
                h3, a3 = block(lp, h2)
                return (h3, a2 + a3), None

            (hh, aux_c), self_kv = jax.lax.scan(inner, (hh, aux_c), slp)
            if return_cache:
                hh, ckv = cross_block(clp, hh, cfg, image_embed, return_kv=True)
                return (hh, aux_c), (self_kv, ckv)
            hh = cross_block(clp, hh, cfg, image_embed)
            return (hh, aux_c), None

        (h, aux), kvs = jax.lax.scan(group, (h, aux), (self_stacked, params["cross_layers"]))
        if return_cache:
            self_kv, cross_kv = kvs
            caches = {"self": jax.tree_util.tree_map(
                lambda a: a.reshape((-1,) + a.shape[2:]), self_kv),
                "cross": cross_kv}
    else:  # dense / moe
        block = _maybe_remat(
            functools.partial(dense_block, cfg=cfg, positions=positions, impl=impl,
                              chunk=chunk, return_kv=return_cache, moe_cf=moe_cf), remat)

        def body(carry, lp):
            hh, aux_c = carry
            if return_cache:
                h2, a2, kv = block(lp, hh)
                return (h2, aux_c + a2), kv
            h2, a2 = block(lp, hh)
            return (h2, aux_c + a2), None

        kv_parts = []
        if cfg.is_moe and cfg.first_k_dense:
            (h, aux), kv0 = jax.lax.scan(body, (h, aux), params["dense_layers"])
            kv_parts.append(kv0)
        (h, aux), kv1 = jax.lax.scan(body, (h, aux), params["layers"])
        kv_parts.append(kv1)
        if return_cache:
            if len(kv_parts) > 1:
                caches = jax.tree_util.tree_map(
                    lambda a, b: jnp.concatenate([a, b], 0), kv_parts[0], kv_parts[1])
            else:
                caches = kv_parts[0]

    if return_cache:
        # prefill semantics: only the last position's logits are needed
        logits = _logits(params, cfg, h[:, -1:])
        return logits, aux, (h, caches)
    logits = _logits(params, cfg, h)
    return logits, aux, h


def _last_logits(params, cfg, h, last_pos=None):
    """Logits of the last *valid* prompt position. ``last_pos=None`` means the
    final position; an index (host int or traced scalar) selects earlier —
    the bucketed-prefill case, where the prompt is right-padded to a bucket
    length and causality keeps every position < true length unaffected."""
    if last_pos is None:
        return _logits(params, cfg, h[:, -1:])
    return _logits(params, cfg, jax.lax.dynamic_slice_in_dim(
        h, jnp.asarray(last_pos, jnp.int32), 1, 1))


def prefill_decoder(params, cfg, tokens, *, image_embed=None, audio_embed=None,
                    impl="chunked", chunk=1024, moe_cf=1.25, last_pos=None):
    """Single-pass prefill: returns (logits, cache) with per-layer caches/states.

    ``last_pos`` supports bucketed admission: prompts padded up to a bucket
    length still report the logits of their true last token.
    """
    if cfg.family not in ("ssm", "hybrid"):
        logits, aux, (h, caches) = forward_decoder(
            params, cfg, tokens, image_embed=image_embed, audio_embed=audio_embed,
            impl=impl, chunk=chunk, return_cache=True, moe_cf=moe_cf)
        if last_pos is not None:
            logits = _last_logits(params, cfg, h, last_pos)
        return logits, caches

    if last_pos is not None:
        # recurrent families carry the padded positions *through their
        # state* — a right-padded prompt corrupts it, so there is no valid
        # last_pos semantics to offer; fail loudly over a silent wrong token
        raise ValueError(f"last_pos (bucketed prefill) is not supported for "
                         f"the {cfg.family!r} family: recurrent state would "
                         f"absorb the padding")

    B, S = tokens.shape
    h = embed_tokens(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    if cfg.family == "ssm":
        states = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params["layers"])
            t, S_fin, last_t = ssm.rwkv6_tmix(lp["tmix"], apply_norm(lp["ln1"], h, cfg.norm),
                                              cfg, return_state=True)
            h = h + t
            c, last_c = ssm.rwkv6_cmix(lp["cmix"], apply_norm(lp["ln2"], h, cfg.norm))
            h = h + c
            states.append({"S": S_fin, "prev_t": last_t, "prev_c": last_c})
        cache = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *states)
        return _logits(params, cfg, h[:, -1:]), cache

    # hybrid (zamba2)
    emb0 = h
    mstates, skvs = [], []
    for i in range(cfg.n_layers):
        if cfg.shared_attn_every and i % cfg.shared_attn_every == 0:
            h, kv = shared_attn_apply(params["shared"], h, emb0, cfg, impl, chunk,
                                      positions, return_kv=True)
            skvs.append(kv)
        lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params["layers"])
        m, st = ssm.mamba2_forward(lp["mamba"], apply_norm(lp["ln"], h, cfg.norm),
                                   cfg, return_state=True)
        h = h + m
        mstates.append(st)
    cache = {
        "mamba": jax.tree_util.tree_map(lambda *a: jnp.stack(a), *mstates),
        "shared_kv": jax.tree_util.tree_map(lambda *a: jnp.stack(a), *skvs),
    }
    return _logits(params, cfg, h[:, -1:]), cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------


def loss_decoder(params, cfg, batch, *, impl="chunked", chunk=1024, remat="none",
                 moe_cf=1.25):
    tokens = batch["tokens"]
    logits, aux, h = forward_decoder(
        params, cfg, tokens, image_embed=batch.get("image_embed"),
        audio_embed=batch.get("audio_embed"), impl=impl, chunk=chunk, remat=remat,
        moe_cf=moe_cf)
    loss = cross_entropy(logits[:, :-1], tokens[:, 1:]) + aux
    if cfg.n_mtp_modules and "mtp" in params:
        # MTP (deepseek-v3): predict token t+2 from (h_t, emb(t+1))
        mtp = params["mtp"]
        emb_next = embed_tokens(params["embed"], tokens[:, 1:-1])
        u = jnp.concatenate([h[:, :-2], emb_next], axis=-1) @ mtp["proj"]
        B, S2 = tokens.shape[0], tokens.shape[1] - 2
        pos = jnp.broadcast_to(jnp.arange(S2, dtype=jnp.int32)[None], (B, S2))
        u, _ = dense_block(mtp["block"], u, cfg, pos, impl, chunk)
        mtp_logits = _logits(params, cfg, apply_norm(mtp["ln"], u, cfg.norm))
        loss = loss + 0.3 * cross_entropy(mtp_logits, tokens[:, 2:])
    return loss


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------


def init_cache_decoder(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.family == "ssm":
        st = ssm.init_rwkv_state(cfg, batch, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), st)
    if cfg.family == "hybrid":
        mst = ssm.init_mamba_state(cfg, batch, dtype)
        n_groups = cfg.n_layers // cfg.shared_attn_every
        kvshape = (n_groups, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        return {
            "mamba": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), mst),
            "shared_kv": {"k": jnp.zeros(kvshape, dtype), "v": jnp.zeros(kvshape, dtype)},
        }
    if cfg.family == "vlm":
        n_cross = cfg.n_layers // cfg.cross_attn_every
        cshape = (n_cross, batch, cfg.n_image_tokens, cfg.n_kv_heads, cfg.head_dim)
        self_c = init_gqa_cache(cfg, batch, max_len, dtype)
        return {
            "self": jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), self_c),
            "cross": {"k": jnp.zeros(cshape, dtype), "v": jnp.zeros(cshape, dtype)},
        }
    percfg = init_mla_cache(cfg, batch, max_len, dtype) if cfg.use_mla else \
        init_gqa_cache(cfg, batch, max_len, dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), percfg)


def init_paged_cache_decoder(cfg, num_blocks: int, block_size: int,
                             dtype=jnp.bfloat16):
    """Paged KV layout for dense/moe: per-layer (num_blocks, block_size, ...)
    pools with a leading layer axis. One block-table row addresses the same
    physical block index in every layer's pool, so the table is shared
    across the stack."""
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"paged KV cache needs a slotted-KV family, "
                         f"got {cfg.family!r}")
    per = init_mla_pool(cfg, num_blocks, block_size, dtype) if cfg.use_mla \
        else init_gqa_pool(cfg, num_blocks, block_size, dtype)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape).copy(), per)


def paged_prefill_step_decoder(params, cfg, cache, tokens, start, block_table,
                               *, last_pos=None, write: bool = True,
                               moe_cf=1.25):
    """One block-sized chunk of paged prefill for dense/moe stacks.

    tokens: (B, block_size) int32 — one chunk of the (right-padded) prompt;
    ``start`` (traced scalar) is its first virtual position, always a block
    multiple so the chunk occupies exactly one block-table column. KV is
    written straight into the (L, num_blocks, block_size, ...) pools through
    each layer's scatter — there is no contiguous (1, P, ...) prefill cache
    to splice afterwards. ``write=False`` recomputes activations against
    already-populated (prefix-hit) blocks without touching the pools.

    Returns (logits, cache): logits of position ``last_pos`` within the
    chunk (``None`` = final position), as :func:`_last_logits`.

    MoE note: routing capacity depends on the tokens routed together, so a
    chunk routes independently of the full-prompt pass; with a saturating
    capacity factor (no drops) the two are token-identical, otherwise
    chunked prefill may drop differently than contiguous prefill would.
    """
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"paged prefill needs a dense/moe KV cache, "
                         f"got {cfg.family!r}")
    h = embed_tokens(params["embed"], tokens)

    def make_body(moe_layer):
        def body(carry, xs):
            hh = carry
            lp, lcache = xs
            x = apply_norm(lp["ln1"], hh, cfg.norm)
            if cfg.use_mla:
                a, lnew = mla_prefill_paged(lp["attn"], x, lcache, start,
                                            block_table, cfg, write=write)
            else:
                a, lnew = gqa_prefill_paged(lp["attn"], x, lcache, start,
                                            block_table, cfg, write=write)
            hh = hh + a
            x = apply_norm(lp["ln2"], hh, cfg.norm)
            if moe_layer:
                m, _ = apply_moe(lp["moe"], x, cfg, capacity_factor=moe_cf)
            else:
                m = apply_mlp(lp["mlp"], x, cfg.activation)
            return hh + m, lnew

        return body

    if cfg.is_moe and cfg.first_k_dense:
        kd = cfg.first_k_dense
        cache_dense = jax.tree_util.tree_map(lambda a: a[:kd], cache)
        cache_moe = jax.tree_util.tree_map(lambda a: a[kd:], cache)
        h, new_dense = jax.lax.scan(make_body(False), h,
                                    (params["dense_layers"], cache_dense))
        h, new_moe = jax.lax.scan(make_body(True), h, (params["layers"], cache_moe))
        new_cache = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], 0), new_dense, new_moe)
    else:
        h, new_cache = jax.lax.scan(make_body(cfg.is_moe), h,
                                    (params["layers"], cache))

    return _last_logits(params, cfg, h, last_pos), new_cache


def decode_step_decoder(params, cfg, cache, tokens, cache_len, *, impl="chunked",
                        moe_cf=1.25, block_table=None):
    """One-token decode. tokens: (B,1) int32; cache_len: scalar or (B,) int32.

    ``impl="pallas"`` selects the fused single-query flash-decode kernel for
    every KV-cache attention in the stack; any other impl uses the naive
    decode oracle (the prefill/train impls chunked/pallas only apply to full
    sequence attention, so decode maps them onto {naive, pallas}).

    ``block_table`` (B, T) int32 switches the dense/moe KV path to the paged
    layout: ``cache`` leaves are (L, num_blocks, block_size, ...) pools and
    every layer resolves the same table row to its own pool.
    """
    B = tokens.shape[0]
    dimpl = "pallas" if impl == "pallas" else "naive"
    if block_table is not None and cfg.family not in ("dense", "moe"):
        raise ValueError(f"paged decode needs a dense/moe KV cache, "
                         f"got {cfg.family!r}")
    h = embed_tokens(params["embed"], tokens)

    if cfg.family == "ssm":
        def body(carry, xs):
            hh = carry
            lp, st = xs
            t, S_new, prev_t = ssm.rwkv6_tmix_step(
                lp["tmix"], apply_norm(lp["ln1"], hh, cfg.norm), st["S"], st["prev_t"], cfg)
            hh = hh + t
            c, prev_c = ssm.rwkv6_cmix(lp["cmix"], apply_norm(lp["ln2"], hh, cfg.norm),
                                       prev=st["prev_c"])
            hh = hh + c
            return hh, {"S": S_new, "prev_t": prev_t, "prev_c": prev_c}

        h, new_cache = jax.lax.scan(body, h, (params["layers"], cache))
    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]), params["layers"])
        mstacked = jax.tree_util.tree_map(
            lambda a: a.reshape((n_groups, every) + a.shape[1:]), cache["mamba"])
        emb0 = h

        def group(carry, xs):
            hh = carry
            glp, mst, skv = xs
            u = jnp.concatenate([hh, emb0], axis=-1) @ params["shared"]["w_in"]
            x = apply_norm(params["shared"]["ln1"], u, cfg.norm)
            a, skv_new = gqa_decode(params["shared"]["attn"], x, skv, cache_len, cfg,
                                    impl=dimpl)
            u = u + a
            u = u + apply_mlp(params["shared"]["mlp"],
                              apply_norm(params["shared"]["ln2"], u, cfg.norm), cfg.activation)
            hh = hh + u @ params["shared"]["w_out_proj"]

            def inner(c, xs2):
                lp, st = xs2
                m, st_new = ssm.mamba2_decode(lp["mamba"], apply_norm(lp["ln"], c, cfg.norm),
                                              st, cfg)
                return c + m, st_new

            hh, mst_new = jax.lax.scan(inner, hh, (glp, mst))
            return hh, (mst_new, skv_new)

        h, (mnew, snew) = jax.lax.scan(group, h, (stacked, mstacked, cache["shared_kv"]))
        new_cache = {
            "mamba": jax.tree_util.tree_map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), mnew),
            "shared_kv": snew,
        }
    elif cfg.family == "vlm":
        every = cfg.cross_attn_every
        n_cross = cfg.n_layers // every
        self_stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((n_cross, every) + a.shape[1:]), params["layers"])
        cache_stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((n_cross, every) + a.shape[1:]), cache["self"])

        def group(carry, xs):
            hh = carry
            slp, scache, clp, ckv = xs

            def inner(c, xs2):
                lp, lcache = xs2
                x = apply_norm(lp["ln1"], c, cfg.norm)
                a, lnew = gqa_decode(lp["attn"], x, lcache, cache_len, cfg, impl=dimpl)
                c = c + a
                c = c + apply_mlp(lp["mlp"], apply_norm(lp["ln2"], c, cfg.norm), cfg.activation)
                return c, lnew

            hh, snew = jax.lax.scan(inner, hh, (slp, scache))
            x = apply_norm(clp["ln1"], hh, cfg.norm)
            a, _ = gqa_decode(clp["attn"], x, None, cache_len, cfg,
                              cross_kv=(ckv["k"], ckv["v"]), impl=dimpl)
            hh = hh + jnp.tanh(clp["gate_attn"]) * a
            m = apply_mlp(clp["mlp"], apply_norm(clp["ln2"], hh, cfg.norm), cfg.activation)
            hh = hh + jnp.tanh(clp["gate_mlp"]) * m
            return hh, snew

        h, self_new = jax.lax.scan(
            group, h, (self_stacked, cache_stacked, params["cross_layers"], cache["cross"]))
        new_cache = {
            "self": jax.tree_util.tree_map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), self_new),
            "cross": cache["cross"],
        }
    else:  # dense / moe
        def make_body(moe_layer):
            def body(carry, xs):
                hh = carry
                lp, lcache = xs
                x = apply_norm(lp["ln1"], hh, cfg.norm)
                if cfg.use_mla:
                    if block_table is not None:
                        a, lnew = mla_decode_paged(lp["attn"], x, lcache,
                                                   cache_len, block_table, cfg,
                                                   impl=dimpl)
                    else:
                        a, lnew = mla_decode(lp["attn"], x, lcache, cache_len,
                                             cfg, impl=dimpl)
                elif block_table is not None:
                    a, lnew = gqa_decode_paged(lp["attn"], x, lcache, cache_len,
                                               block_table, cfg, impl=dimpl)
                else:
                    a, lnew = gqa_decode(lp["attn"], x, lcache, cache_len, cfg,
                                         impl=dimpl)
                hh = hh + a
                x = apply_norm(lp["ln2"], hh, cfg.norm)
                if moe_layer:
                    m, _ = apply_moe(lp["moe"], x, cfg, capacity_factor=moe_cf)
                else:
                    m = apply_mlp(lp["mlp"], x, cfg.activation)
                return hh + m, lnew

            return body

        if cfg.is_moe and cfg.first_k_dense:
            kd = cfg.first_k_dense
            cache_dense = jax.tree_util.tree_map(lambda a: a[:kd], cache)
            cache_moe = jax.tree_util.tree_map(lambda a: a[kd:], cache)
            h, new_dense = jax.lax.scan(make_body(False), h,
                                        (params["dense_layers"], cache_dense))
            h, new_moe = jax.lax.scan(make_body(True), h, (params["layers"], cache_moe))
            new_cache = jax.tree_util.tree_map(
                lambda a, b: jnp.concatenate([a, b], 0), new_dense, new_moe)
        else:
            h, new_cache = jax.lax.scan(make_body(cfg.is_moe), h,
                                        (params["layers"], cache))

    logits = _logits(params, cfg, h)
    return logits, new_cache


def spec_decode_step_decoder(params, cfg, cache, tokens, cache_len, *,
                             impl="chunked", moe_cf=1.25, block_table=None):
    """Speculative verify step for dense/moe stacks.

    tokens: (B, S) int32 — the last accepted token followed by S-1 draft
    tokens; window position qi occupies cache slot cache_len + qi. One pass
    scores every draft: the returned logits are (B, S, V), where row qi is
    the target model's next-token distribution *given* the window prefix
    through position qi — row 0 scores the first draft token, row S-1 is
    the bonus distribution past the last draft. The KV cache comes back
    with all S positions written; the caller's accept/rollback is pure
    cache_len bookkeeping (rejected tail KVs are masked dead by later
    calls' lengths and overwritten in place by the next window).

    Recurrent families (ssm/hybrid) fold positions into their state, so a
    rejected draft cannot be rolled back by bookkeeping — refuse loudly.
    VLM/MLA can grow spec windows later; dense/moe GQA is the serving path.
    """
    dimpl = "pallas" if impl == "pallas" else "naive"
    if cfg.family not in ("dense", "moe"):
        raise ValueError(f"speculative decode needs a slotted-KV family, "
                         f"got {cfg.family!r}")
    if cfg.use_mla:
        raise ValueError("speculative decode is not implemented for MLA "
                         "attention (absorbed-q verify window pending)")
    h = embed_tokens(params["embed"], tokens)

    def make_body(moe_layer):
        def body(carry, xs):
            hh = carry
            lp, lcache = xs
            x = apply_norm(lp["ln1"], hh, cfg.norm)
            if block_table is not None:
                a, lnew = gqa_decode_spec_paged(lp["attn"], x, lcache,
                                                cache_len, block_table, cfg,
                                                impl=dimpl)
            else:
                a, lnew = gqa_decode_spec(lp["attn"], x, lcache, cache_len,
                                          cfg, impl=dimpl)
            hh = hh + a
            x = apply_norm(lp["ln2"], hh, cfg.norm)
            if moe_layer:
                m, _ = apply_moe(lp["moe"], x, cfg, capacity_factor=moe_cf)
            else:
                m = apply_mlp(lp["mlp"], x, cfg.activation)
            return hh + m, lnew

        return body

    if cfg.is_moe and cfg.first_k_dense:
        kd = cfg.first_k_dense
        cache_dense = jax.tree_util.tree_map(lambda a: a[:kd], cache)
        cache_moe = jax.tree_util.tree_map(lambda a: a[kd:], cache)
        h, new_dense = jax.lax.scan(make_body(False), h,
                                    (params["dense_layers"], cache_dense))
        h, new_moe = jax.lax.scan(make_body(True), h, (params["layers"], cache_moe))
        new_cache = jax.tree_util.tree_map(
            lambda a, b: jnp.concatenate([a, b], 0), new_dense, new_moe)
    else:
        h, new_cache = jax.lax.scan(make_body(cfg.is_moe), h,
                                    (params["layers"], cache))

    logits = _logits(params, cfg, h)
    return logits, new_cache
