"""Logical-axis sharding rules (MaxText-style).

Model code annotates activations with *logical* axis names; an execution
choice (core/choices.py) installs a rule set mapping logical names to mesh
axes. This is the mechanism through which Swan's execution choices rebind the
distribution strategy without touching model code.

Logical axes:
  batch   - data-parallel batch dim
  seq     - sequence (SP) dim
  fsdp    - weight dim sharded for FSDP (usually d_model / vocab rows)
  tp      - tensor-parallel dim (heads, ffn hidden, vocab cols)
  ep      - expert-parallel dim (MoE expert axis)
  kvseq   - KV-cache sequence dim (context parallel decode)
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh

AxisBinding = Union[None, str, Tuple[str, ...]]

# Default rule set: single-pod (data, model) mesh, FSDP+TP.
DEFAULT_RULES: dict[str, AxisBinding] = {
    "batch": ("data",),
    "seq": None,
    "fsdp": "data",
    "tp": "model",
    "ep": "model",
    "kvseq": "model",
}

_state = threading.local()


def get_rules() -> dict[str, AxisBinding]:
    return getattr(_state, "rules", DEFAULT_RULES)


@contextlib.contextmanager
def axis_rules(rules: dict[str, AxisBinding]):
    """Install a logical->mesh axis rule set for the enclosed scope."""
    prev = getattr(_state, "rules", None)
    _state.rules = dict(rules)
    try:
        yield
    finally:
        if prev is None:
            del _state.rules
        else:
            _state.rules = prev


def resolve(*logical: Optional[str]) -> P:
    """Resolve logical axis names to a PartitionSpec under current rules."""
    rules = get_rules()
    out, used = [], set()
    for name in logical:
        if name is None:
            out.append(None)
            continue
        binding = rules.get(name)
        if binding is None:
            out.append(None)
            continue
        axes = (binding,) if isinstance(binding, str) else tuple(binding)
        fresh = tuple(a for a in axes if a not in used)
        used.update(fresh)
        if not fresh:
            out.append(None)
        elif len(fresh) == 1:
            out.append(fresh[0])
        else:
            out.append(fresh)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the current logical rules.

    No-op outside a mesh context so model code runs unmodified on a bare CPU.
    """
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    spec = resolve(*logical)
    # Drop bindings to axes the active mesh doesn't have.
    names = set(mesh.axis_names)

    def keep(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a in names)
            return kept if kept else None
        return e if e in names else None

    spec = P(*(keep(e) for e in spec))
    # Never shard a dim that isn't divisible by its mesh extent.
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    fixed = []
    for dim, e in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if e is None:
            fixed.append(None)
            continue
        extent = 1
        for a in (e,) if isinstance(e, str) else e:
            extent *= sizes[a]
        fixed.append(e if dim % extent == 0 and dim >= extent else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


# ---------------------------------------------------------------------------
# Parameter partition-spec inference (name-based, t5x-style).
# Order matters: first match wins. Specs are in LOGICAL names; leading layer-
# stacking dims are padded with None.
# ---------------------------------------------------------------------------
_PARAM_RULES: Sequence[Tuple[str, Tuple[Optional[str], ...]]] = (
    (r"experts/w_down$", ("ep", None, "fsdp")),
    (r"experts/(w_gate|w_up)$", ("ep", "fsdp", None)),
    # embed: vocab rows replicated, d sharded on tp — a gather over a
    # vocab-sharded table forces SPMD "involuntary full rematerialization"
    (r"(^|/)embed$", (None, "tp")),
    (r"pos_embed$", (None, None)),
    (r"(wq|wk|wv|wqkv)$", ("fsdp", "tp")),
    (r"(wq_b|wkv_b)$", (None, "tp")),
    (r"(wq_a|wkv_a)$", ("fsdp", None)),
    (r"wo$", ("tp", "fsdp")),
    (r"(w_gate|w_up|w_in|w_r|w_k|w_v|w_g|w_kc|w_rc|router|w_cross_kv)$", ("fsdp", "tp")),
    (r"(w_down|w_out|w_o|w_vc)$", ("tp", "fsdp")),
    (r"w_decay_a$", ("fsdp", None)),
    (r"w_decay_b$", (None, "tp")),
    (r"lm_head$", (None, "tp")),
    (r"(conv|kernel)$", (None, None, None, "tp")),
    (r".*", ()),  # scales, biases, gates, A_log, D, dt_bias -> replicated
)


def _spec_for(path: str, ndim: int) -> P:
    for pat, logical in _PARAM_RULES:
        if re.search(pat, path):
            spec = list(logical)
            break
    spec = spec[:ndim]
    spec = [None] * (ndim - len(spec)) + spec
    return resolve(*spec)


def param_specs(params) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec pytree matching ``params`` under the current rules."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    paths = ["/".join(str(getattr(k, "key", k)) for k in kp) for kp, _ in flat]
    leaves = [_spec_for(p, v.ndim) for p, (_, v) in zip(paths, flat)]
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def mesh_safe_specs(params, mesh) -> "jax.tree_util.PyTreeDef":
    """param_specs with axes dropped where sizes don't divide."""
    specs = param_specs(params)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes if hasattr(mesh, "axis_sizes") else mesh.devices.shape))
    names = set(mesh.axis_names)

    def fix(v, spec):
        entries = tuple(spec) + (None,) * (v.ndim - len(spec))
        fixed = []
        for dim, e in zip(v.shape, entries):
            if e is None:
                fixed.append(None)
                continue
            axes = (e,) if isinstance(e, str) else tuple(a for a in e)
            axes = tuple(a for a in axes if a in names)
            extent = 1
            for a in axes:
                extent *= sizes[a]
            if not axes or extent == 1 or dim % extent != 0:
                fixed.append(None)
            elif len(axes) == 1:
                fixed.append(axes[0])
            else:
                fixed.append(axes)
        while fixed and fixed[-1] is None:
            fixed.pop()
        return P(*fixed)

    return jax.tree_util.tree_map(fix, params, specs)
