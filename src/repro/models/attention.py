"""Attention: GQA / MHA / cross-attention / MLA, with KV-cache decode.

Three implementations of the core softmax-attention compute:
  naive   - materialize (Sq, Sk) scores; smoke tests + oracle
  chunked - flash-style online softmax over KV chunks in pure jnp; the
            dry-run/default path (never materializes Sq x Sk)
  pallas  - kernels/flash_attention.py fused fwd + custom_vjp flash backward
            (training-grade; TPU Mosaic target, interpret-mode on CPU).
            Selected per execution choice via MeshChoice.attn_impl.

Decode shards the KV cache sequence dim over the ``kvseq`` logical axis
(context-parallel decode): softmax over a sharded axis lowers to tiny
all-reduces of the per-shard max/denominator.
"""
from __future__ import annotations

import math
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_norm, apply_rope, dense_init
from repro.models.sharding import shard  # noqa: F401  (used throughout)

# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def gqa_params(key, cfg, dtype=jnp.float32, cross: bool = False):
    hd = cfg.head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dtype,
                         scale=1.0 / math.sqrt(cfg.n_heads * hd)),
    }


def mla_params(key, cfg, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], cfg.d_model, cfg.q_lora_rank, dtype),
        "q_norm": {"scale": jnp.ones((cfg.q_lora_rank,), dtype)},
        "wq_b": dense_init(ks[1], cfg.q_lora_rank, cfg.n_heads * qk_dim, dtype),
        "wkv_a": dense_init(ks[2], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype),
        "kv_norm": {"scale": jnp.ones((cfg.kv_lora_rank,), dtype)},
        "wkv_b": dense_init(ks[3], cfg.kv_lora_rank,
                            cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim), dtype),
        "wo": dense_init(ks[4], cfg.n_heads * cfg.v_head_dim, cfg.d_model, dtype),
    }


def attn_params(key, cfg, dtype=jnp.float32, cross: bool = False):
    if cfg.use_mla and not cross:
        return mla_params(key, cfg, dtype)
    return gqa_params(key, cfg, dtype, cross=cross)


# ---------------------------------------------------------------------------
# core attention computations
# ---------------------------------------------------------------------------


def _group(q, n_kv):
    """(B,S,H,hd) -> (B,S,K,G,hd) grouped query heads."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, n_kv, H // n_kv, hd)


def naive_attention(q, k, v, *, causal: bool, q_offset=0, kv_len: Optional[jax.Array] = None):
    """Oracle attention. q:(B,Sq,H,hd) k,v:(B,Sk,K,hd).

    ``kv_len`` may be a scalar or a per-sequence (B,) vector (ragged decode
    under continuous batching); rows must keep kv_len >= 1 to stay
    well-defined — a fully-masked row softmaxes to uniform, not zero.
    ``q_offset`` may likewise be a (B,) vector: query row qi of sequence b
    then attends positions <= q_offset[b] + qi (the speculative-verify
    oracle, where each sequence's draft window starts at its own cache_len).
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    qg = _group(q, K)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    Sk = k.shape[1]
    kv_idx = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        if jnp.ndim(q_offset) > 0:  # per-sequence window starts
            q_idx = jnp.arange(Sq)[None] + q_offset[:, None]     # (B, Sq)
            mask = kv_idx[None, None, :] <= q_idx[:, :, None]    # (B, Sq, Sk)
        else:
            q_idx = jnp.arange(Sq) + q_offset
            mask = kv_idx[None, :] <= q_idx[:, None]
    if kv_len is not None and jnp.ndim(kv_len) > 0:  # per-sequence lengths
        lenm = kv_idx[None, None, :] < kv_len[:, None, None]
        mask = (mask[None] if mask.ndim == 2 else mask) & lenm
    elif kv_len is not None:
        mask = mask & (kv_idx[None, :] < kv_len)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, q_offset=0, chunk: int = 1024):
    """Flash-style online-softmax attention, scanning KV chunks.

    Never materializes the (Sq, Sk) score matrix; per-step live memory is
    O(Sq * chunk). This is the HLO the dry-run sees for prefill/train.

    Layout: everything runs in full-H (B, H, ...) form — GQA KV heads are
    broadcast to H *inside* each chunk — because the grouped (B, K, G, ...)
    layout cannot shard K=8 kv-heads over a 16-way tensor axis and forces the
    SPMD partitioner to replicate the scan carries (observed: 40GB+ carries).
    With full-H, every tensor shards (batch, tp) cleanly, including the
    online-softmax carries, which we constrain explicitly.
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    vd = v.shape[-1]
    G = H // K
    Sk = k.shape[1]
    chunk = min(chunk, Sk)
    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, K, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, K, vd), 1, 0)

    qh = q.transpose(0, 2, 1, 3).astype(jnp.float32) / math.sqrt(hd)  # (B,H,Sq,hd)
    qh = shard(qh, "batch", "tp", None, None)
    q_idx = jnp.arange(Sq) + q_offset

    def expand(blk):  # (B,chunk,K,d) -> (B,H,chunk,d)
        e = jnp.broadcast_to(blk.transpose(0, 2, 1, 3)[:, :, None],
                             (B, K, G, chunk, blk.shape[-1]))
        return e.reshape(B, H, chunk, blk.shape[-1])

    # bf16 score/probability tensors (fp32 online-softmax statistics and
    # accumulator) — standard TPU practice; halves the dominant HBM traffic
    # of the jnp fallback path. Toggled by the execution choice (hillclimb).
    lowp = os.environ.get("REPRO_ATTN_BF16", "0") == "1"

    def step(carry, xs):
        m, l, acc = carry
        k_blk, v_blk, c = xs
        kdt = jnp.bfloat16 if lowp else jnp.float32
        kh = shard(expand(k_blk).astype(kdt), "batch", "tp", None, None)
        vh = shard(expand(v_blk).astype(kdt), "batch", "tp", None, None)
        s = jnp.einsum("bhqd,bhkd->bhqk", qh.astype(kdt), kh,
                       preferred_element_type=jnp.float32)
        kv_idx = c * chunk + jnp.arange(chunk)
        mask = kv_idx[None, :] < Sk
        if causal:
            mask = mask & (kv_idx[None, :] <= q_idx[:, None])
        s = jnp.where(mask[None, None], s, -1e30)
        m_blk = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, m_blk)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = shard(l * corr + p.sum(-1), "batch", "tp", None)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p.astype(kdt), vh,
            preferred_element_type=jnp.float32)
        acc_new = shard(acc_new, "batch", "tp", None, None)
        return (shard(m_new, "batch", "tp", None), l_new, acc_new), None

    m0 = shard(jnp.full((B, H, Sq), -jnp.inf, jnp.float32), "batch", "tp", None)
    l0 = shard(jnp.zeros((B, H, Sq), jnp.float32), "batch", "tp", None)
    a0 = shard(jnp.zeros((B, H, Sq, vd), jnp.float32), "batch", "tp", None, None)
    # checkpoint each chunk step: the backward recomputes s/p per chunk
    # instead of saving O(Sq*Sk) probability residuals (flash-style backward)
    (m, l, acc), _ = jax.lax.scan(jax.checkpoint(step), (m0, l0, a0),
                                  (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3)  # (B,Sq,H,vd)
    return out.astype(q.dtype)


def attention_impl(q, k, v, *, causal, q_offset=0, impl: str = "chunked", chunk: int = 1024):
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset)
    if impl == "chunked":
        return chunked_attention(q, k, v, causal=causal, q_offset=q_offset, chunk=chunk)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, q_offset=q_offset)
    raise ValueError(impl)


# ---------------------------------------------------------------------------
# GQA block forward (train / prefill / cross) and decode
# ---------------------------------------------------------------------------


def gqa_forward(p, x, cfg, *, positions=None, kv_x=None, causal=True,
                impl="chunked", chunk=1024, return_kv=False):
    """Self-attention (kv_x=None) or cross-attention (kv_x=encoder states)."""
    B, S, _ = x.shape
    hd = cfg.head_dim
    src = x if kv_x is None else kv_x
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = (src @ p["wk"]).reshape(B, src.shape[1], cfg.n_kv_heads, hd)
    v = (src @ p["wv"]).reshape(B, src.shape[1], cfg.n_kv_heads, hd)
    q = shard(q, "batch", "seq", "tp", None)
    k = shard(k, "batch", "seq", "tp", None)
    v = shard(v, "batch", "seq", "tp", None)
    if positions is not None and kv_x is None and cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = attention_impl(q, k, v, causal=causal and kv_x is None, impl=impl, chunk=chunk)
    out = shard(out, "batch", "seq", "tp", None)
    y = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]
    if return_kv:
        # cache layout: sequence dim sharded over kvseq (context-parallel
        # decode) so the prefill scan's ys accumulator shards too
        return y, {"k": shard(k, "batch", "kvseq", None, None),
                   "v": shard(v, "batch", "kvseq", None, None)}
    return y


def init_gqa_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    hd = cfg.head_dim
    shape = (batch, max_seq, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_gqa_pool(cfg, num_blocks: int, block_size: int, dtype=jnp.bfloat16):
    """Paged layout: KV blocks shared by all sequences, no batch dim."""
    shape = (num_blocks, block_size, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def init_mla_pool(cfg, num_blocks: int, block_size: int, dtype=jnp.bfloat16):
    return {
        "latent": jnp.zeros((num_blocks, block_size, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((num_blocks, block_size, cfg.qk_rope_head_dim), dtype),
    }


def _decode_positions(cache_len, B):
    """(B,1) rope positions from a scalar or per-sequence cache_len."""
    if jnp.ndim(cache_len) == 0:
        return jnp.full((B, 1), cache_len, jnp.int32)
    return cache_len.astype(jnp.int32)[:, None]


def _scatter_token(buf, new, cache_len):
    """Write ``new`` (B,1,...) into ``buf`` (B,Smax,...) at seq position
    ``cache_len`` — scalar (lockstep decode, one dynamic slice) or per-
    sequence (B,) (continuous batching, one-hot masked select)."""
    if jnp.ndim(cache_len) == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), cache_len, 1)
    onehot = jnp.arange(buf.shape[1])[None] == cache_len[:, None]  # (B,Smax)
    onehot = onehot.reshape(onehot.shape + (1,) * (buf.ndim - 2))
    return jnp.where(onehot, new.astype(buf.dtype), buf)


def _scatter_token_paged(pool, new, cache_len, block_table):
    """Write ``new`` (B,1,...) into a block pool (num_blocks, block_size, ...)
    at virtual position ``cache_len`` of each sequence, routed through its
    block-table row. Idle serving slots' rows point at the null block, so
    their masked-garbage writes never touch a live sequence's cache."""
    bs = pool.shape[1]
    B = new.shape[0]
    cl = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    blk = jnp.clip(cl // bs, 0, block_table.shape[1] - 1)
    phys = jnp.take_along_axis(jnp.asarray(block_table, jnp.int32),
                               blk[:, None], 1)[:, 0]
    phys = jnp.clip(phys, 0, pool.shape[0] - 1)
    return pool.at[phys, cl % bs].set(new[:, 0].astype(pool.dtype))


def _scatter_tokens(buf, new, cache_len):
    """Write ``new`` (B,S,...) into ``buf`` (B,Smax,...) at seq positions
    cache_len..cache_len+S-1 (the speculative draft window). Scalar
    cache_len is one dynamic slice; per-sequence (B,) routes each buffer
    position p to draft index p - cache_len[b] via a masked gather."""
    S = new.shape[1]
    if jnp.ndim(cache_len) == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            buf, new.astype(buf.dtype), cache_len, 1)
    rel = jnp.arange(buf.shape[1])[None] - cache_len[:, None]      # (B,Smax)
    idx = jnp.clip(rel, 0, S - 1).reshape(
        rel.shape + (1,) * (buf.ndim - 2))
    sel = jnp.take_along_axis(new.astype(buf.dtype), idx, axis=1)
    valid = ((rel >= 0) & (rel < S)).reshape(idx.shape)
    return jnp.where(valid, sel, buf)


def _scatter_tokens_paged(pool, new, cache_len, block_table):
    """Write ``new`` (B,S,...) into a block pool at virtual positions
    cache_len..cache_len+S-1 of each sequence. The window is at most a few
    tokens, so S single-position scatters (each one indexed pool update)
    beat building a multi-hot routing tensor over the whole pool."""
    cl = jnp.asarray(cache_len, jnp.int32)
    for i in range(new.shape[1]):
        pool = _scatter_token_paged(pool, new[:, i:i + 1], cl + i, block_table)
    return pool


def _scatter_chunk_paged(pool, new, start, block_table):
    """Write one block-aligned chunk ``new`` (B, block_size, ...) into a
    block pool at virtual positions [start, start + block_size), routed
    through each sequence's block-table row. ``start`` may be traced (the
    chunked-prefill loop reuses one compile for every chunk index); it must
    be a multiple of block_size — the chunk grid *is* the block grid, which
    is what lets prefix-cache hits skip whole chunks exactly."""
    bs = pool.shape[1]
    B = new.shape[0]
    blk_idx = jnp.clip(jnp.asarray(start, jnp.int32) // bs, 0,
                       block_table.shape[1] - 1)
    phys = jax.lax.dynamic_slice_in_dim(
        jnp.asarray(block_table, jnp.int32), blk_idx, 1, 1)[:, 0]
    phys = jnp.clip(phys, 0, pool.shape[0] - 1)
    return pool.at[phys].set(new.astype(pool.dtype))


def gqa_prefill_paged(p, x, cache, start, block_table, cfg, *,
                      write: bool = True):
    """One chunk of paged prefill: ingest block_size prompt positions
    starting at ``start`` straight into the KV pools, then attend causally
    over everything written so far (gathered through the block table).

    ``write=False`` is the full-prefix-hit path: every block is already
    populated (by the donor sequence that prefilled the identical prefix),
    so the chunk only *reads* the pools to recompute the last position's
    activations for logits — no pool mutation, shared blocks stay intact.

    Attention is the naive oracle: the flash kernel bakes ``q_offset`` into
    its index maps (static), which would force one compile per chunk index;
    a traced offset keeps the whole prefill at one compile. Prefill impl
    only affects ingestion — decode keeps its kernel selection.
    """
    from repro.paging import gather_paged_kv

    B, C, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, C, cfg.n_heads, hd)
    k_new = (x @ p["wk"]).reshape(B, C, cfg.n_kv_heads, hd)
    v_new = (x @ p["wv"]).reshape(B, C, cfg.n_kv_heads, hd)
    if cfg.pos_embedding == "rope":
        pos = jnp.asarray(start, jnp.int32) + jnp.arange(C, dtype=jnp.int32)
        pos = jnp.broadcast_to(pos[None], (B, C))
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    if write:
        ck = _scatter_chunk_paged(cache["k"], k_new, start, block_table)
        cv = _scatter_chunk_paged(cache["v"], v_new, start, block_table)
    else:
        ck, cv = cache["k"], cache["v"]
    out = naive_attention(q, gather_paged_kv(ck, block_table),
                          gather_paged_kv(cv, block_table),
                          causal=True, q_offset=start)
    y = out.reshape(B, C, cfg.n_heads * hd) @ p["wo"]
    return y, {"k": ck, "v": cv}


def mla_prefill_paged(p, x, cache, start, block_table, cfg, *,
                      write: bool = True):
    """One chunk of paged MLA prefill over latent pools.

    Ingests the chunk's normalized latent + rope key into the pools, then
    reconstructs per-head K/V from the gathered latents (the same
    ``latent @ wkv_b`` expansion :func:`mla_forward` uses, so chunked
    ingestion matches the contiguous prefill numerics) and attends causally
    with a traced ``q_offset``. ``write=False`` as in
    :func:`gqa_prefill_paged`: read-only recompute on a full prefix hit.
    """
    from repro.paging import gather_paged_kv

    B, C, _ = x.shape
    nope, v_dim = cfg.qk_nope_head_dim, cfg.v_head_dim
    pos = jnp.asarray(start, jnp.int32) + jnp.arange(C, dtype=jnp.int32)
    pos = jnp.broadcast_to(pos[None], (B, C))
    q_nope, q_rope, latent_new, k_rope_new = _mla_qkv(p, x, cfg, pos)
    if write:
        lat = _scatter_chunk_paged(cache["latent"], latent_new, start, block_table)
        kr = _scatter_chunk_paged(cache["k_rope"], k_rope_new, start, block_table)
    else:
        lat, kr = cache["latent"], cache["k_rope"]
    lat_g = gather_paged_kv(lat, block_table)  # (B, S, r)
    kr_g = gather_paged_kv(kr, block_table)    # (B, S, rope_d)
    S = lat_g.shape[1]
    kv = (lat_g.astype(jnp.float32) @ p["wkv_b"]).reshape(
        B, S, cfg.n_heads, nope + v_dim)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_g[:, :, None, :].astype(jnp.float32),
                                  (B, S, cfg.n_heads, kr_g.shape[-1]))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    out = naive_attention(q, k, v, causal=True, q_offset=start)
    y = out.reshape(B, C, cfg.n_heads * v_dim) @ p["wo"]
    return y, {"latent": lat, "k_rope": kr}


def gqa_decode(p, x, cache, cache_len, cfg, *, cross_kv=None, impl: str = "naive"):
    """One-token decode. x: (B,1,d); cache k/v: (B,Smax,K,hd).

    ``cache_len``: scalar (all sequences in lockstep) or (B,) int32 (ragged
    continuous batching). ``impl``: ``naive`` materializes the (H, Smax)
    score rows; ``pallas`` runs the fused single-query flash-decode kernel
    that streams only cache_len-valid KV tiles once per GQA group.
    """
    B = x.shape[0]
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    if cross_kv is not None:
        k, v = cross_kv
        if impl == "pallas":
            from repro.kernels import ops as kops
            out = kops.decode_attention(q, k, v, k.shape[1])
        else:
            out = naive_attention(q, k, v, causal=False)
        new_cache = cache
    else:
        k_new = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
        v_new = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
        if cfg.pos_embedding == "rope":
            pos = _decode_positions(cache_len, B)
            q = apply_rope(q, pos, cfg.rope_theta)
            k_new = apply_rope(k_new, pos, cfg.rope_theta)
        ck = _scatter_token(cache["k"], k_new, cache_len)
        cv = _scatter_token(cache["v"], v_new, cache_len)
        ck = shard(ck, "batch", "kvseq", None, None)
        cv = shard(cv, "batch", "kvseq", None, None)
        new_cache = {"k": ck, "v": cv}
        if impl == "pallas":
            from repro.kernels import ops as kops
            out = kops.decode_attention(q, ck, cv, cache_len + 1)
        else:
            out = naive_attention(q, ck, cv, causal=False, kv_len=cache_len + 1)
    y = out.reshape(B, 1, cfg.n_heads * hd) @ p["wo"]
    return y, new_cache


def gqa_decode_paged(p, x, cache, cache_len, block_table, cfg, *,
                     impl: str = "naive"):
    """One-token GQA decode over a paged KV cache.

    cache: {"k","v"} pools of shape (num_blocks, block_size, K, hd) shared by
    every sequence; ``block_table`` (B, T) int32 names each sequence's
    blocks. Math is identical to :func:`gqa_decode` on the contiguous cache
    the table describes: scatter the new token's KV at virtual position
    ``cache_len``, then attend over positions < cache_len + 1. ``naive``
    gathers the contiguous view through the table (the oracle); ``pallas``
    streams physical blocks directly via the block-table flash-decode kernel.
    """
    from repro.paging import gather_paged_kv

    B = x.shape[0]
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, 1, cfg.n_heads, hd)
    k_new = (x @ p["wk"]).reshape(B, 1, cfg.n_kv_heads, hd)
    v_new = (x @ p["wv"]).reshape(B, 1, cfg.n_kv_heads, hd)
    if cfg.pos_embedding == "rope":
        pos = _decode_positions(cache_len, B)
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    ck = _scatter_token_paged(cache["k"], k_new, cache_len, block_table)
    cv = _scatter_token_paged(cache["v"], v_new, cache_len, block_table)
    new_cache = {"k": ck, "v": cv}
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.decode_attention_paged(q, ck, cv, block_table, cache_len + 1)
    else:
        out = naive_attention(q, gather_paged_kv(ck, block_table),
                              gather_paged_kv(cv, block_table),
                              causal=False, kv_len=cache_len + 1)
    y = out.reshape(B, 1, cfg.n_heads * hd) @ p["wo"]
    return y, new_cache


def gqa_decode_spec(p, x, cache, cache_len, cfg, *, impl: str = "naive"):
    """Speculative multi-token decode: verify S draft positions in one pass.

    x: (B,S,d) — the last accepted token followed by S-1 draft tokens, so
    position qi of the window sits at cache slot cache_len + qi. All S
    tokens' KV are scattered into the cache (rollback is the *caller's*
    cache_len bookkeeping: rejected tail KVs stay resident but are masked
    dead by every later call's length arguments), then each position
    attends causally inside the window on top of its sequence's history:
    positions < cache_len + qi + 1. Returns (B,S,d) activations — the
    logits at window position qi score draft token qi+1, exactly the
    verify distribution rejection sampling needs.
    """
    B, S, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k_new = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v_new = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.pos_embedding == "rope":
        pos = _decode_positions(cache_len, B) + jnp.arange(S, dtype=jnp.int32)
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    ck = _scatter_tokens(cache["k"], k_new, cache_len)
    cv = _scatter_tokens(cache["v"], v_new, cache_len)
    ck = shard(ck, "batch", "kvseq", None, None)
    cv = shard(cv, "batch", "kvseq", None, None)
    new_cache = {"k": ck, "v": cv}
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.decode_attention_spec(q, ck, cv, cache_len)
    else:
        out = naive_attention(q, ck, cv, causal=True, q_offset=cache_len)
    y = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]
    return y, new_cache


def gqa_decode_spec_paged(p, x, cache, cache_len, block_table, cfg, *,
                          impl: str = "naive"):
    """Speculative multi-token decode over a paged KV cache.

    Same verify-window math as :func:`gqa_decode_spec`; the S draft KVs are
    scattered through the block table (the engine appends boundary blocks
    for positions cache_len..cache_len+S-1 before the call), and rollback is
    again pure cache_len bookkeeping — rejected positions' blocks stay
    mapped, their stale contents masked dead and overwritten by the next
    window.
    """
    from repro.paging import gather_paged_kv

    B, S, _ = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k_new = (x @ p["wk"]).reshape(B, S, cfg.n_kv_heads, hd)
    v_new = (x @ p["wv"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.pos_embedding == "rope":
        pos = _decode_positions(cache_len, B) + jnp.arange(S, dtype=jnp.int32)
        q = apply_rope(q, pos, cfg.rope_theta)
        k_new = apply_rope(k_new, pos, cfg.rope_theta)
    ck = _scatter_tokens_paged(cache["k"], k_new, cache_len, block_table)
    cv = _scatter_tokens_paged(cache["v"], v_new, cache_len, block_table)
    new_cache = {"k": ck, "v": cv}
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.decode_attention_spec_paged(q, ck, cv, block_table,
                                               cache_len)
    else:
        out = naive_attention(q, gather_paged_kv(ck, block_table),
                              gather_paged_kv(cv, block_table),
                              causal=True, q_offset=cache_len)
    y = out.reshape(B, S, cfg.n_heads * hd) @ p["wo"]
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (deepseek-v3): latent KV cache; decode uses the absorbed form
# ---------------------------------------------------------------------------

_MLA_PALLAS_WARNED = False  # one-time impl-fallback warning (mla_forward)


def _mla_qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    nope, rope_d = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = apply_norm(p["q_norm"], x @ p["wq_a"], "rmsnorm") @ p["wq_b"]
    q = q.reshape(B, S, cfg.n_heads, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    kv_a = x @ p["wkv_a"]
    latent = apply_norm(p["kv_norm"], kv_a[..., :cfg.kv_lora_rank], "rmsnorm")
    k_rope = kv_a[..., cfg.kv_lora_rank:].reshape(B, S, 1, rope_d)
    if positions is not None:
        q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
        k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, latent, k_rope[..., 0, :]


def mla_forward(p, x, cfg, *, positions, impl="chunked", chunk=1024, return_cache=False):
    B, S, _ = x.shape
    nope, v_dim = cfg.qk_nope_head_dim, cfg.v_head_dim
    if impl == "pallas" and nope + cfg.qk_rope_head_dim != v_dim:
        # the fused MHA kernel assumes one head dim for q/k/v; MLA's qk dim
        # (nope + rope) differs from v_dim, so route prefill/training through
        # the chunked online-softmax path instead of producing garbage.
        # (MLA *decode* has its own latent-space pallas kernel and is fine.)
        import warnings
        global _MLA_PALLAS_WARNED
        if not _MLA_PALLAS_WARNED:
            _MLA_PALLAS_WARNED = True
            warnings.warn(
                f"MLA prefill cannot use impl='pallas' (qk head dim "
                f"{nope + cfg.qk_rope_head_dim} != v head dim {v_dim}); "
                f"falling back to 'chunked'. Decode still uses the fused "
                f"latent-space kernel.", RuntimeWarning, stacklevel=2)
        impl = "chunked"
    q_nope, q_rope, latent, k_rope = _mla_qkv(p, x, cfg, positions)
    kv = (latent @ p["wkv_b"]).reshape(B, S, cfg.n_heads, nope + v_dim)
    k_nope, v = kv[..., :nope], kv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, cfg.n_heads, k_rope.shape[-1]))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    q = shard(q, "batch", "seq", "tp", None)
    k = shard(k, "batch", "seq", "tp", None)
    v = shard(v, "batch", "seq", "tp", None)
    out = attention_impl(q, k, v, causal=True, impl=impl, chunk=chunk)
    out = out.reshape(B, S, cfg.n_heads * v_dim)
    y = out @ p["wo"]
    if return_cache:
        return y, {"latent": shard(latent, "batch", "kvseq", None),
                   "k_rope": shard(k_rope, "batch", "kvseq", None)}
    return y


def init_mla_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    return {
        "latent": jnp.zeros((batch, max_seq, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_seq, cfg.qk_rope_head_dim), dtype),
    }


def _mla_naive_latent_ctx(q_lat, q_rope, lat, kr, kv_len, scale):
    """Latent-space attention oracle shared by the contiguous and paged
    decode paths: scores = q_lat . latent + q_rope . k_rope, values = latent.
    Returns the (B, 1, H, r) context."""
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat, lat.astype(jnp.float32))
         + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    kv_idx = jnp.arange(lat.shape[1])
    if jnp.ndim(kv_len) > 0:  # ragged continuous batch
        valid = (kv_idx[None] < kv_len[:, None])[:, None, None]
    else:
        valid = (kv_idx < kv_len)[None, None, None]
    s = jnp.where(valid, s, -1e30)
    probs = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqs,bsr->bqhr", probs, lat.astype(jnp.float32))


def _mla_absorbed_q(p, q_nope, cfg):
    """Absorb W_UK into the query; returns (q_lat, w_uv)."""
    nope, v_dim = cfg.qk_nope_head_dim, cfg.v_head_dim
    wkv_b = p["wkv_b"].reshape(cfg.kv_lora_rank, cfg.n_heads, nope + v_dim)
    w_uk, w_uv = wkv_b[..., :nope], wkv_b[..., nope:]
    # (B,1,H,nope) x (r,H,nope) -> (B,1,H,r)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    return q_lat, w_uv


def mla_decode(p, x, cache, cache_len, cfg, *, impl: str = "naive"):
    """Absorbed-matrix MLA decode: attention runs in the latent space.

    scores = q_nope . W_UK^T . latent  +  q_rope . k_rope
    out    = (probs . latent) . W_UV -> wo
    The KV cache is only (kv_lora_rank + rope_dim) wide per position.
    ``cache_len`` scalar or (B,); ``impl="pallas"`` routes the latent-space
    attention through the fused single-query kernel (K=1, G=H).
    """
    B = x.shape[0]
    nope, v_dim, rope_d = cfg.qk_nope_head_dim, cfg.v_head_dim, cfg.qk_rope_head_dim
    pos = _decode_positions(cache_len, B)
    q_nope, q_rope, latent_new, k_rope_new = _mla_qkv(p, x, cfg, pos)

    lat = _scatter_token(cache["latent"], latent_new, cache_len)
    kr = _scatter_token(cache["k_rope"], k_rope_new, cache_len)
    lat = shard(lat, "batch", "kvseq", None)
    kr = shard(kr, "batch", "kvseq", None)

    q_lat, w_uv = _mla_absorbed_q(p, q_nope, cfg)
    scale = 1.0 / math.sqrt(nope + rope_d)
    if impl == "pallas":
        from repro.kernels import ops as kops
        ctx = kops.decode_attention_mla(
            q_lat, q_rope.astype(jnp.float32), lat, kr, cache_len + 1,
            scale=scale).astype(jnp.float32)
    else:
        ctx = _mla_naive_latent_ctx(q_lat, q_rope, lat, kr, cache_len + 1, scale)
    out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv.astype(jnp.float32)).astype(x.dtype)
    y = out.reshape(B, 1, cfg.n_heads * v_dim) @ p["wo"]
    return y, {"latent": lat, "k_rope": kr}


def mla_decode_paged(p, x, cache, cache_len, block_table, cfg, *,
                     impl: str = "naive"):
    """Absorbed-matrix MLA decode over paged latent pools.

    cache: {"latent": (num_blocks, block_size, r),
            "k_rope": (num_blocks, block_size, rd)} shared physical blocks;
    ``block_table`` (B, T) int32. Same latent-space math as
    :func:`mla_decode`, with the per-sequence cache reached through the
    table (gathered for ``naive``, scalar-prefetched for ``pallas``).
    """
    from repro.paging import gather_paged_kv

    B = x.shape[0]
    nope, v_dim, rope_d = cfg.qk_nope_head_dim, cfg.v_head_dim, cfg.qk_rope_head_dim
    pos = _decode_positions(cache_len, B)
    q_nope, q_rope, latent_new, k_rope_new = _mla_qkv(p, x, cfg, pos)

    lat = _scatter_token_paged(cache["latent"], latent_new, cache_len, block_table)
    kr = _scatter_token_paged(cache["k_rope"], k_rope_new, cache_len, block_table)

    q_lat, w_uv = _mla_absorbed_q(p, q_nope, cfg)
    scale = 1.0 / math.sqrt(nope + rope_d)
    if impl == "pallas":
        from repro.kernels import ops as kops
        ctx = kops.decode_attention_mla_paged(
            q_lat, q_rope.astype(jnp.float32), lat, kr, block_table,
            cache_len + 1, scale=scale).astype(jnp.float32)
    else:
        ctx = _mla_naive_latent_ctx(
            q_lat, q_rope, gather_paged_kv(lat, block_table),
            gather_paged_kv(kr, block_table), cache_len + 1, scale)
    out = jnp.einsum("bqhr,rhv->bqhv", ctx, w_uv.astype(jnp.float32)).astype(x.dtype)
    y = out.reshape(B, 1, cfg.n_heads * v_dim) @ p["wo"]
    return y, {"latent": lat, "k_rope": kr}
