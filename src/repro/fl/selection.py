"""Participant selection: uniform random + Oort-style utility (Lai et al.)."""
from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np


def random_selection(rng: np.random.Generator, available: Sequence[int], k: int) -> List[int]:
    avail = list(available)
    if len(avail) <= k:
        return avail
    return list(rng.choice(avail, size=k, replace=False))


class OortSelector:
    """Utility = statistical utility * (deadline/latency)^alpha, with
    epsilon-greedy exploration of never-tried clients."""

    def __init__(self, alpha: float = 2.0, epsilon: float = 0.2):
        self.alpha = alpha
        self.epsilon = epsilon
        self.stat_util: Dict[int, float] = {}
        self.latency: Dict[int, float] = {}

    def report(self, client: int, loss: float, n_samples: int, latency_s: float):
        self.stat_util[client] = abs(loss) * np.sqrt(max(n_samples, 1))
        self.latency[client] = latency_s

    def select(self, rng: np.random.Generator, available: Sequence[int], k: int,
               deadline_s: float) -> List[int]:
        avail = list(available)
        if len(avail) <= k:
            return avail
        explored = [c for c in avail if c in self.stat_util]
        fresh = [c for c in avail if c not in self.stat_util]
        n_explore = min(len(fresh), max(1, int(k * self.epsilon))) if fresh else 0
        n_exploit = k - n_explore

        def utility(c):
            u = self.stat_util[c]
            lat = self.latency.get(c, deadline_s)
            if lat > deadline_s:
                u *= (deadline_s / lat) ** self.alpha
            return u

        exploit = sorted(explored, key=utility, reverse=True)[:n_exploit]
        explore = list(rng.choice(fresh, size=n_explore, replace=False)) if n_explore else []
        chosen = exploit + explore
        if len(chosen) < k:
            rest = [c for c in avail if c not in chosen]
            chosen += list(rng.choice(rest, size=min(k - len(chosen), len(rest)),
                                      replace=False))
        return chosen
