"""The standardized client interface (paper §4.1): isActive + run_local_step.

A SwanClient owns: a device model, a battery trace + energy loan, a Swan plan
(explored execution-choice profiles) and a controller. ``run_local_step``
returns the wall-time and energy its active execution choice costs — the FL
simulator charges these against the loan; the distributed-framework
standard-interface contract (PySyft-style) is exactly these two methods.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import energy as E
from repro.core.controller import SwanController
from repro.core.planner import SwanPlan, explore_soc
from repro.core.profiler import greedy_baseline_profile
from repro.fl.traces import BatteryTrace


@dataclasses.dataclass
class LocalStepReport:
    latency_s: float
    energy_j: float
    choice_name: str


class SwanClient:
    def __init__(self, cid: int, device: str, trace: BatteryTrace, workload: str,
                 *, policy: str = "swan", n_samples: int = 200,
                 local_steps: int = 10, seed: int = 0):
        self.cid = cid
        self.device = device
        self.model = E.SOC_MODELS[device]
        self.trace = trace
        self.workload = workload
        self.policy = policy
        self.n_samples = n_samples
        self.local_steps = local_steps
        self.loan = E.EnergyLoan(
            battery_j=self.model.battery_j,
            daily_charge_j=0.55 * self.model.battery_j,
            daily_usage_j=0.5 * self.model.battery_j)
        if policy == "swan":
            self.plan: SwanPlan = explore_soc(device, workload)
            self.controller: Optional[SwanController] = self.plan.controller()
            self._profile = self.plan.selected
        else:  # PyTorch-greedy baseline (§5.1)
            self._profile = greedy_baseline_profile(self.model, workload)
            self.plan = None
            self.controller = None
        self._rng = np.random.default_rng(seed + cid)

    # -- standardized interface ------------------------------------------------
    def isActive(self, minute: float) -> bool:
        level, state = self.trace.at(minute)
        if not self.loan.available(level):
            return False
        # accept while charging, or above minimum level (paper §4.1 step 3)
        return state >= 0 or level > 0.35

    def run_local_step(self, minute: float, *, interference: float = 0.0) -> LocalStepReport:
        """One local training round (local_steps mini-batches)."""
        prof = self._profile
        if self.controller is not None and interference > 0:
            # observed latency inflated by the interferer -> controller migrates
            observed = prof.latency_s * (1.0 + interference)
            prof = self.controller.observe_step(observed)
            self._profile = prof
        elif self.controller is not None:
            prof = self.controller.observe_step(prof.latency_s)
            self._profile = prof
        jitter = self._rng.uniform(0.95, 1.1)
        lat = prof.latency_s * self.local_steps * jitter
        energy = prof.energy_j * self.local_steps * jitter
        level, state = self.trace.at(minute)
        if state <= 0:  # only discharging time draws the loan
            self.loan.borrow(energy)
        return LocalStepReport(latency_s=lat, energy_j=energy, choice_name=prof.name)

    def end_of_day(self):
        self.loan.repay_daily()
