from repro.fl.aggregation import FedYogi, fedavg, fedprox_grad  # noqa: F401
from repro.fl.client import SwanClient  # noqa: F401
from repro.fl.simulator import FLConfig, FLResult, compare_policies, run_fl  # noqa: F401
from repro.fl.traces import make_client_traces, pchip_interpolate  # noqa: F401
