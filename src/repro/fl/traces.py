"""GreenHub-like battery traces (paper §A.1/§A.2).

The raw GreenHub dataset is not redistributable; we generate statistically
matched synthetic traces (diurnal charge cycles, irregular sampling) and then
apply the paper's exact §A.2 pipeline: quality filters, PCHIP resampling to a
10-minute grid (own Fritsch–Carlson implementation — scipy is unavailable),
battery_state from consecutive level differences, and the 23x1h timezone
augmentation that turns 100 traces into 2400 clients.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

MINUTES_PER_DAY = 1440
RESAMPLE_MIN = 10


# ---------------------------------------------------------------------------
# PCHIP (Fritsch–Carlson monotone cubic Hermite), numpy-only
# ---------------------------------------------------------------------------


def _pchip_slopes(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    h = np.diff(x)
    delta = np.diff(y) / h
    n = len(x)
    d = np.zeros(n)
    if n == 2:
        d[:] = delta[0]
        return d
    w1 = 2 * h[1:] + h[:-1]
    w2 = h[1:] + 2 * h[:-1]
    with np.errstate(divide="ignore", invalid="ignore"):
        interior = (w1 + w2) / (w1 / delta[:-1] + w2 / delta[1:])
    same_sign = np.sign(delta[:-1]) * np.sign(delta[1:]) > 0
    d[1:-1] = np.where(same_sign, interior, 0.0)
    d[0] = _edge_slope(h[0], h[1], delta[0], delta[1])
    d[-1] = _edge_slope(h[-1], h[-2], delta[-1], delta[-2])
    return d


def _edge_slope(h0, h1, d0, d1):
    d = ((2 * h0 + h1) * d0 - h0 * d1) / (h0 + h1)
    if np.sign(d) != np.sign(d0):
        return 0.0
    if np.sign(d0) != np.sign(d1) and abs(d) > 3 * abs(d0):
        return 3 * d0
    return d


def pchip_interpolate(x: np.ndarray, y: np.ndarray, xq: np.ndarray) -> np.ndarray:
    """Monotone piecewise-cubic Hermite interpolation (shape-preserving)."""
    x = np.asarray(x, float)
    y = np.asarray(y, float)
    d = _pchip_slopes(x, y)
    idx = np.clip(np.searchsorted(x, xq, side="right") - 1, 0, len(x) - 2)
    h = x[idx + 1] - x[idx]
    t = (xq - x[idx]) / h
    h00 = (1 + 2 * t) * (1 - t) ** 2
    h10 = t * (1 - t) ** 2
    h01 = t * t * (3 - 2 * t)
    h11 = t * t * (t - 1)
    return h00 * y[idx] + h10 * h * d[idx] + h01 * y[idx + 1] + h11 * h * d[idx + 1]


# ---------------------------------------------------------------------------
# synthetic raw traces + the paper's §A.2 pipeline
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BatteryTrace:
    """10-min-grid battery level in [0,1] + state (1 charge, 0 flat, -1 drain)."""
    level: np.ndarray
    state: np.ndarray
    start_offset_min: int = 0

    def at(self, minute: float) -> Tuple[float, int]:
        i = int((minute + self.start_offset_min) // RESAMPLE_MIN) % len(self.level)
        return float(self.level[i]), int(self.state[i])

    @property
    def days(self) -> float:
        return len(self.level) * RESAMPLE_MIN / MINUTES_PER_DAY


def generate_raw_trace(rng: np.random.Generator, days: int = 28):
    """Irregularly-sampled (timestamp_min, level) like a GreenHub logger."""
    ts, level = [], []
    t = 0.0
    lv = rng.uniform(0.5, 0.95)
    charge_start = rng.uniform(21, 26)  # plug-in hour (mod 24)
    while t < days * MINUTES_PER_DAY:
        hour = (t / 60.0) % 24
        charging = (hour >= charge_start % 24 and hour < (charge_start + 7) % 24) \
            if charge_start % 24 < (charge_start + 7) % 24 else \
            (hour >= charge_start % 24 or hour < (charge_start + 7) % 24)
        dt = rng.exponential(9.0) + 1.0  # ~100+ samples/day
        if charging:
            lv = min(1.0, lv + 0.006 * dt * rng.uniform(0.8, 1.2))
        else:
            drain = 0.0006 * dt * (1.0 + 2.0 * np.exp(-((hour - 14) ** 2) / 18.0))
            lv = max(0.02, lv - drain * rng.uniform(0.6, 1.6))
        ts.append(t)
        level.append(lv)
        t += dt
    return np.asarray(ts), np.asarray(level)


def passes_quality_filters(ts: np.ndarray, days_min: float = 28.0,
                           freq_min_hz: float = 100.0 / 86400.0,
                           max_gap_h: float = 24.0, max_big_gaps: int = 15) -> bool:
    """Paper §A.2 criteria 1-4. NOTE: the paper states 5/432 Hz "equivalent
    to 100 samples a day", but 5/432 Hz is 1000/day; we use the 100/day
    reading (the stated intent)."""
    if len(ts) < 2:
        return False
    span_days = (ts[-1] - ts[0]) / MINUTES_PER_DAY
    if span_days < days_min - 1e-9:
        return False
    freq_hz = len(ts) / ((ts[-1] - ts[0]) * 60.0)
    if freq_hz < freq_min_hz:
        return False
    gaps_h = np.diff(ts) / 60.0
    if gaps_h.max() > max_gap_h:
        return False
    if int((gaps_h > 6.0).sum()) > max_big_gaps:
        return False
    return True


def resample_trace(ts: np.ndarray, level: np.ndarray) -> BatteryTrace:
    grid = np.arange(ts[0], ts[-1], RESAMPLE_MIN, dtype=float)
    lv = np.clip(pchip_interpolate(ts, level, grid), 0.0, 1.0)
    dlv = np.diff(lv, prepend=lv[0])
    state = np.where(dlv > 1e-6, 1, np.where(dlv < -1e-6, -1, 0)).astype(np.int8)
    return BatteryTrace(level=lv, state=state)


def make_client_traces(n_base: int = 100, *, seed: int = 0, days: int = 29,
                       tz_shifts: int = 24,
                       max_attempts_per_trace: int = 50) -> List[BatteryTrace]:
    """100 quality-filtered traces x 24 timezone shifts = 2400 clients (§A.2).

    The span filter is binding: ``days_min`` is passed explicitly (a previous
    version passed ``lv.size and 28.0`` positionally, which evaluates to ``0``
    for an empty trace and silently disabled the filter). A configuration
    whose raw traces cannot satisfy the filters (e.g. ``days < 28``) raises
    after a bounded number of attempts instead of looping forever."""
    rng = np.random.default_rng(seed)
    base: List[BatteryTrace] = []
    attempts = 0
    while len(base) < n_base:
        if attempts >= max_attempts_per_trace * n_base:
            raise ValueError(
                f"quality filters rejected every candidate trace "
                f"({attempts} attempts for {n_base} traces; days={days} "
                f"cannot satisfy days_min=28)")
        attempts += 1
        ts, lv = generate_raw_trace(rng, days=days)
        if passes_quality_filters(ts, days_min=28.0):
            base.append(resample_trace(ts, lv))
    out: List[BatteryTrace] = []
    for shift in range(tz_shifts):
        for tr in base:
            out.append(BatteryTrace(level=tr.level, state=tr.state,
                                    start_offset_min=shift * 60))
    return out
