"""Large-scale FL simulation (paper §5.3): 2400 clients, energy loans,
time-to-accuracy, online-device counts.

Statistical accuracy model (FedScale-style): global accuracy approaches a
task ceiling as total useful samples accumulate, with diminishing returns and
participation-dependent round gain. It deliberately models only what the
paper's macro claims depend on — rounds completed per wall-clock unit and how
many devices stay online — not the optimization trajectory itself (the real
optimization path is exercised by benchmarks/table4_fl.py's real-training
mode on a reduced cohort).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.fl.client import SwanClient
from repro.fl.selection import OortSelector, random_selection
from repro.fl.traces import make_client_traces
from repro.runtime.fault import StragglerPolicy

DEVICE_MIX = ("pixel3", "s10e", "oneplus8", "mi10", "tab_s6")

TASK_CEILING = {"resnet34": 0.63, "shufflenet-v2": 0.49, "mobilenet-v2": 0.56}
TASK_TAU = {"resnet34": 2.5e5, "shufflenet-v2": 3.5e6, "mobilenet-v2": 3.5e6}


@dataclasses.dataclass
class FLConfig:
    workload: str = "shufflenet-v2"
    n_clients: int = 2400
    clients_per_round: int = 100
    rounds: int = 500
    policy: str = "swan"  # swan | baseline
    selector: str = "random"  # random | oort
    round_deadline_s: float = 600.0
    interference_prob: float = 0.15  # fraction of rounds a client sees a foreground app
    seed: int = 0


@dataclasses.dataclass
class RoundLog:
    t_min: float
    accuracy: float
    online: int
    participated: int
    round_s: float
    energy_j: float
    shortfall: int = 0  # accepted-vs-target gap when the deadline binds


@dataclasses.dataclass
class FLResult:
    rounds: List[RoundLog]

    def time_to_accuracy(self, target: float) -> Optional[float]:
        for r in self.rounds:
            if r.accuracy >= target:
                return r.t_min
        return None

    @property
    def final_accuracy(self) -> float:
        return self.rounds[-1].accuracy if self.rounds else 0.0

    @property
    def total_energy_j(self) -> float:
        return sum(r.energy_j for r in self.rounds)


def run_fl(cfg: FLConfig) -> FLResult:
    rng = np.random.default_rng(cfg.seed)
    traces = make_client_traces(max(1, cfg.n_clients // 24), seed=cfg.seed,
                                tz_shifts=24)[:cfg.n_clients]
    clients = [
        SwanClient(i, DEVICE_MIX[i % len(DEVICE_MIX)], traces[i], cfg.workload,
                   policy=cfg.policy, seed=cfg.seed,
                   n_samples=int(rng.lognormal(4.5, 1.0)) + 16)
        for i in range(cfg.n_clients)
    ]
    oort = OortSelector() if cfg.selector == "oort" else None
    straggler = StragglerPolicy(over_provision=1.3, deadline_factor=2.0)

    t_min = 0.0
    samples_seen = 0.0
    ceiling = TASK_CEILING[cfg.workload]
    tau = TASK_TAU[cfg.workload]
    logs: List[RoundLog] = []
    last_day = 0

    for rnd in range(cfg.rounds):
        day = int(t_min // 1440)
        if day != last_day:
            for c in clients:
                c.end_of_day()
            last_day = day
        online = [c.cid for c in clients if c.isActive(t_min)]
        if not online:
            t_min += 10.0
            continue
        k = min(cfg.clients_per_round, len(online))
        invite = straggler.n_to_invite(k)
        if oort is not None:
            chosen = oort.select(rng, online, invite, cfg.round_deadline_s)
        else:
            chosen = random_selection(rng, online, invite)
        lats, energies, reports = [], [], []
        for cid in chosen:
            c = clients[cid]
            interf = float(rng.random() < cfg.interference_prob) * rng.uniform(0.5, 2.0)
            rep = c.run_local_step(t_min, interference=interf)
            lats.append(rep.latency_s)
            energies.append(rep.energy_j)
            reports.append((cid, rep))
        outcome = straggler.accept(lats, k, deadline_s=cfg.round_deadline_s)
        accepted = outcome.indices
        round_s = min(max((lats[i] for i in accepted), default=0.0), cfg.round_deadline_s)
        useful = len(accepted)
        if oort is not None:
            for i in accepted:
                cid, rep = reports[i]
                loss = max(0.1, 2.3 * (1 - samples_seen / (samples_seen + tau)))
                oort.report(cid, loss, clients[cid].n_samples, rep.latency_s)
        samples_seen += sum(clients[reports[i][0]].n_samples * 0.2 for i in accepted)
        acc = ceiling * (1.0 - math.exp(-samples_seen / tau))
        t_min += round_s / 60.0 + 0.5  # +30s aggregation/communication
        logs.append(RoundLog(t_min=t_min, accuracy=acc, online=len(online),
                             participated=useful, round_s=round_s,
                             energy_j=float(np.sum(energies)),
                             shortfall=outcome.shortfall))
    return FLResult(logs)


def compare_policies(workload: str, *, rounds: int = 300, n_clients: int = 480,
                     clients_per_round: int = 50, seed: int = 0) -> Dict[str, FLResult]:
    out = {}
    for policy in ("baseline", "swan"):
        cfg = FLConfig(workload=workload, n_clients=n_clients, rounds=rounds,
                       clients_per_round=clients_per_round, policy=policy, seed=seed)
        out[policy] = run_fl(cfg)
    return out
