"""Server aggregation rules: FedAvg, FedProx (client proximal), FedYogi."""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp


def fedavg(global_params, client_deltas: Sequence[Any],
           weights: Optional[Sequence[float]] = None):
    """global += weighted mean of client deltas (McMahan et al.)."""
    n = len(client_deltas)
    if weights is None:
        weights = [1.0 / n] * n
    total = sum(weights)
    ws = [w / total for w in weights]

    def combine(*leaves):
        g = leaves[0]
        acc = jnp.zeros_like(g, dtype=jnp.float32)
        for w, leaf in zip(ws, leaves[1:]):
            acc = acc + w * leaf.astype(jnp.float32)
        return (g.astype(jnp.float32) + acc).astype(g.dtype)

    return jax.tree_util.tree_map(combine, global_params, *client_deltas)


def fedprox_grad(local_params, global_params, mu: float):
    """Proximal-term gradient mu*(w - w_global) added to client grads."""
    return jax.tree_util.tree_map(
        lambda w, g: mu * (w.astype(jnp.float32) - g.astype(jnp.float32)),
        local_params, global_params)


@dataclasses.dataclass
class FedYogi:
    """Adaptive server optimizer (Reddi et al., cited by the paper)."""
    lr: float = 0.01
    b1: float = 0.9
    b2: float = 0.99
    eps: float = 1e-3
    state: Any = None

    def init(self, params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        self.state = {"m": z, "v": jax.tree_util.tree_map(
            lambda p: jnp.full(p.shape, self.eps ** 2, jnp.float32), params)}

    def step(self, global_params, client_deltas, weights=None):
        if self.state is None:
            self.init(global_params)
        n = len(client_deltas)
        weights = weights or [1.0 / n] * n
        total = sum(weights)
        delta = jax.tree_util.tree_map(
            lambda *ls: sum(w / total * l.astype(jnp.float32)
                            for w, l in zip(weights, ls)), *client_deltas)
        m = jax.tree_util.tree_map(
            lambda m_, d: self.b1 * m_ + (1 - self.b1) * d, self.state["m"], delta)
        v = jax.tree_util.tree_map(
            lambda v_, d: v_ - (1 - self.b2) * jnp.square(d) * jnp.sign(v_ - jnp.square(d)),
            self.state["v"], delta)
        self.state = {"m": m, "v": v}
        return jax.tree_util.tree_map(
            lambda p, m_, v_: (p.astype(jnp.float32)
                               + self.lr * m_ / (jnp.sqrt(v_) + self.eps)).astype(p.dtype),
            global_params, m, v)
