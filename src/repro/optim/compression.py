"""Gradient/update compression for cross-pod and FL uplinks.

Two composable schemes with error feedback (the residual of what compression
dropped is carried into the next round, preserving convergence — FetchSGD/
Deep-Gradient-Compression lineage, both cited by the paper's related work):

  int8 quantization  - per-tensor symmetric scale; 4x over fp32
  top-k sparsify     - keep the k largest-magnitude entries per tensor

``compress/decompress`` are pure pytree->pytree functions so they can sit
inside a jitted train step (cross-pod reduce) or at the FL client boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


def quantize_int8(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def topk_sparsify(x, frac: float):
    """Keep the ceil(frac*n) largest-|.| entries; returns (values, indices)."""
    flat = x.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(values, idx, shape):
    n = 1
    for s in shape:
        n *= s
    return jnp.zeros((n,), values.dtype).at[idx].set(values).reshape(shape)


@dataclasses.dataclass(frozen=True)
class Compressor:
    """scheme: none | int8 | topk:<frac> | int8+topk:<frac>"""
    scheme: str = "none"

    @property
    def topk_frac(self) -> Optional[float]:
        for part in self.scheme.split("+"):
            if part.startswith("topk:"):
                return float(part.split(":")[1])
        return None

    @property
    def use_int8(self) -> bool:
        return "int8" in self.scheme

    def ratio(self) -> float:
        """Compressed bytes / fp32 bytes (for the collective roofline term)."""
        r = 1.0
        if self.topk_frac is not None:
            r *= self.topk_frac * 2  # values + int32 indices
        if self.use_int8:
            r *= 0.25 if self.topk_frac is None else 0.625  # idx stays int32
        return min(r, 1.0)

    def init_error(self, grads):
        if self.scheme == "none":
            return ()
        return jax.tree_util.tree_map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def roundtrip(self, grads, error):
        """Returns (decompressed grads as seen by the receiver, new error)."""
        if self.scheme == "none":
            return grads, error

        frac = self.topk_frac

        def one(g, e):
            gf = g.astype(jnp.float32) + e
            if frac is not None:
                vals, idx = topk_sparsify(gf, frac)
                if self.use_int8:
                    q, s = quantize_int8(vals)
                    vals = dequantize_int8(q, s)
                dec = topk_densify(vals, idx, gf.shape)
            else:
                q, s = quantize_int8(gf)
                dec = dequantize_int8(q, s)
            return dec.astype(g.dtype), gf - dec

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_flatten(error)[0]
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        dec = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        err = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        return dec, err
