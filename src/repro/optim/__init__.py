from repro.optim.optimizers import adam, sgd, apply_updates  # noqa: F401
from repro.optim.schedule import cosine_schedule, warmup_linear  # noqa: F401
