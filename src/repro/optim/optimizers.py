"""Pure-JAX optimizers (no optax in this environment).

Optimizer = (init(params) -> state, update(grads, state, params, lr) ->
(updates, state)). The paper trains with plain SGD lr=0.05 (§5.1); that is the
paper-faithful setting. Adam exists for the beyond-paper experiments and the
LM examples. SGD keeps zero extra state, which is what lets deepseek-v3-671b
fit a v5e pod in the dry-run memory analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[..., Any]  # (grads, state, params, lr) -> (updates, state)
    bytes_per_param: int  # optimizer-state bytes (for the memory roofline)


def sgd(momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    if momentum == 0.0:
        def init(params):
            return ()

        def update(grads, state, params, lr):
            return jax.tree_util.tree_map(lambda g: -lr * g, grads), state

        return Optimizer("sgd", init, update, 0)

    def init(params):
        return jax.tree_util.tree_map(jnp.zeros_like, params)

    def update(grads, state, params, lr):
        new_m = jax.tree_util.tree_map(lambda m, g: momentum * m + g, state, grads)
        if nesterov:
            upd = jax.tree_util.tree_map(lambda m, g: -lr * (momentum * m + g), new_m, grads)
        else:
            upd = jax.tree_util.tree_map(lambda m: -lr * m, new_m)
        return upd, new_m

    return Optimizer("sgd_momentum", init, update, 4)


def adam(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": jax.tree_util.tree_map(jnp.copy, z), "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(m_, v_, p):
            step = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        return (jax.tree_util.tree_map(upd, m, v, params),
                {"m": m, "v": v, "t": t})

    return Optimizer("adam", init, update, 8)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(lambda p, u: p + u.astype(p.dtype), params, updates)
