"""End-to-end training driver (runs on real devices; CPU-scale by default).

Composes the full substrate: config -> model -> data pipeline -> optimizer ->
(optional) compression -> checkpoint manager -> fault-tolerant train loop with
Swan interference monitoring. ``--arch`` accepts any registry config; use
reduced configs + small shapes on CPU.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.core.interference import InterferenceMonitor
from repro.data.pipeline import synthetic_cnn_batch, synthetic_lm_batch
from repro.launch.steps import build_train_step, init_train_state
from repro.models.registry import build_model
from repro.optim.compression import Compressor
from repro.optim.optimizers import adam, sgd


def make_batch_fn(cfg, batch, seq, seed=0):
    def fn(step):
        rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
        if cfg.family == "cnn":
            return synthetic_cnn_batch(rng, batch, cfg.image_size, cfg.in_channels,
                                       cfg.n_classes)
        b = synthetic_lm_batch(rng, batch, seq, cfg.vocab_size)
        if cfg.family == "vlm":
            b["image_embed"] = rng.standard_normal(
                (batch, cfg.n_image_tokens, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.family == "encdec":
            b["audio_embed"] = rng.standard_normal(
                (batch, cfg.n_audio_frames, cfg.d_model)).astype(np.float32) * 0.02
        return b

    return fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adam"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--attn-impl", default="auto",
                    choices=["auto", "naive", "chunked", "pallas"],
                    help="attention kernel; auto = naive for short seq, "
                         "chunked beyond 512")
    ap.add_argument("--compression", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    impl = args.attn_impl
    if impl == "auto":
        impl = "naive" if args.seq <= 512 else "chunked"
    model = build_model(cfg, impl=impl)
    opt = sgd() if args.optimizer == "sgd" else adam()
    comp = Compressor(args.compression)
    step_fn = jax.jit(build_train_step(model, opt, microbatch=args.microbatch,
                                       lr=args.lr, compressor=comp))
    batch_fn = make_batch_fn(cfg, args.batch, args.seq)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    state = None
    start = 0
    if mgr and args.resume:
        restored = mgr.restore_latest()
        if restored:
            start, state = restored
            state = jax.tree_util.tree_map(jnp.asarray, state)
            print(f"resumed from step {start}")
    if state is None:
        state = init_train_state(model, opt, jax.random.PRNGKey(0), compressor=comp)

    monitor = None
    losses = []
    for step in range(start, args.steps):
        t0 = time.time()
        state, metrics = step_fn(state, batch_fn(step))
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if monitor is None and step > start + 1:
            monitor = InterferenceMonitor(expected_latency_s=dt)
        elif monitor is not None:
            monitor.observe(dt)
            if monitor.interfering:
                print(f"[swan] interference inferred at step {step} "
                      f"(severity {monitor.severity:.2f})")
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {loss:8.4f} ({dt * 1e3:.0f} ms)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, state)
    if mgr:
        mgr.save(args.steps, state)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
