"""Training CLI — a thin front-end over engine.session.TrainSession.

Composes config -> model -> data pipeline -> optimizer -> (optional)
compression -> checkpoint manager, then hands the loop to the engine. With
``--adaptive`` the session runs a Rung downgrade ladder under Swan's
controller and migrates in place when interference appears;
``--interference-trace`` injects synthetic co-tenant bursts
(``start:stop:slowdown[,...]``) and ``--thermal-trace`` closed-loop thermal
throttling (``heat:cool:slowdown[:trigger:release]``, paper §3.3) so the
adaptive path can be exercised on a quiet machine. ``--arch`` accepts any
registry config; use reduced configs + small shapes on CPU.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
  ... --adaptive --interference-trace 40:80:3.0
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import synthetic_cnn_batch, synthetic_lm_batch
from repro.engine.events import InterferenceTrace, ThermalTrace
from repro.engine.rungs import Rung, default_rung_ladder
from repro.engine.session import TrainSession
from repro.kernels.backend import auto_attn_impl
from repro.optim.compression import Compressor
from repro.optim.optimizers import adam, sgd


def make_batch_fn(cfg, batch, seq, seed=0):
    def fn(step):
        rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
        if cfg.family == "cnn":
            return synthetic_cnn_batch(rng, batch, cfg.image_size, cfg.in_channels,
                                       cfg.n_classes)
        b = synthetic_lm_batch(rng, batch, seq, cfg.vocab_size)
        if cfg.family == "vlm":
            b["image_embed"] = rng.standard_normal(
                (batch, cfg.n_image_tokens, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.family == "encdec":
            b["audio_embed"] = rng.standard_normal(
                (batch, cfg.n_audio_frames, cfg.d_model)).astype(np.float32) * 0.02
        return b

    return fn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adam"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--attn-impl", default="auto",
                    choices=["auto", "naive", "chunked", "pallas"],
                    help="attention kernel; auto consults backend capability "
                         "and sequence length (kernels/backend.auto_attn_impl)")
    ap.add_argument("--compression", default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--adaptive", action="store_true",
                    help="run the Rung downgrade ladder under Swan's "
                         "controller instead of one static step")
    ap.add_argument("--interference-trace", default=None,
                    help="synthetic co-tenant bursts, e.g. '40:80:2.5,120:140:3'")
    ap.add_argument("--thermal-trace", default=None,
                    help="closed-loop thermal throttling (paper §3.3): "
                         "'heat:cool:slowdown[:trigger:release]', e.g. "
                         "'0.05:0.02:2.5'; mutually exclusive with "
                         "--interference-trace")
    ap.add_argument("--upgrade-patience", type=int, default=5)
    ap.add_argument("--timeline-out", default=None,
                    help="write the migration timeline JSON here")
    ap.add_argument("--telemetry-out", default=None,
                    help="directory for the repro.obs telemetry bundle "
                         "(metrics.jsonl, spans.jsonl, trace.json, "
                         "audit.json)")
    args = ap.parse_args(argv)

    tel = obs.enable() if args.telemetry_out else None
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    impl = args.attn_impl
    if impl == "auto":
        impl = auto_attn_impl(args.seq)
    opt = sgd() if args.optimizer == "sgd" else adam()
    comp = Compressor(args.compression)

    if args.adaptive:
        rungs = default_rung_ladder(batch=args.batch,
                                    microbatch=args.microbatch,
                                    attn_impl=impl)
        if len(rungs) == 1:
            print(f"[swan] warning: --batch {args.batch} leaves no deeper "
                  f"accumulation rungs; --adaptive has nothing to migrate to")
    else:
        rungs = [Rung(name="static", microbatch=args.microbatch,
                      attn_impl=impl)]
    if args.interference_trace and args.thermal_trace:
        raise SystemExit("--interference-trace and --thermal-trace are "
                         "mutually exclusive (one trace drives the monitor)")
    trace = InterferenceTrace.parse(args.interference_trace) \
        if args.interference_trace else None
    if args.thermal_trace:
        trace = ThermalTrace.parse(args.thermal_trace)

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    state = None
    start = 0
    if mgr and args.resume:
        restored = mgr.restore_latest()
        if restored:
            start, state = restored
            state = jax.tree_util.tree_map(jnp.asarray, state)
            print(f"resumed from step {start}")
    if start >= args.steps:
        print(f"nothing to do: resumed step {start} >= --steps {args.steps}")
        return []

    session = TrainSession(
        cfg, rungs, optimizer=opt, lr=args.lr, compressor=comp,
        batch_fn=make_batch_fn(cfg, args.batch, args.seq),
        ckpt=mgr, ckpt_every=args.ckpt_every, trace=trace,
        adaptive=args.adaptive, upgrade_patience=args.upgrade_patience,
        log_every=args.log_every)
    result = session.run(args.steps, start=start, state=state)

    losses = result.losses
    summary = result.timeline.summary()
    if args.adaptive or trace:
        print(f"[swan] migrations: {summary['n_migrations']} "
              f"(down {summary['downgrades']}, up {summary['upgrades']}), "
              f"final rung {result.final_rung}")
    if args.timeline_out:
        result.timeline.save(args.timeline_out)
        print(f"[swan] timeline -> {args.timeline_out}")
    if tel is not None:
        tel.save(args.telemetry_out)
        print(f"[obs] telemetry bundle -> {args.telemetry_out} "
              f"({len(tel.tracer.spans())} spans)")
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
