"""Production meshes. Importing this module never touches jax device state."""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(shape)))


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale dry-run tests (host device count permitting)."""
    return jax.make_mesh(shape, axes, axis_types=_auto(len(shape)))


def make_choice_mesh(choice):
    """Mesh for an arbitrary MeshChoice (Swan exploration)."""
    return jax.make_mesh(choice.mesh_shape, choice.axis_names,
                         axis_types=_auto(len(choice.mesh_shape)))
