"""Production meshes. Importing this module never touches jax device state."""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CI-scale dry-run tests (host device count permitting)."""
    return make_mesh(shape, axes)


def make_choice_mesh(choice):
    """Mesh for an arbitrary MeshChoice (Swan exploration)."""
    return make_mesh(choice.mesh_shape, choice.axis_names)
