"""Summarize a ``repro.obs`` telemetry bundle from the command line.

A bundle directory (written by any launcher's ``--telemetry-out``) holds
``metrics.jsonl`` (per-tick snapshots), ``spans.jsonl`` (span records),
``trace.json`` (Chrome-trace/Perfetto) and ``audit.json`` (arbiter
decision log). This tool prints the three views that answer "what did the
runtime do and why":

- top spans by total time (count / total / mean / max per span name),
- the migration audit table — every propose/commit/veto with the
  relinquish scores, SLO headroom and rule that decided it,
- the per-rung step-latency quantile table (the measured ladder costs the
  planner's estimates should be checked against — including per-draft-depth
  speculative verify latency),
- final metric values from the last snapshot line.

``--chrome-trace OUT`` re-derives a Chrome-trace JSON from ``spans.jsonl``
(useful when only the JSONL stream was shipped off-device) — the output
loads directly in Perfetto / chrome://tracing.

Usage:
  PYTHONPATH=src python -m repro.launch.obs_report /tmp/tel
  PYTHONPATH=src python -m repro.launch.obs_report /tmp/tel \
      --chrome-trace /tmp/trace.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List, Optional

from repro.obs.schema import SCHEMA_VERSION, versioned


def load_jsonl(path: str) -> List[Dict[str, Any]]:
    """Read a JSONL stream, skipping the versioned header line if present."""
    rows: List[Dict[str, Any]] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            row = json.loads(line)
            if "stream" in row and "schema_version" in row:
                continue  # header line
            rows.append(row)
    return rows


def span_table(spans: List[Dict[str, Any]], top: int = 0) -> List[Dict[str, Any]]:
    """Aggregate span records by name, sorted by total time descending."""
    agg: Dict[str, Dict[str, float]] = {}
    for s in spans:
        a = agg.setdefault(s["name"], {"count": 0, "total_us": 0.0,
                                       "max_us": 0.0})
        a["count"] += 1
        a["total_us"] += s["dur_us"]
        a["max_us"] = max(a["max_us"], s["dur_us"])
    rows = [{"name": name, **a, "mean_us": a["total_us"] / a["count"]}
            for name, a in agg.items()]
    rows.sort(key=lambda r: -r["total_us"])
    return rows[:top] if top else rows


def _parse_labels(flat_key: str) -> Dict[str, str]:
    """``name{k=v,k2=v2}`` -> label dict (empty for unlabeled keys)."""
    if "{" not in flat_key:
        return {}
    inner = flat_key[flat_key.index("{") + 1:flat_key.rindex("}")]
    out: Dict[str, str] = {}
    for part in inner.split(","):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def rung_latency_table(final: Dict[str, Any],
                       metric: str = "job_step_latency_s"
                       ) -> List[Dict[str, Any]]:
    """Per-(job, rung) quantile rows from the final snapshot's histogram
    summaries — the measured per-rung step costs the planner's estimates
    should be checked against (and, for a speculating ServeJob, the
    per-draft-depth latency of the verify rounds)."""
    rows: List[Dict[str, Any]] = []
    for key, val in final.items():
        if not key.startswith(metric) or not isinstance(val, dict):
            continue
        labels = _parse_labels(key)
        rows.append({"job": labels.get("job", "-"),
                     "rung": labels.get("rung", "-"),
                     "count": val.get("count"), "mean": val.get("mean"),
                     "p50": val.get("p50"), "p90": val.get("p90"),
                     "p99": val.get("p99"), "max": val.get("max")})
    rows.sort(key=lambda r: (r["job"], r["rung"]))
    return rows


def print_rung_latency_table(rows: List[Dict[str, Any]], file=None) -> None:
    if not rows:
        print("  (no per-rung latency samples)", file=file)
        return
    print(f"  {'job':<10} {'rung':<18} {'n':>5} {'mean':>9} {'p50':>9} "
          f"{'p90':>9} {'p99':>9} {'max':>9}", file=file)

    def ms(v):
        return f"{v * 1e3:8.2f}m" if isinstance(v, (int, float)) else "       -"

    for r in rows:
        print(f"  {r['job']:<10} {r['rung']:<18} {r['count'] or 0:>5} "
              f"{ms(r['mean'])} {ms(r['p50'])} {ms(r['p90'])} {ms(r['p99'])} "
              f"{ms(r['max'])}", file=file)


def _fmt_scores(scores: Dict[str, Any]) -> str:
    if not scores:
        return "-"
    parts = []
    for k, v in sorted(scores.items()):
        parts.append(f"{k}={v:.3g}" if isinstance(v, (int, float)) else
                     f"{k}={v}")
    return " ".join(parts)


def print_audit_table(records: List[Dict[str, Any]], file=None) -> None:
    if not records:
        print("  (no audit records)", file=file)
        return
    hdr = (f"  {'tick':>5} {'job':<10} {'event':<11} {'rule':<12} "
           f"{'rung':<18} scores")
    print(hdr, file=file)
    for r in records:
        rung = r.get("from_rung", "")
        if r.get("to_rung") and r["to_rung"] != rung:
            rung = f"{rung}->{r['to_rung']}"
        print(f"  {str(r.get('tick', '')):>5} {r.get('job', ''):<10} "
              f"{r.get('event', ''):<11} {r.get('rule', '') or '-':<12} "
              f"{rung:<18} {_fmt_scores(r.get('scores') or {})}", file=file)


def spans_to_chrome_trace(spans: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Rebuild a Chrome-trace document from span records (spans.jsonl)."""
    tids = sorted({s["tid"] for s in spans})
    dense = {t: i + 1 for i, t in enumerate(tids)}
    events: List[Dict[str, Any]] = [
        {"name": "process_name", "ph": "M", "pid": 1,
         "args": {"name": "swan"}}]
    for t in tids:
        events.append({"name": "thread_name", "ph": "M", "pid": 1,
                       "tid": dense[t], "args": {"name": f"thread-{t}"}})
    for s in spans:
        events.append({"name": s["name"], "ph": "X", "pid": 1,
                       "tid": dense[s["tid"]], "ts": s["ts_us"],
                       "dur": s["dur_us"], "args": s.get("args") or {}})
    return {"traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": versioned({"source": "obs_report"})}


def report(outdir: str, *, top: int = 15, audit_limit: int = 40,
           chrome_trace: Optional[str] = None) -> Dict[str, Any]:
    """Print the report; returns the structured summary (for tests)."""
    out: Dict[str, Any] = versioned({})

    spans_path = os.path.join(outdir, "spans.jsonl")
    spans = load_jsonl(spans_path) if os.path.exists(spans_path) else []
    out["spans"] = span_table(spans, top=top)
    print(f"== top spans by total time ({len(spans)} spans) ==")
    for r in out["spans"]:
        print(f"  {r['name']:<24} n={r['count']:<6} "
              f"total={r['total_us'] / 1e3:9.2f} ms  "
              f"mean={r['mean_us'] / 1e3:8.3f} ms  "
              f"max={r['max_us'] / 1e3:8.3f} ms")

    audit_path = os.path.join(outdir, "audit.json")
    audit: List[Dict[str, Any]] = []
    if os.path.exists(audit_path):
        with open(audit_path) as f:
            doc = json.load(f)
        audit = doc.get("records", [])
    out["audit"] = audit
    decisions = [r for r in audit if r.get("event") in ("commit", "veto")]
    print(f"\n== migration audit ({len(audit)} records, "
          f"{len(decisions)} commits/vetoes) ==")
    shown = decisions[-audit_limit:] if audit_limit else decisions
    if len(shown) < len(decisions):
        print(f"  ... showing last {len(shown)}")
    print_audit_table(shown)

    metrics_path = os.path.join(outdir, "metrics.jsonl")
    final: Dict[str, Any] = {}
    if os.path.exists(metrics_path):
        lines = load_jsonl(metrics_path)
        if lines:
            final = lines[-1].get("metrics", {})
    out["final_metrics"] = final
    out["rung_latency"] = rung_latency_table(final)
    print("\n== per-rung step latency quantiles ==")
    print_rung_latency_table(out["rung_latency"])

    print(f"\n== final metric values ({len(final)}) ==")
    for key in sorted(final):
        v = final[key]
        if isinstance(v, dict):  # histogram summary
            print(f"  {key}: n={v.get('count')} mean={v.get('mean')} "
                  f"p99={v.get('p99')}")
        else:
            print(f"  {key}: {v}")

    if chrome_trace:
        doc = spans_to_chrome_trace(spans)
        with open(chrome_trace, "w") as f:
            json.dump(doc, f)
        print(f"\n[obs] chrome trace ({len(spans)} spans) -> {chrome_trace}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="summarize a repro.obs telemetry bundle "
                    f"(schema v{SCHEMA_VERSION})")
    ap.add_argument("outdir", help="telemetry bundle directory "
                                   "(from --telemetry-out)")
    ap.add_argument("--top", type=int, default=15,
                    help="span-table rows (0 = all)")
    ap.add_argument("--audit-limit", type=int, default=40,
                    help="audit rows to print (0 = all)")
    ap.add_argument("--chrome-trace", default=None,
                    help="also convert spans.jsonl to a Chrome-trace JSON "
                         "at this path")
    args = ap.parse_args(argv)
    return report(args.outdir, top=args.top, audit_limit=args.audit_limit,
                  chrome_trace=args.chrome_trace)


if __name__ == "__main__":
    main()
