"""Roofline report: reads the dry-run JSON and renders EXPERIMENTS.md tables.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline reports/dryrun.json [--mesh 16x16]
"""
from __future__ import annotations

import argparse
import json
import sys


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}us"


BOTTLENECK_FIX = {
    "compute": "reduce recompute (remat policy) / increase MXU utilization via larger per-chip tiles",
    "memory": "fuse elementwise chains, cut activation round-trips (bigger microbatch, kernel fusion)",
    "collective": "shrink payloads (grad compression, bf16 collectives) or trade TP for DP",
}


def render(reports, mesh_filter=None):
    rows = [r for r in reports if mesh_filter is None or r["mesh"] == mesh_filter]
    rows.sort(key=lambda r: (r["mesh"], r["arch"], r["shape"]))
    out = []
    hdr = ("| arch | shape | mesh | status | compute | memory | collective | dominant "
           "| est step | MODEL_FLOPS/HLO | roofline frac | GB/dev | fits |")
    out.append(hdr)
    out.append("|" + "---|" * 13)
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']}: "
                       f"{r.get('reason', r.get('error', ''))[:60]} |" + " - |" * 9)
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | {r['dominant']} "
            f"| {fmt_s(r['latency_s'])} | {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} | {r['per_device_gb']:.2f} "
            f"| {'yes' if r['fits_hbm'] else 'NO'} |")
    return "\n".join(out)


def one_liners(reports):
    out = []
    for r in sorted(reports, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            continue
        out.append(f"- **{r['arch']} x {r['shape']} ({r['mesh']})**: dominant = "
                   f"{r['dominant']}; to move it down: {BOTTLENECK_FIX[r['dominant']]}.")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("report")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--advice", action="store_true")
    args = ap.parse_args()
    with open(args.report) as f:
        reports = json.load(f)
    print(render(reports, args.mesh))
    if args.advice:
        print()
        print(one_liners(reports))


if __name__ == "__main__":
    main()
