"""Fleet driver: thousands of trace-driven FL client SoCs, one coordinator.

Runs the fleet coordinator over a quality-filtered battery-trace population:
each selected client executes its local round as a preemptible
:class:`~repro.fleet.job.FLTrainJob` inside its own per-device
``SwanRuntime`` (thermal throttling, energy loan, foreground bursts), while
the coordinator owns invites, deadlines, retry waves, dedup/checksum
acceptance, and crash-consistent aggregation.

Fleet fault injection (client churn, dropped/duplicated/corrupted update
delivery, a coordinator crash) is seeded and optional. With ``--crash-round``
the run demonstrates crash recovery end to end: the coordinator dies
mid-aggregation and is resumed from its durable state in-process —
the final aggregate is bitwise identical to a crash-free run.

Usage:
  PYTHONPATH=src python -m repro.launch.fleet --clients 480 --rounds 6 \
      --per-round 20 --policy swan --churn 0.1 --heavy-churn 4:0.35 \
      --drop 0.05 --dup 0.05 --corrupt 0.05 --crash-round 2 \
      --json-out /tmp/fleet.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import tempfile

from repro import obs
from repro.engine.chaos import FleetChaos
from repro.fleet import (CoordinatorCrash, FleetConfig, FleetCoordinator,
                         build_fleet_clients)


def build_chaos(args):
    """FleetChaos from the CLI namespace, or None when nothing is injected."""
    churn_rounds = {}
    if args.heavy_churn:
        for part in args.heavy_churn.split(","):
            rnd, frac = part.split(":")
            churn_rounds[int(rnd)] = float(frac)
    crash_at = (args.crash_round, args.crash_after) \
        if args.crash_round >= 0 else None
    if not (args.churn or churn_rounds or args.drop or args.dup
            or args.corrupt or crash_at):
        return None
    return FleetChaos(seed=args.chaos_seed, churn_prob=args.churn,
                      churn_rounds=churn_rounds or None, drop_prob=args.drop,
                      dup_prob=args.dup, corrupt_prob=args.corrupt,
                      crash_at=crash_at)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=480,
                    help="fleet size (trace set = ceil(n/24) base traces "
                         "x 24 timezone shifts)")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--per-round", type=int, default=20,
                    help="aggregation target k per round (invites are "
                         "over-provisioned above this)")
    ap.add_argument("--policy", default="swan", choices=["swan", "baseline"])
    ap.add_argument("--selector", default="random",
                    choices=["random", "oort"],
                    help="client selection; note oort keeps in-process "
                         "utility state, so crash-resume bitwise parity is "
                         "only guaranteed with random")
    ap.add_argument("--workload", default="shufflenet-v2")
    ap.add_argument("--local-steps", type=int, default=16)
    ap.add_argument("--deadline", type=float, default=0.0,
                    help="round deadline in seconds (0 = derive from the "
                         "fleet-median clean round wall time)")
    ap.add_argument("--over-provision", type=float, default=1.3)
    ap.add_argument("--max-retries", type=int, default=1)
    ap.add_argument("--stale-frac", type=float, default=0.25,
                    help="stale-update acceptance window as a fraction of "
                         "the deadline")
    # fleet fault injection
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--churn", type=float, default=0.0,
                    help="per-round client churn probability")
    ap.add_argument("--heavy-churn", default=None,
                    help="per-round churn overrides 'round:frac,...' "
                         "(e.g. '4:0.35' for a 35%%-churn round 4)")
    ap.add_argument("--drop", type=float, default=0.0,
                    help="update delivery drop probability")
    ap.add_argument("--dup", type=float, default=0.0,
                    help="update duplicate-delivery probability (rejected "
                         "by coordinator dedup)")
    ap.add_argument("--corrupt", type=float, default=0.0,
                    help="update corruption probability (rejected by "
                         "checksum)")
    ap.add_argument("--crash-round", type=int, default=-1,
                    help="crash the coordinator mid-aggregation in this "
                         "round, then resume from durable state (-1 = off)")
    ap.add_argument("--crash-after", type=int, default=3,
                    help="accepted updates before the injected crash fires")
    ap.add_argument("--state-dir", default=None,
                    help="coordinator durable-state directory (default: "
                         "a temporary directory)")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--telemetry-out", default=None,
                    help="directory for the repro.obs telemetry bundle "
                         "(metrics.jsonl, spans.jsonl, trace.json, "
                         "audit.json)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", dest="verbose", action="store_false")
    args = ap.parse_args(argv)

    tel = obs.enable() if args.telemetry_out else None
    cfg = FleetConfig(n_clients=args.clients,
                      clients_per_round=args.per_round, rounds=args.rounds,
                      policy=args.policy, selector=args.selector,
                      workload=args.workload, local_steps=args.local_steps,
                      seed=args.seed, round_deadline_s=args.deadline,
                      stale_frac=args.stale_frac,
                      over_provision=args.over_provision,
                      max_retries=args.max_retries)
    chaos = build_chaos(args)
    clients = build_fleet_clients(cfg)

    tmp = None
    state_dir = args.state_dir
    if state_dir is None:
        tmp = tempfile.TemporaryDirectory()
        state_dir = tmp.name
    try:
        coord = FleetCoordinator(clients, cfg, state_dir=state_dir,
                                 chaos=chaos)
        try:
            res = coord.run()
        except CoordinatorCrash:
            if args.verbose:
                print("[fleet] coordinator crashed mid-aggregation; "
                      "resuming from durable state")
            coord = FleetCoordinator.resume(clients, cfg,
                                            state_dir=state_dir, chaos=chaos)
            res = coord.run()
    finally:
        if tmp is not None:
            tmp.cleanup()

    if args.verbose:
        for r in res.rounds:
            print(f"[fleet] round {r.rnd}: online={r.online} "
                  f"invited={r.invited} accepted={r.accepted} "
                  f"(stale {r.stale_accepted}, shortfall {r.shortfall}) "
                  f"churn={r.churned} offline={r.offline} "
                  f"preempt={r.preempted} straggle={r.straggled} "
                  f"rejects(dup/crc/late)={r.dup_rejected}/"
                  f"{r.corrupt_rejected}/{r.late_rejected} "
                  f"round={r.round_s:.1f}s/{r.deadline_s:.1f}s "
                  f"acc={r.accuracy:.5f}")
    print(f"[fleet] {args.policy}: {len(res.rounds)} rounds, "
          f"goodput {res.goodput_samples_per_h:.0f} samples/h, "
          f"SLO attainment {res.slo_attainment:.3f}, "
          f"energy {res.total_energy_j:.0f} J, "
          f"final accuracy {res.final_accuracy:.5f}")
    by_cls = res.accepted_by_class()
    if by_cls:
        print("[fleet] accepted by device class: "
              + ", ".join(f"{k}={v}" for k, v in sorted(by_cls.items())))
    if chaos is not None:
        print(f"[fleet] chaos: applied {sorted(chaos.applied)}")

    if args.json_out:
        payload = obs.versioned({"config": dataclasses.asdict(cfg),
                                 "result": res.to_json()})
        if chaos is not None:
            payload["chaos"] = chaos.to_json()
        with open(args.json_out, "w") as f:
            json.dump(obs.encode_record(payload), f, indent=1)
        if args.verbose:
            print(f"[fleet] wrote {args.json_out}")
    if tel is not None:
        tel.save(args.telemetry_out)
        print(f"[obs] telemetry bundle -> {args.telemetry_out} "
              f"({len(tel.tracer.spans())} spans)")
    return res


if __name__ == "__main__":
    main()
