"""Mixed-workload driver: training and serving co-tenant on one SoC.

Swan's premise is that workloads share the device; this CLI is the smallest
end-to-end demonstration: one ``TrainSession`` (personalization training in
the background) and one ``ServeJob`` (interactive decode) under a single
``SwanRuntime`` arbiter. The shared ThermalTrace integrates the **summed**
power draw of both jobs — training alone may never trip the throttle, but
training *plus* serving does, and the arbiter decides who relinquishes:
the job whose next rung frees the most contended resource per unit of
goodput lost (priority-weighted). An optional energy budget
(``core.energy.EnergyLoan``) additionally walks jobs toward low-power rungs
once the borrowed battery would cross the critical level.

Usage:
  PYTHONPATH=src python -m repro.launch.mixed --arch llama3.2-1b --reduced \
      --ticks 40 --batch 8 --seq 64 --slots 4 --requests 16 \
      --thermal-trace 0.2:0.25:3.0 --timeline-out /tmp/mixed.json
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.core.energy import EnergyLoan
from repro.engine.chaos import ChaosInjector
from repro.engine.events import (ChargingTrace, InterferenceTrace,
                                 ThermalTrace)
from repro.engine.jobs import (ForegroundAppJob, ServeJob,
                               default_serve_ladder)
from repro.engine.runtime import SwanRuntime
from repro.engine.rungs import default_rung_ladder
from repro.engine.session import TrainSession
from repro.kernels.backend import auto_attn_impl, auto_decode_impl
from repro.launch.serve import ContinuousBatchingEngine
from repro.launch.serve import _synthetic_requests
from repro.launch.train import make_batch_fn
from repro.models.registry import build_model
from repro.optim.compression import Compressor
from repro.optim.optimizers import adam, sgd


def build_jobs(args):
    """Job list from the CLI namespace: [train, serve] plus a foreground
    app when ``--fg-burst`` is given. (The arbitration benchmark builds its
    own latency-simulated jobs; this is the real-compute construction
    path.)"""
    cfg_t = get_config(args.arch)
    cfg_s = get_config(args.serve_arch or args.arch)
    if args.reduced:
        cfg_t, cfg_s = cfg_t.reduced(), cfg_s.reduced()

    impl_t = args.attn_impl
    if impl_t == "auto":
        impl_t = auto_attn_impl(args.seq)
    rungs = default_rung_ladder(batch=args.batch, microbatch=args.microbatch,
                                attn_impl=impl_t)
    opt = sgd() if args.optimizer == "sgd" else adam()
    train = TrainSession(
        cfg_t, rungs, optimizer=opt, lr=args.lr,
        compressor=Compressor("none"),
        batch_fn=make_batch_fn(cfg_t, args.batch, args.seq),
        adaptive=True, upgrade_patience=args.upgrade_patience,
        log_every=args.log_every, verbose=False,  # the runtime narrates
        name="train", priority=args.train_priority)
    train.bind(args.ticks)

    max_seq = args.max_seq or 2 * (args.prompt_len + args.gen)
    impl_s = auto_decode_impl(max_seq)
    model = build_model(cfg_s, impl=impl_s)
    params = model.init(jax.random.PRNGKey(0))
    engine = ContinuousBatchingEngine(model, params, max_batch=args.slots,
                                      max_seq=max_seq,
                                      kv_layout=args.kv_layout,
                                      admission_policy=args.admission_policy)
    rng = np.random.default_rng(args.seed)
    n_req = args.requests or 3 * args.slots
    reqs = _synthetic_requests(rng, n_req, args.prompt_len, args.gen,
                               cfg_s.vocab_size)
    serve = ServeJob(engine, reqs, rungs=default_serve_ladder(args.slots),
                     name="serve", priority=args.serve_priority,
                     upgrade_patience=args.upgrade_patience,
                     slo_p99_s=args.slo_p99 or None)
    jobs = [train, serve]
    if args.fg_burst:
        bursts = []
        for part in args.fg_burst.split(","):
            a, b = part.split(":")
            bursts.append((int(a), int(b)))
        jobs.append(ForegroundAppJob(bursts=bursts))
    return jobs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--serve-arch", default=None,
                    help="serving model (default: same as --arch)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ticks", type=int, default=40,
                    help="runtime quanta (one train step + one decode step "
                         "each); the loop also ends when every job is done")
    # training job
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--optimizer", default="sgd", choices=["sgd", "adam"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--attn-impl", default="auto",
                    choices=["auto", "naive", "chunked", "pallas"])
    # serving job
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=0,
                    help="requests in the stream (default: 3x slots)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=0)
    ap.add_argument("--kv-layout", default="contig",
                    choices=("contig", "paged"),
                    help="serving KV layout; 'paged' exercises the block "
                         "pool (prefix sharing, COW) and publishes pool_* "
                         "telemetry metrics")
    ap.add_argument("--slo-p99", type=float, default=0.0,
                    help="p99 per-token latency SLO in seconds (0 = none); "
                         "the arbiter sheds co-tenants while it is violated "
                         "and holds upgrades until it recovers")
    ap.add_argument("--admission-policy", default="serialize",
                    choices=["serialize", "shed"],
                    help="under KV-pool pressure: 'serialize' stalls the "
                         "queue behind the head, 'shed' rejects with a "
                         "retry-after hint (bounded queue)")
    # shared SoC
    ap.add_argument("--thermal-trace", default="0.2:0.25:3.0",
                    help="shared closed-loop thermal model "
                         "('heat:cool:slowdown[:trigger:release]'; die "
                         "temperature integrates the SUMMED job power draw); "
                         "'' disables")
    ap.add_argument("--interference-trace", default=None,
                    help="scripted co-tenant bursts instead of the thermal "
                         "model ('start:stop:slowdown,...')")
    ap.add_argument("--train-priority", type=float, default=1.0)
    ap.add_argument("--serve-priority", type=float, default=1.0,
                    help="higher priority = arbiter prefers downgrading the "
                         "other job first")
    ap.add_argument("--upgrade-patience", type=int, default=5)
    ap.add_argument("--battery-level", type=float, default=1.0,
                    help="battery fraction; with --battery-j this gates the "
                         "EnergyLoan (depleted budget forces low-power rungs)")
    ap.add_argument("--battery-j", type=float, default=0.0,
                    help="battery capacity in joules (0 disables the energy "
                         "budget); each tick borrows summed-power joules")
    ap.add_argument("--charging-trace", default=None,
                    help="charger plug schedule 'start:stop:watts,...'; "
                         "repays the energy loan while plugged so upgrades "
                         "come back")
    ap.add_argument("--day-ticks", type=int, default=0,
                    help="ticks per 'day'; at each boundary the energy loan "
                         "repays the daily charge surplus (0 disables)")
    ap.add_argument("--fg-burst", default=None,
                    help="foreground-app bursts 'start:stop,...'; while one "
                         "is active every preemptible job is paused "
                         "(training checkpoints + releases its state)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="inject a seeded chaos fault schedule (device "
                         "loss, pool pressure, torn checkpoints, spikes, "
                         "fg bursts) over the run")
    ap.add_argument("--timeline-out", default=None,
                    help="write the merged job-tagged timeline JSON here")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--telemetry-out", default=None,
                    help="directory for the repro.obs telemetry bundle: "
                         "per-tick metrics.jsonl, spans.jsonl, Perfetto "
                         "trace.json, arbiter audit.json")
    ap.add_argument("--log-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quiet", dest="verbose", action="store_false")
    args = ap.parse_args(argv)

    # enable telemetry before any job/engine is constructed so every span
    # source (engine, checkpoint manager, runtime) sees the live instance
    tel = obs.enable() if args.telemetry_out else None

    if args.interference_trace and args.thermal_trace:
        args.thermal_trace = ""  # explicit bursts replace the thermal model
    trace = None
    if args.interference_trace:
        trace = InterferenceTrace.parse(args.interference_trace)
    elif args.thermal_trace:
        trace = ThermalTrace.parse(args.thermal_trace)

    energy = None
    if args.battery_j > 0:
        energy = EnergyLoan(battery_j=args.battery_j, daily_charge_j=0.0,
                            daily_usage_j=0.0)
    charging = ChargingTrace.parse(args.charging_trace) \
        if args.charging_trace else None
    chaos = ChaosInjector.random(args.chaos_seed, args.ticks) \
        if args.chaos_seed is not None else None

    jobs = build_jobs(args)
    train, serve = jobs[0], jobs[1]
    rt = SwanRuntime(jobs, trace=trace, energy=energy,
                     battery_level=args.battery_level, charging=charging,
                     day_ticks=args.day_ticks or None, chaos=chaos,
                     verbose=args.verbose)
    res = rt.run(args.ticks)

    s = res.timeline.summary()
    print(f"[swan] {res.ticks} ticks, migrations: {s['n_migrations']} "
          f"(down {s['downgrades']}, up {s['upgrades']})")
    for name, job in res.jobs.items():
        migs = [m for m in res.timeline.migrations if m.job == name]
        print(f"[swan]   {name}: rung={job.active_rung.name} "
              f"work={res.work[name]:.0f} migrations={len(migs)}")
    tl = train.result()
    print(f"[swan] train: final loss {tl.losses[-1]:.4f} "
          f"(first {tl.losses[0]:.4f})" if tl.losses else "[swan] train: idle")
    done = serve.result()
    print(f"[swan] serve: {len(done)} finished, "
          f"{serve.engine.tokens_out} tokens, "
          f"occupancy {serve.engine.occupancy:.2f}")
    if serve.slo_p99_s is not None:
        print(f"[swan] serve SLO: {serve.slo_stats()}")
    if serve.engine.rejected:
        print(f"[swan] serve rejected: {len(serve.engine.rejected)} "
              f"(shed {serve.engine.shed_count}, "
              f"timeout {serve.engine.timeout_count})")
    if res.preemptions:
        print(f"[swan] foreground preemptions: {res.preemptions}")
    if chaos is not None:
        print(f"[swan] chaos: applied {sorted(chaos.applied)}; "
              f"{len(chaos.log)} log entries")
    if args.timeline_out:
        res.timeline.save(args.timeline_out)
        print(f"[swan] merged timeline -> {args.timeline_out}")
    if args.json_out:
        payload = obs.versioned({
            "summary": s, "work": res.work,
            "virtual_time_s": round(res.virtual_time_s, 6),
            "preemptions": res.preemptions,
            "per_job": {n: res.timeline.for_job(n).summary()
                        for n in res.timeline.jobs()}})
        if serve.slo_p99_s is not None:
            payload["slo"] = serve.slo_stats()
        payload["serve_stats"] = serve.engine.stats()
        if chaos is not None:
            payload["chaos"] = chaos.to_json()
        with open(args.json_out, "w") as f:
            json.dump(obs.encode_record(payload), f, indent=1)
    if tel is not None:
        tel.save(args.telemetry_out)
        print(f"[obs] telemetry bundle -> {args.telemetry_out} "
              f"({len(tel.tracer.spans())} spans, "
              f"{len(tel.audit)} audit records)")
    return res


if __name__ == "__main__":
    main()
