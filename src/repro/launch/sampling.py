"""Shared logits shaping for every sampling consumer.

One implementation of temperature/top-k masking feeds both the engine's
fallback sampler (``launch.steps.build_sampler``) and the speculative
verifier (``repro.spec.verify``). Rejection sampling is only
distribution-faithful if the accept test and the fallback sample agree on
the target distribution — keeping the masking here makes drift between the
two structurally impossible.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mask_logits(logits, temperature: float, top_k: int = 0):
    """(..., V) raw logits -> fp32 temperature-scaled, top-k-masked logits.

    ``top_k > 0`` masks everything below the k-th largest logit to -inf.
    Works on any leading batch shape — (B, V) engine rows and (B, S, V)
    speculative verify windows share the exact same shaping.
    """
    if temperature <= 0.0:
        raise ValueError("mask_logits needs temperature > 0; greedy "
                         "decoding never shapes logits")
    lg = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jax.lax.top_k(lg, top_k)[0][..., -1:]
        lg = jnp.where(lg < kth, -jnp.inf, lg)
    return lg


def sample_probs(logits, temperature: float, top_k: int = 0):
    """The sampling distribution implied by (temperature, top_k): softmax of
    the masked logits. This is the q(x) the rejection test accepts against
    and the distribution the faithfulness property test checks."""
    return jax.nn.softmax(mask_logits(logits, temperature, top_k), axis=-1)


def categorical(keys, logits, temperature: float, top_k: int = 0):
    """Sample one token per leading row. keys: (B, 2) uint32 per-row PRNG
    keys (the engine's fold_in(seed, uid, index) streams); logits: (B, V)."""
    lg = mask_logits(logits, temperature, top_k)
    return jax.vmap(jax.random.categorical)(keys, lg).astype(jnp.int32)
