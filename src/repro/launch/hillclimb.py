import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Swan-planner perf search over TPU execution choices (EXPERIMENTS.md §Perf).

This IS the paper's technique applied to the pod: each candidate MeshChoice
(microbatch x remat x chunk x compression) is *explored* via an AOT profile
(lower+compile -> roofline terms), choices are *pruned* under the Swan cost
order, and the fastest feasible survivor is *selected*. The log records every
hypothesis -> measurement pair.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb --arch llama3.2-1b \
      --shape train_4k --out reports/hillclimb_llama.json
"""
import argparse
import dataclasses
import json
import time

from repro.configs import SHAPES
from repro.core.choices import MeshChoice
from repro.core.cost import ChoiceProfile, ladder, pick_fastest
from repro.launch.dryrun import default_choice, lower_cell

HBM = 16 * 2 ** 30


def profile_choice(arch, shape, choice):
    # a choice that fails to lower (e.g. attn_impl=pallas on a backend whose
    # AOT path can't take Mosaic/interpret callbacks) is *explored and
    # rejected*, Swan-style — it must not kill the search
    try:
        rec = lower_cell(arch, shape, choice=choice, verbose=False)
    except Exception as e:
        rec = {"status": "error", "error": f"{type(e).__name__}: {e}",
               "choice": choice.name}
    if rec["status"] != "ok":
        return None, rec
    prof = ChoiceProfile(
        choice=choice, latency_s=rec["latency_s"],
        energy_j=rec["latency_s"] * 220 * choice.n_chips,
        power_w=220 * choice.n_chips, cost_key=choice.cost_key(),
        memory_bytes=rec["per_device_bytes"], meta=rec)
    return prof, rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--out", default=None)
    ap.add_argument("--grid", default=None,
                    help="semicolon-separated overrides, e.g. 'mb=4,remat=dots;mb=8'")
    args = ap.parse_args()

    base = default_choice(args.arch, args.shape, False)
    candidates = [("baseline", base)]
    if args.grid:
        for spec in args.grid.split(";"):
            over = {}
            for kv in spec.split(","):
                k, v = kv.split("=")
                k = {"mb": "microbatch", "attn": "attn_impl"}.get(k, k)
                over[k] = int(v) if v.isdigit() else v
            candidates.append((spec, dataclasses.replace(base, **over)))
    else:
        for mb in {1, max(1, base.microbatch // 2), base.microbatch,
                   base.microbatch * 2}:
            for remat in ("full", "dots"):
                if (mb, remat) != (base.microbatch, base.remat):
                    candidates.append(
                        (f"mb{mb},{remat}",
                         dataclasses.replace(base, microbatch=mb, remat=remat)))
        # kernel dimension of the choice space: the fused Pallas flash
        # attention vs the jnp chunked fallback, at the baseline (mb, remat)
        candidates.append(("attn=pallas",
                           dataclasses.replace(base, attn_impl="pallas")))

    log = []
    profiles = []
    for name, choice in candidates:
        t0 = time.time()
        prof, rec = profile_choice(args.arch, args.shape, choice)
        entry = {"candidate": name, "choice": choice.name, "wall_s": round(time.time() - t0, 1)}
        if prof is None:
            entry["status"] = rec.get("status")
        else:
            entry.update(status="ok", latency_s=rec["latency_s"],
                         compute_s=rec["compute_s"], memory_s=rec["memory_s"],
                         collective_s=rec["collective_s"], dominant=rec["dominant"],
                         gb=rec["per_device_gb"], fits=rec["fits_hbm"],
                         roofline_fraction=rec["roofline_fraction"])
            profiles.append(prof)
        log.append(entry)
        print(json.dumps(entry))

    lad = ladder(profiles)
    best = pick_fastest(profiles, memory_limit=HBM)
    summary = {"arch": args.arch, "shape": args.shape,
               "ladder": [p.name for p in lad],
               "selected": best.name,
               "selected_latency_s": best.latency_s,
               "selected_roofline": best.meta["roofline_fraction"]}
    print(json.dumps(summary))
    if args.out:
        os.makedirs(os.path.dirname(os.path.abspath(args.out)) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump({"log": log, "summary": summary}, f, indent=1)


if __name__ == "__main__":
    main()
