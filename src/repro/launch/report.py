"""Inject the roofline table + bottleneck advice into EXPERIMENTS.md.

Usage: PYTHONPATH=src python -m repro.launch.report reports/dryrun.json
"""
from __future__ import annotations

import json
import sys

from repro.launch.roofline import one_liners, render

MARK = "<!-- ROOFLINE_TABLE -->"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun.json"
    with open(path) as f:
        reports = json.load(f)
    table = render(reports, None)
    advice = one_liners([r for r in reports if r["mesh"] == "16x16"])
    ok = sum(1 for r in reports if r["status"] == "ok")
    skipped = sum(1 for r in reports if r["status"] == "skipped")
    failed = sum(1 for r in reports if r["status"] == "FAILED")
    block = (f"{MARK}\n\n{ok} cells compiled, {skipped} skipped per spec, "
             f"{failed} failed.\n\n{table}\n\n### Dominant-term advice "
             f"(single-pod)\n\n{advice}\n")
    with open("EXPERIMENTS.md") as f:
        doc = f.read()
    pre = doc.split(MARK)[0]
    post = doc.split("## §Perf")[1] if "## §Perf" in doc else ""
    with open("EXPERIMENTS.md", "w") as f:
        f.write(pre + block + "\n## §Perf" + post)
    print(f"injected table: {ok} ok / {skipped} skipped / {failed} failed")


if __name__ == "__main__":
    main()
