"""Train/serve step builders — the functions the dry-run lowers and the
examples execute.

``build_train_step`` composes: microbatched gradient accumulation (lax.scan),
the model's remat policy (inside build_model), optional gradient compression
with error feedback (cross-pod reduce), and the optimizer. All sharding comes
from the logical-axis rules installed by the active MeshChoice.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.choices import MeshChoice
from repro.models.registry import Model
from repro.models.sharding import shard
from repro.optim.compression import Compressor
from repro.optim.optimizers import Optimizer, apply_updates


def build_train_step(model: Model, optimizer: Optimizer, *, microbatch: int = 1,
                     lr: float = 0.05, compressor: Optional[Compressor] = None):
    """Returns f(state, batch) -> (state, metrics). state = {params, opt, err, step}."""
    comp = compressor or Compressor("none")

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(state, batch):
        params = state["params"]

        if microbatch == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def slice_mb(x):
                b = x.shape[0]
                return x.reshape((microbatch, b // microbatch) + x.shape[1:])

            mbs = jax.tree_util.tree_map(slice_mb, batch)

            def acc_body(carry, mb):
                loss_acc, grad_acc = carry
                mb = jax.tree_util.tree_map(
                    lambda x: shard(x, "batch", *([None] * (x.ndim - 1))), mb)
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grad_acc = jax.tree_util.tree_map(jnp.add, grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zero = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.zeros((), jnp.float32), zero), mbs)
            loss = loss / microbatch
            grads = jax.tree_util.tree_map(lambda g: g / microbatch, grads)

        err = state.get("err", ())
        if comp.scheme != "none":
            grads, err = comp.roundtrip(grads, err)

        updates, opt_state = optimizer.update(grads, state["opt"], params, lr)
        params = apply_updates(params, updates)
        new_state = {"params": params, "opt": opt_state, "err": err,
                     "step": state["step"] + 1}
        gnorm = jax.tree_util.tree_reduce(
            lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
        return new_state, {"loss": loss, "grad_norm": jnp.sqrt(gnorm)}

    return train_step


def init_train_state(model: Model, optimizer: Optimizer, key,
                     compressor: Optional[Compressor] = None):
    params = model.init(key)
    comp = compressor or Compressor("none")
    return {"params": params, "opt": optimizer.init(params),
            "err": comp.init_error(params) if comp.scheme != "none" else (),
            "step": jnp.zeros((), jnp.int32)}


def build_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def build_decode_step(model: Model, *, jit: bool = True, donate: bool = True,
                      greedy: bool = True):
    """One-token decode step.

    Jitted with the KV cache donated (``donate_argnums``): the per-token
    update writes the cache buffers in place instead of copying the whole
    (L, B, Smax, ...) allocation every generated token — the difference
    between O(1) and O(cache) memory traffic per step. Callers must treat
    the passed-in cache as consumed and keep only the returned one.
    ``cache_len`` may be a scalar (lockstep) or (B,) vector (continuous
    batching with ragged per-sequence lengths). ``greedy=False`` skips the
    argmax (its slot in the return triple is None) for callers that sample
    from the logits instead — no point computing and transferring a
    full-vocab argmax that is always discarded.
    """
    def decode_step(params, cache, tokens, cache_len):
        logits, new_cache = model.decode_step(params, cache, tokens, cache_len)
        # greedy next token (serving semantics)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None] \
            if greedy else None
        return next_tok, logits, new_cache

    if not jit:
        return decode_step
    return jax.jit(decode_step, donate_argnums=(1,) if donate else ())


def build_paged_decode_step(model: Model, *, jit: bool = True,
                            donate: bool = True, greedy: bool = True):
    """One-token decode step over a paged KV cache.

    Same contract as :func:`build_decode_step` (pools donated, per-token
    update in place, ``greedy=False`` skips the argmax) with one extra
    argument: the (B, T) int32 block table routing each sequence's virtual
    cache positions to physical pool blocks. The table shape is fixed by
    the engine, so a single compile serves every mix of resident sequences.
    """
    if model.paged_decode_step is None:
        raise ValueError(f"family {model.cfg.family!r} has no paged decode path")

    def decode_step(params, cache, tokens, cache_len, block_table):
        logits, new_cache = model.paged_decode_step(params, cache, tokens,
                                                    cache_len, block_table)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None] \
            if greedy else None
        return next_tok, logits, new_cache

    if not jit:
        return decode_step
    return jax.jit(decode_step, donate_argnums=(1,) if donate else ())


def build_paged_prefill_step(model: Model, *, write: bool = True,
                             jit: bool = True):
    """One block-sized chunk of paged prefill, writing prompt KV straight
    into the pool blocks the chunk's block-table column names.

    ``start`` and ``last_pos`` are traced scalars, so one compile serves
    every chunk index and every true-last-token position — the chunked
    prefill loop never grows the jit cache the way per-length contiguous
    prefill does. ``write=True`` donates the pools (in-place ingestion);
    ``write=False`` is the read-only full-prefix-hit recompute and leaves
    the pools untouched (not donated — the engine keeps using them).
    """
    if model.paged_prefill_step is None:
        raise ValueError(f"family {model.cfg.family!r} has no paged "
                         f"prefill path")

    def prefill_chunk(params, cache, tokens, start, block_table, last_pos):
        return model.paged_prefill_step(params, cache, tokens, start,
                                        block_table, last_pos, write)

    if not jit:
        return prefill_chunk
    return jax.jit(prefill_chunk, donate_argnums=(1,) if write else ())


def build_sampler(temperature: float, top_k: int = 0, *, jit: bool = True):
    """Returns f(logits (B, V), keys (B, 2) uint32) -> (B,) sampled int32 ids.

    Temperature scales the logits; ``top_k > 0`` masks everything below the
    k-th logit before sampling — both via the shared masking in
    :mod:`repro.launch.sampling`, which the speculative verifier also uses
    (accept-test and fallback-sample distributions cannot drift). Keys are
    per-sequence PRNG keys (one row per slot) so sampling stays independent
    of batch composition — the serve engine derives them per request uid and
    generation index, which makes a request's sampled stream identical
    however it was batched.
    """
    from repro.launch.sampling import categorical

    if temperature <= 0.0:
        raise ValueError("build_sampler needs temperature > 0; greedy "
                         "decoding is the decode step's argmax")

    def sample(logits, keys):
        return categorical(keys, logits, temperature, top_k)

    return jax.jit(sample) if jit else sample


def build_spec_decode_step(model: Model, *, jit: bool = True,
                           donate: bool = True):
    """Speculative verify step: score a (B, S) draft window in one pass.

    Same donation contract as :func:`build_decode_step`. Returns
    (logits (B, S, V), new_cache): row qi of the logits is the target
    model's next-token distribution after window position qi, which is what
    both greedy verification (argmax chain) and rejection sampling consume.
    No argmax is fused here — accept/rollback in :mod:`repro.spec.verify`
    needs the full rows either way.
    """
    if model.spec_decode_step is None:
        raise ValueError(f"family {model.cfg.family!r} has no speculative "
                         f"decode path")

    def spec_step(params, cache, tokens, cache_len):
        return model.spec_decode_step(params, cache, tokens, cache_len)

    if not jit:
        return spec_step
    return jax.jit(spec_step, donate_argnums=(1,) if donate else ())


def build_paged_spec_decode_step(model: Model, *, jit: bool = True,
                                 donate: bool = True):
    """Speculative verify step over a paged KV cache (block-table routed)."""
    if model.paged_spec_decode_step is None:
        raise ValueError(f"family {model.cfg.family!r} has no paged "
                         f"speculative decode path")

    def spec_step(params, cache, tokens, cache_len, block_table):
        return model.paged_spec_decode_step(params, cache, tokens, cache_len,
                                            block_table)

    if not jit:
        return spec_step
    return jax.jit(spec_step, donate_argnums=(1,) if donate else ())


def greedy_decode_tokens(model: Model, params, tokens, *, steps: int,
                         max_len: int, cache_dtype=jnp.float32):
    """Greedy-decode ``steps`` tokens from ``tokens`` (B,1) with a fresh
    cache; returns the (B, steps) numpy array of sampled ids.

    Shared oracle for the decode parity gates: the pallas-vs-naive
    token-identical checks in tests/test_decode_consistency.py and
    benchmarks/decode_bench.py both call this so the two gates cannot drift.
    """
    import numpy as np
    cache = model.init_cache(tokens.shape[0], max_len, cache_dtype)
    t, out = tokens, []
    for i in range(steps):
        logits, cache = model.decode_step(params, cache, t, jnp.int32(i))
        t = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
        out.append(np.asarray(t))
    return np.concatenate(out, 1)


def cast_params(params, dtype):
    return jax.tree_util.tree_map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
