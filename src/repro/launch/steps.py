"""Train/serve step builders — the functions the dry-run lowers and the
examples execute.

``build_train_step`` composes: microbatched gradient accumulation (lax.scan),
the model's remat policy (inside build_model), optional gradient compression
with error feedback (cross-pod reduce), and the optimizer. All sharding comes
from the logical-axis rules installed by the active MeshChoice.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.choices import MeshChoice
from repro.models.registry import Model
from repro.models.sharding import shard
from repro.optim.compression import Compressor
from repro.optim.optimizers import Optimizer, apply_updates


def build_train_step(model: Model, optimizer: Optimizer, *, microbatch: int = 1,
                     lr: float = 0.05, compressor: Optional[Compressor] = None):
    """Returns f(state, batch) -> (state, metrics). state = {params, opt, err, step}."""
    comp = compressor or Compressor("none")

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(state, batch):
        params = state["params"]

        if microbatch == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def slice_mb(x):
                b = x.shape[0]
                return x.reshape((microbatch, b // microbatch) + x.shape[1:])

            mbs = jax.tree_util.tree_map(slice_mb, batch)

            def acc_body(carry, mb):
                loss_acc, grad_acc = carry
                mb = jax.tree_util.tree_map(
                    lambda x: shard(x, "batch", *([None] * (x.ndim - 1))), mb)
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grad_acc = jax.tree_util.tree_map(jnp.add, grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zero = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.zeros((), jnp.float32), zero), mbs)
            loss = loss / microbatch
            grads = jax.tree_util.tree_map(lambda g: g / microbatch, grads)

        err = state.get("err", ())
        if comp.scheme != "none":
            grads, err = comp.roundtrip(grads, err)

        updates, opt_state = optimizer.update(grads, state["opt"], params, lr)
        params = apply_updates(params, updates)
        new_state = {"params": params, "opt": opt_state, "err": err,
                     "step": state["step"] + 1}
        gnorm = jax.tree_util.tree_reduce(
            lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), grads, 0.0)
        return new_state, {"loss": loss, "grad_norm": jnp.sqrt(gnorm)}

    return train_step


def init_train_state(model: Model, optimizer: Optimizer, key,
                     compressor: Optional[Compressor] = None):
    params = model.init(key)
    comp = compressor or Compressor("none")
    return {"params": params, "opt": optimizer.init(params),
            "err": comp.init_error(params) if comp.scheme != "none" else (),
            "step": jnp.zeros((), jnp.int32)}


def build_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.prefill(params, batch)

    return prefill_step


def build_decode_step(model: Model, *, jit: bool = True, donate: bool = True):
    """Greedy one-token decode step.

    Jitted with the KV cache donated (``donate_argnums``): the per-token
    update writes the cache buffers in place instead of copying the whole
    (L, B, Smax, ...) allocation every generated token — the difference
    between O(1) and O(cache) memory traffic per step. Callers must treat
    the passed-in cache as consumed and keep only the returned one.
    ``cache_len`` may be a scalar (lockstep) or (B,) vector (continuous
    batching with ragged per-sequence lengths).
    """
    def decode_step(params, cache, tokens, cache_len):
        logits, new_cache = model.decode_step(params, cache, tokens, cache_len)
        # greedy next token (serving semantics)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        return next_tok, logits, new_cache

    if not jit:
        return decode_step
    return jax.jit(decode_step, donate_argnums=(1,) if donate else ())


def greedy_decode_tokens(model: Model, params, tokens, *, steps: int,
                         max_len: int, cache_dtype=jnp.float32):
    """Greedy-decode ``steps`` tokens from ``tokens`` (B,1) with a fresh
    cache; returns the (B, steps) numpy array of sampled ids.

    Shared oracle for the decode parity gates: the pallas-vs-naive
    token-identical checks in tests/test_decode_consistency.py and
    benchmarks/decode_bench.py both call this so the two gates cannot drift.
    """
    import numpy as np
    cache = model.init_cache(tokens.shape[0], max_len, cache_dtype)
    t, out = tokens, []
    for i in range(steps):
        logits, cache = model.decode_step(params, cache, t, jnp.int32(i))
        t = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
        out.append(np.asarray(t))
    return np.concatenate(out, 1)


def cast_params(params, dtype):
    return jax.tree_util.tree_map(
        lambda p: p.astype(dtype) if jnp.issubdtype(p.dtype, jnp.floating) else p, params)
