"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

``input_specs`` returns weak-type-correct, shardable SDS trees with NO device
allocation — the dry-run lowers against these. Modality frontends are stubs
per the assignment: audio/image embeddings appear as precomputed inputs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models.sharding import axis_rules, mesh_safe_specs, resolve


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: InputShape, *, dtype=jnp.bfloat16) -> Dict[str, Any]:
    """SDS tree for the data batch of a cell (train/prefill modes)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "cnn":
        return {"images": _sds((B, cfg.image_size, cfg.image_size, cfg.in_channels), dtype),
                "labels": _sds((B,), jnp.int32)}
    batch = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embed"] = _sds((B, cfg.n_image_tokens, cfg.d_model), dtype)
    if cfg.family == "encdec":
        batch["audio_embed"] = _sds((B, cfg.n_audio_frames, cfg.d_model), dtype)
    return batch


def decode_specs(model, cfg: ModelConfig, shape: InputShape,
                 *, cache_dtype=jnp.bfloat16) -> Tuple[Dict[str, Any], Any]:
    """(inputs, cache) SDS for a decode cell: one new token against a
    seq_len-deep cache."""
    B, S = shape.global_batch, shape.seq_len
    tokens = _sds((B, 1), jnp.int32)
    cache = jax.eval_shape(lambda: model.init_cache(B, S, cache_dtype))
    inputs = {"tokens": tokens, "cache_len": _sds((), jnp.int32)}
    return inputs, cache


# ---------------------------------------------------------------------------
# sharding assignment
# ---------------------------------------------------------------------------


def _shardable(spec: P, shape, mesh) -> P:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    names = set(mesh.axis_names)
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    fixed = []
    for dim, e in zip(shape, entries):
        if e is None:
            fixed.append(None)
            continue
        axes = (e,) if isinstance(e, str) else tuple(a for a in e)
        axes = tuple(a for a in axes if a in names)
        ext = 1
        for a in axes:
            ext *= sizes[a]
        if not axes or ext == 1 or dim % ext != 0:
            fixed.append(None)
        else:
            fixed.append(axes[0] if len(axes) == 1 else axes)
    return P(*fixed)


def batch_shardings(batch_sds, mesh, rules: dict):
    """NamedShardings for a data batch: leading dim over the batch axes."""
    with axis_rules(rules):
        def one(sds):
            spec = resolve("batch", *([None] * (len(sds.shape) - 1)))
            return NamedSharding(mesh, _shardable(spec, sds.shape, mesh))

        return jax.tree_util.tree_map(one, batch_sds)


_CACHE_AXES = {
    "k": ("layer", "batch", "kvseq", None, None),
    "v": ("layer", "batch", "kvseq", None, None),
    "latent": ("layer", "batch", "kvseq", None),
    "k_rope": ("layer", "batch", "kvseq", None),
    "h": ("layer", "batch", "tp", None, None),      # mamba ssm state (heads)
    "conv": ("layer", "batch", None, None),
    "S": ("layer", "batch", "tp", None, None),      # rwkv wkv state (heads)
    "prev_t": ("layer", "batch", None),
    "prev_c": ("layer", "batch", None),
}


def cache_shardings(cache_sds, mesh, rules: dict):
    with axis_rules(rules):
        flat = jax.tree_util.tree_flatten_with_path(cache_sds)[0]
        leaves = []
        for kp, sds in flat:
            key = str(getattr(kp[-1], "key", kp[-1]))
            logical = _CACHE_AXES.get(key, ("layer", "batch"))
            logical = tuple(None if a == "layer" else a for a in logical)
            spec = resolve(*logical[:len(sds.shape)])
            leaves.append(NamedSharding(mesh, _shardable(spec, sds.shape, mesh)))
        treedef = jax.tree_util.tree_structure(cache_sds)
        return jax.tree_util.tree_unflatten(treedef, leaves)


def param_shardings(params_sds, mesh, rules: dict):
    with axis_rules(rules):
        specs = mesh_safe_specs(params_sds, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))


def replicated(mesh):
    return NamedSharding(mesh, P())
