"""Serving driver: batched prefill + decode with a KV cache (CPU-scale demo).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import synthetic_lm_batch
from repro.launch.steps import build_decode_step
from repro.models.registry import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "cnn":
        raise SystemExit("CNN archs have no decode path")
    model = build_model(cfg, impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = synthetic_lm_batch(rng, args.batch, args.prompt_len, cfg.vocab_size)
    if cfg.family == "vlm":
        batch["image_embed"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_image_tokens, cfg.d_model)),
            jnp.float32) * 0.02
    if cfg.family == "encdec":
        batch["audio_embed"] = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_audio_frames, cfg.d_model)),
            jnp.float32) * 0.02

    max_len = args.prompt_len + args.gen
    t0 = time.time()
    if cfg.family == "encdec":
        # encoder once, then pure decode (prompt = BOS only)
        from repro.models import encdec as E
        cache = model.init_cache(args.batch, max_len, jnp.float32)
        enc_h = E.encode(params, cfg, jnp.asarray(batch["audio_embed"]))
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params["dec_layers"])
            hd = cfg.head_dim
            B, Senc = enc_h.shape[:2]
            ks.append((enc_h @ lp["cross_attn"]["wk"]).reshape(B, Senc, cfg.n_kv_heads, hd))
            vs.append((enc_h @ lp["cross_attn"]["wv"]).reshape(B, Senc, cfg.n_kv_heads, hd))
        cache["cross"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
        tokens = jnp.zeros((args.batch, 1), jnp.int32)
        pos0 = 0
    else:
        logits, pcache = model.prefill(params, {k: jnp.asarray(v) for k, v in batch.items()})
        cache = model.init_cache(args.batch, max_len, jnp.float32)
        # copy prefill caches into the decode buffers
        def splice(buf, pc):
            if buf.ndim >= 3 and pc.shape[2] == args.prompt_len and buf.shape[1] == args.batch:
                return buf.at[:, :, :args.prompt_len].set(pc.astype(buf.dtype))
            return pc.astype(buf.dtype) if pc.shape == buf.shape else buf
        if cfg.family in ("ssm", "hybrid"):
            cache = jax.tree_util.tree_map(lambda b, p: p.astype(b.dtype), cache, pcache)
        else:
            cache = jax.tree_util.tree_map(splice, cache, pcache)
        tokens = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
        pos0 = args.prompt_len
    t_prefill = time.time() - t0

    step = jax.jit(build_decode_step(model))
    out_tokens = [tokens]
    t0 = time.time()
    for t in range(args.gen - 1):
        tokens, logits, cache = step(params, cache, tokens, jnp.int32(pos0 + t))
        out_tokens.append(tokens)
    gen = jnp.concatenate(out_tokens, axis=1)
    t_decode = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prefill {t_prefill*1e3:.0f}ms "
          f"decode {args.gen - 1} steps in {t_decode*1e3:.0f}ms "
          f"({(args.gen - 1) * args.batch / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample:", np.asarray(gen[0])[:12])
    return gen


if __name__ == "__main__":
    main()
