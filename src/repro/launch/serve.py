"""Serving driver: continuous batching over a slotted KV cache.

The engine serves a *stream* of requests rather than one lockstep batch:
each of ``max_batch`` cache slots carries its own ``cache_len``, finished
sequences (EOS or length budget) retire immediately, and queued requests are
admitted into freed slots mid-stream — throughput is measured under the
ragged traffic a real endpoint sees, which is where Swan's pick-the-config-
that-fits-the-hardware argument bites for decode (KV-bandwidth-bound).

Mechanics per decode step:
  - one jitted decode over all slots with a per-slot (B,) cache_len vector;
    the cache is donated (``build_decode_step``) so the per-token update is
    in place, never a full-cache copy;
  - admission runs single-request prefill and splices the (L, 1, P, ...)
    prefill cache into the slot with one donated dynamic_update_slice;
  - idle slots decode garbage that is masked out on the host — their
    frozen cache_len keeps the math well-defined and their KV tiles are
    skipped by the Pallas decode kernel's length-clamped index maps.

KV layouts (``--kv-layout``):
  - ``contig`` reserves a (max_batch, max_seq) slab per layer — every slot
    pays for a full-length cache whether or not it uses it;
  - ``paged`` stores KV in fixed-size blocks from a shared pool
    (``repro.paging``): admission allocates just the blocks the prompt
    needs and ingests the prompt with *chunked paged prefill* — block-sized
    chunks written straight into pool blocks, no contiguous (1, P, ...)
    prefill cache, one compile for every prompt length — decode allocates
    on block boundaries, and retirement returns blocks to the pool — peak
    KV memory tracks *live tokens*, not slots x max_seq. The decode step
    routes each sequence through its (B, T) block table (scalar-prefetched
    by the paged flash-decode kernel); only table rows that changed since
    the last step are re-shipped to the device.

Prefix sharing (paged; on by default, ``--no-prefix-cache`` disables):
admission hash-conses prompt-prefix blocks — a request whose prompt prefix
was already prefilled maps the *same physical blocks* via pool refcounts
and skips the prefill compute for every hit chunk (a full-prompt hit runs
one read-only chunk just to recompute the last token's logits). Divergence
is copy-on-write: the first decode append into a shared block allocates a
private copy and device-copies the donor block. Retired prompts' blocks
park on a cached-free LRU tier — still allocatable, but a later identical
prefix resurrects them for free.

Host swap tier (``--admission-policy swap``): under pool pressure, cold
resident sequences' blocks are copied to host memory and freed instead of
serializing or shedding admission (LRU by last swap-in/admit step, with a
grace period as second chance); swapped sequences restore — bitwise — into
fresh blocks when headroom returns, with priority over new admissions.
``hold_blocks()`` co-tenant pressure can likewise force residents out to
host rather than starving admission.

Sampling: greedy by default; ``--temperature/--top-k`` switch the emitted
stream to seeded sampling with a per-request PRNG key (a request's stream
is independent of how it was batched). Parity gates keep using greedy.

Speculative decoding (``--draft-depth k``, ``--draft-source``): a draft
source (``repro.spec.draft``) proposes k cheap tokens per request, one
multi-token verify pass (the flash-decode kernel grown to a q-block)
scores the whole window, and the engine emits the accepted prefix plus
one non-draft token per round. Greedy mode is token-identical to
non-speculative decoding; sampled mode is distribution-faithful rejection
sampling on the same fold_in(seed, uid, index) streams. Rollback is pure
cache_len bookkeeping — rejected positions keep stale KV, masked dead by
the ragged-length kernels and overwritten next round. Draft depth is a
serving rung (``engine.jobs.ServeRung.draft_depth``): the arbiter walks
speculation down before capping slots when thermals bite.

``--bucket-prompts`` rounds admission prefill lengths up to power-of-two
buckets so the prefill jit cache stops growing per unique prompt length.

``--attn-impl pallas`` routes decode attention through the fused
single-query flash-decode kernel (kernels/flash_attention.flash_decode /
flash_decode_paged); ``auto`` consults kernels/backend.auto_decode_impl
(cache length x backend).

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --reduced \
      --batch 4 --requests 12 --prompt-len 32 --gen 16 [--kv-layout paged]
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import functools
import json
import time
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.kernels.backend import auto_decode_impl
from repro.launch.steps import (build_decode_step, build_paged_decode_step,
                                build_paged_prefill_step, build_sampler,
                                build_paged_spec_decode_step,
                                build_spec_decode_step)
from repro.spec.verify import greedy_verify, rejection_verify
from repro.models.registry import build_model
from repro.paging import BlockPoolExhausted, PagedKVCache

# families whose decode state is a slotted (L, B, Smax, ...) KV cache the
# engine knows how to splice; SSM/hybrid state and encoder-decoder cross
# caches stay on the legacy lockstep path below
ENGINE_FAMILIES = ("dense", "moe")


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32 prompt tokens
    max_new_tokens: int
    # graceful degradation: a queued request that has not been admitted
    # within deadline_steps engine steps of submission is dropped with a
    # "timeout" rejection instead of waiting forever (None = patient)
    deadline_steps: Optional[int] = None
    submitted_at: int = -1  # engine decode_steps at submit(); set by submit


@dataclasses.dataclass
class Finished:
    uid: int
    tokens: List[int]  # generated token ids (first comes from prefill logits)
    reason: str  # "eos" | "length"
    prompt_len: int


@dataclasses.dataclass
class Rejected:
    """A request the engine declined instead of serving: load shedding under
    pool pressure, a queued-deadline timeout, or a drain. ``retry_after`` is
    the engine's estimate (in decode steps) of when resubmission could
    succeed — the serving analogue of an HTTP 503 Retry-After."""
    uid: int
    reason: str  # "shed" | "timeout" | "draining"
    retry_after: int


@dataclasses.dataclass
class SwappedSeq:
    """A mid-stream sequence whose KV blocks were evicted to host memory.

    Everything needed to resume exactly where it left off: the host copy of
    its blocks (logical order), the slot bookkeeping, and its worst-case
    block reservation. Restore is bitwise — the device -> host -> device
    round trip does not touch the values — so a swapped sequence's stream
    is token-identical to one that was never swapped."""
    uid: int
    generated: List[int]
    cache_len: int
    budget: int
    next_token: int
    host_kv: object  # numpy tree, leaves (L, n_blocks, block_size, ...)
    n_blocks: int
    worst: int  # worst-case block reservation to restore
    swapped_at: int  # engine decode_steps at swap-out (FIFO restore order)


class ContinuousBatchingEngine:
    """Slot-based continuous batching over a model's KV-cache decode path."""

    def __init__(self, model, params, *, max_batch: int, max_seq: int,
                 eos_id: Optional[int] = None, cache_dtype=jnp.float32,
                 kv_layout: str = "contig", block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0, bucket_prompts: bool = False,
                 admission_policy: str = "serialize",
                 max_queue: Optional[int] = None,
                 prefix_cache: bool = True, swap_grace: int = 2,
                 draft_depth: int = 0, draft_source=None):
        cfg = model.cfg
        if cfg.family not in ENGINE_FAMILIES:
            raise ValueError(
                f"continuous batching needs a slotted KV cache; family "
                f"{cfg.family!r} is served by the legacy lockstep path")
        if kv_layout not in ("contig", "paged"):
            raise ValueError(f"kv_layout must be contig|paged, got {kv_layout!r}")
        if admission_policy not in ("serialize", "shed", "swap"):
            raise ValueError(f"admission_policy must be serialize|shed|swap, "
                             f"got {admission_policy!r}")
        if admission_policy == "swap" and kv_layout != "paged":
            raise ValueError("admission_policy='swap' needs the paged layout "
                             "(there are no blocks to evict under contig)")
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.kv_layout = kv_layout
        self.block_size = block_size
        self.bucket_prompts = bucket_prompts
        # serving-rung knobs (engine.jobs.ServeJob migrates these live);
        # the as-built settings are what a None override restores
        self.slot_cap: Optional[int] = None
        self._base_model = model
        self._base_cache_dtype = jnp.dtype(cache_dtype)

        self.cache_len = np.zeros(max_batch, np.int32)
        self.tokens = np.zeros((max_batch, 1), np.int32)
        self.slot_uid: List[Optional[int]] = [None] * max_batch
        self.slot_budget = np.zeros(max_batch, np.int32)
        self.generated: List[List[int]] = [[] for _ in range(max_batch)]

        self.queue: Deque[Request] = collections.deque()
        self.finished: Dict[int, Finished] = {}
        # graceful degradation under overload (see _admit_waiting):
        #   "serialize" — head-of-line request waits for resources (the old
        #     implicit behavior: unbounded queueing, no request is refused);
        #   "shed" — a request that cannot get resources *now* is rejected
        #     with a retry-after hint, so admitted requests keep their
        #     latency instead of everyone missing deadlines together.
        self.admission_policy = admission_policy
        # under "shed" the waiting queue is bounded: a submission past the
        # bound is rejected up front with retry-after rather than parked on
        # an unbounded queue it may never leave. "serialize" queues without
        # limit (the implicit legacy behavior).
        if max_queue is None and admission_policy == "shed":
            max_queue = 2 * max_batch
        self.max_queue = max_queue
        self.accepting = True  # drain() flips this; submit() then rejects
        self.rejected: Dict[int, Rejected] = {}
        self.shed_count = 0
        self.timeout_count = 0
        self._held_blocks = 0  # pool blocks held by an external co-tenant
        self._hold_seq = 0
        self.decode_steps = 0
        self.tokens_out = 0
        self._active_slot_steps = 0
        self._uid_prompt_len: Dict[int, int] = {}
        self.prefill_lengths: Dict[int, int] = {}  # padded length -> count
        # prefix sharing / chunked prefill / swap / dirty-row accounting
        self.prefill_chunks = 0          # chunk-prefill kernel invocations
        self.prefill_chunks_skipped = 0  # prompt chunks skipped via prefix hit
        self.cow_copies = 0              # copy-on-write device block copies
        self.table_rows_shipped = 0      # dirty block-table rows sent to device
        self.table_uploads = 0           # full-table uploads (bulk dirt)
        self.swapped: Dict[int, SwappedSeq] = {}  # uid -> parked sequence
        self.swap_outs = 0
        self.swap_ins = 0
        self.swap_grace = max(0, int(swap_grace))
        # per-slot step of last admit/swap-in: LRU victim choice + grace
        self._resident_since = np.zeros(max_batch, np.int64)
        self.admission_waits: Dict[int, int] = {}  # uid -> steps queued
        self._stalled_steps = 0

        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self._sampler = None
        if self.temperature > 0.0:
            self._sampler = build_sampler(self.temperature, self.top_k)
            base = jax.random.PRNGKey(sample_seed)
            # one jitted dispatch per step for the whole batch of keys, not a
            # host-side fold_in pair per slot
            self._keys = jax.jit(jax.vmap(
                lambda u, i: jax.random.fold_in(jax.random.fold_in(base, u), i)))
            # (B, S) key grid for speculative verify: same fold_in(seed,
            # uid, index) streams, one key per candidate emission index, so
            # a request's randomness stays batch-composition independent
            self._keys2 = jax.jit(jax.vmap(jax.vmap(
                lambda u, i: jax.random.fold_in(jax.random.fold_in(base, u), i))))
            self._rej_verify = jax.jit(functools.partial(
                rejection_verify, temperature=self.temperature,
                top_k=self.top_k))
        self._greedy_verify = jax.jit(greedy_verify)

        # speculative decoding: a draft source proposes k tokens per slot,
        # one multi-token verify pass scores the whole window, the engine
        # emits the accepted prefix + 1. Depth is a serving rung
        # (engine.jobs.ServeRung.draft_depth) the arbiter can walk down.
        self._base_draft_depth = max(0, int(draft_depth))
        self.draft_depth = self._base_draft_depth
        self.draft = draft_source
        if self.draft is None and self.draft_depth > 0:
            from repro.spec.draft import NGramDraft
            self.draft = NGramDraft()
        self.spec_rounds = 0
        self.spec_drafted = 0
        self.spec_accepted = 0

        self._prefill = jax.jit(model.prefill)  # one compile per prompt length

        if kv_layout == "paged":
            # virtual capacity per sequence: T blocks; defaults provision the
            # contiguous equivalent so paged-vs-contig is a layout change, not
            # a capacity change — benchmarks report *peak blocks in use*
            blocks_per_seq = -(-max_seq // block_size)
            if num_blocks is None:
                num_blocks = max_batch * blocks_per_seq + 1  # +1 null block
            self.kv = PagedKVCache(num_blocks, block_size, max_batch,
                                   blocks_per_seq, prefix_cache=prefix_cache)
            # admission control: worst-case blocks per resident request, so
            # allocate-on-boundary can never exhaust the pool mid-decode
            # (reservation is accounting only — peak_blocks_in_use still
            # reports blocks actually allocated)
            self._reserved: Dict[int, int] = {}
            self.cache = model.init_paged_cache(num_blocks, block_size,
                                                cache_dtype)
            # jitted, cache donated; sampling mode reads logits, not argmax
            self._decode = build_paged_decode_step(
                model, greedy=self._sampler is None)
            # chunked paged prefill: one compile (traced chunk start / last
            # pos) ingests any prompt, chunk grid == block grid so prefix
            # hits skip whole chunks; the read-only variant recomputes the
            # final chunk of a full-prompt hit without touching the pools
            self._prefill_chunk = build_paged_prefill_step(model)
            self._prefill_chunk_ro = build_paged_prefill_step(model,
                                                              write=False)
            # device-resident dense block table, updated row-wise from the
            # host table's dirty set instead of re-uploaded every step
            self._dev_tables = jnp.asarray(self.kv.tables)
            self.kv.take_dirty()  # the upload above covered the initial rows

            def set_row(tables, row, values):
                return jax.lax.dynamic_update_slice_in_dim(
                    tables, values[None], row, 0)

            self._set_row = jax.jit(set_row, donate_argnums=(0,))

            def copy_block(cache, src, dst):
                # COW: duplicate one physical block across every layer's pool
                def one(pool):  # (L, NB, bs, ...)
                    return pool.at[:, dst].set(jnp.take(pool, src, axis=1))

                return jax.tree_util.tree_map(one, cache)

            self._copy_block = jax.jit(copy_block, donate_argnums=(0,))

            def gather_blocks(cache, phys):
                return jax.tree_util.tree_map(lambda pool: pool[:, phys],
                                              cache)

            self._gather_blocks = jax.jit(gather_blocks)

            def put_blocks(cache, blocks, phys):
                return jax.tree_util.tree_map(
                    lambda pool, b: pool.at[:, phys].set(b.astype(pool.dtype)),
                    cache, blocks)

            self._put_blocks = jax.jit(put_blocks, donate_argnums=(0,))
        else:
            self.kv = None
            self.cache = model.init_cache(max_batch, max_seq, cache_dtype)
            # jitted, cache donated; sampling mode reads logits, not argmax
            self._decode = build_decode_step(model,
                                             greedy=self._sampler is None)

            def splice(cache, pcache, slot):
                def one(buf, pc):
                    start = (jnp.int32(0), slot) + (jnp.int32(0),) * (buf.ndim - 2)
                    return jax.lax.dynamic_update_slice(buf, pc.astype(buf.dtype), start)

                return jax.tree_util.tree_map(one, cache, pcache)

            self._splice = jax.jit(splice, donate_argnums=(0,))

        self._build_spec_steps(model)

    def _build_spec_steps(self, model) -> None:
        """(Re)build the multi-token verify step for the active layout;
        None when the family has no speculative decode path (the engine
        then falls back to one-token steps whatever the draft depth)."""
        if self.kv is not None:
            self._spec_decode = build_paged_spec_decode_step(model) \
                if model.paged_spec_decode_step is not None else None
        else:
            self._spec_decode = build_spec_decode_step(model) \
                if model.spec_decode_step is not None else None

    # -- request lifecycle -------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Queue a request; returns False (with a ``Rejected`` record) when
        the engine is draining. Malformed requests still raise."""
        if len(req.prompt) >= self.max_seq:
            raise ValueError(f"prompt {req.uid} ({len(req.prompt)} tokens) "
                             f"does not fit max_seq={self.max_seq}")
        if self.kv is not None and \
                self._worst_blocks(req) > self.kv.pool.num_usable:
            raise ValueError(
                f"request {req.uid} ({len(req.prompt)} prompt + "
                f"{req.max_new_tokens} budget) can never be resident: pool "
                f"has {self.kv.pool.num_usable} blocks of {self.block_size}")
        if not self.accepting:
            self._reject(req, "draining")
            return False
        if self.admission_policy == "shed" and self.max_queue is not None \
                and len(self.queue) >= self.max_queue:
            self._reject(req, "shed")
            return False
        req.submitted_at = self.decode_steps
        self.queue.append(req)
        return True

    def drain(self) -> None:
        """Stop admitting: refuse new submissions, shed the waiting queue,
        let residents stream to completion. Idempotent."""
        if not self.accepting:
            return
        self.accepting = False
        while self.queue:
            self._reject(self.queue.popleft(), "draining")

    def _retry_after(self) -> int:
        """Steps until an admission could plausibly succeed: the shortest
        remaining generation budget among residents (a slot and its blocks
        free when one retires), or 1 when the engine is idle."""
        remaining = [int(self.slot_budget[s]) - len(self.generated[s])
                     for s in range(self.max_batch)
                     if self.slot_uid[s] is not None]
        return max(1, min(remaining)) if remaining else 1

    def _reject(self, req: Request, reason: str) -> None:
        self.rejected[req.uid] = Rejected(
            uid=req.uid, reason=reason, retry_after=self._retry_after())
        if reason == "shed":
            self.shed_count += 1
        elif reason == "timeout":
            self.timeout_count += 1

    def _expire_deadlines(self) -> None:
        """Drop queued requests whose admission deadline has passed. Only
        *waiting* requests time out — a request already resident owns its
        resources and streams to completion."""
        if not any(r.deadline_steps is not None for r in self.queue):
            return
        keep: List[Request] = []
        for req in self.queue:
            waited = self.decode_steps - req.submitted_at
            if req.deadline_steps is not None and \
                    waited > req.deadline_steps:
                self._reject(req, "timeout")
            else:
                keep.append(req)
        self.queue = collections.deque(keep)

    # -- external memory pressure (chaos / co-tenant apps) ------------------

    def hold_blocks(self, n: int) -> int:
        """Let a co-tenant (the chaos injector) take up to ``n`` KV blocks
        out of the pool. Holds only what residents have not reserved, so a
        live sequence can never be starved mid-decode — exactly the pressure
        a neighboring app's allocation puts on admission. Under the swap
        policy, cold residents are evicted to host memory first so the
        co-tenant gets its blocks without starving admission afterwards.
        Returns the count actually held. No-op (0) under the contig layout."""
        if self.kv is None:
            return 0
        self.release_held()
        if self.admission_policy == "swap":
            # make room for the co-tenant by parking cold residents on host
            while self.kv.pool.num_usable - sum(self._reserved.values()) \
                    < int(n):
                victim = self._swap_victim()
                if victim is None:
                    break
                self._swap_out(victim)
        avail = self.kv.pool.num_usable - sum(self._reserved.values())
        take = max(0, min(int(n), avail, self.kv.pool.num_free))
        if take:
            self._hold_seq += 1
            self.kv.pool.allocate(("__hold__", self._hold_seq),
                                  take * self.block_size)
            self._held_blocks = take
        return take

    def release_held(self) -> None:
        """Return externally-held blocks to the pool (pressure clears)."""
        if self._held_blocks:
            self.kv.pool.free(("__hold__", self._hold_seq))
            self._held_blocks = 0

    # -- host-memory swap tier (admission_policy="swap") ---------------------

    def _swap_victim(self) -> Optional[int]:
        """LRU second-chance victim: the resident slot least recently
        admitted/swapped-in, skipping slots inside the grace window so a
        just-restored sequence is not immediately thrashed back out."""
        cands = [s for s in range(self.max_batch)
                 if self.slot_uid[s] is not None
                 and self.decode_steps - self._resident_since[s]
                 >= self.swap_grace]
        if not cands:
            return None
        return min(cands, key=lambda s: self._resident_since[s])

    def _swap_out(self, slot: int) -> None:
        """Evict a resident sequence's blocks to host memory and free them.

        The host copy is taken in logical-block order, so swap-in can
        restore into *any* fresh physical blocks — the round trip is
        bitwise and the resumed stream is token-identical."""
        uid = self.slot_uid[slot]
        blocks = self.kv.slot_blocks(slot)
        with obs.get_telemetry().span("serve.swap_out", uid=uid,
                                      blocks=len(blocks)):
            host = jax.tree_util.tree_map(
                np.asarray,
                self._gather_blocks(self.cache,
                                    jnp.asarray(blocks, jnp.int32)))
            self.swapped[uid] = SwappedSeq(
                uid=uid, generated=list(self.generated[slot]),
                cache_len=int(self.cache_len[slot]),
                budget=int(self.slot_budget[slot]),
                next_token=int(self.tokens[slot, 0]), host_kv=host,
                n_blocks=len(blocks), worst=self._reserved.pop(slot),
                swapped_at=self.decode_steps)
            self.slot_uid[slot] = None
            self.kv.release(slot)
            if self.draft is not None:
                self.draft.release(slot)
            self.swap_outs += 1

    def _swap_in(self, slot: int, sw: SwappedSeq) -> None:
        """Restore a parked sequence into fresh pool blocks and resume."""
        with obs.get_telemetry().span("serve.swap_in", uid=sw.uid,
                                      blocks=sw.n_blocks):
            blocks = self.kv.admit(slot, sw.uid,
                                   sw.n_blocks * self.block_size)
            self.cache = self._put_blocks(
                self.cache, jax.tree_util.tree_map(jnp.asarray, sw.host_kv),
                jnp.asarray(blocks, jnp.int32))
        self._reserved[slot] = sw.worst
        self.slot_uid[slot] = sw.uid
        self.slot_budget[slot] = sw.budget
        self.cache_len[slot] = sw.cache_len
        self.tokens[slot, 0] = sw.next_token
        self.generated[slot] = list(sw.generated)
        self._resident_since[slot] = self.decode_steps
        if self.draft is not None:
            # the parked record keeps no prompt, so the draft restarts from
            # the generated history alone — weaker proposals for a while,
            # never wrong ones (verification is sound whatever p is)
            self.draft.admit(slot, sw.generated)
        self.swap_ins += 1

    def _try_swap_in(self) -> None:
        """Restore parked sequences (FIFO) into free slots while their
        worst-case reservation fits. Runs before admission each step —
        swapped sequences already paid their queueing once."""
        if not self.swapped:
            return
        for uid in sorted(self.swapped, key=lambda u: self.swapped[u].swapped_at):
            if self.slot_cap is not None and \
                    sum(1 for u in self.slot_uid if u is not None) >= \
                    self.slot_cap:
                return
            free = [s for s in range(self.max_batch)
                    if self.slot_uid[s] is None]
            if not free:
                return
            sw = self.swapped[uid]
            fits = (self._held_blocks + sum(self._reserved.values())
                    + sw.worst <= self.kv.pool.num_usable) and \
                self.kv.pool.can_allocate(sw.n_blocks * self.block_size)
            if not fits:
                return  # FIFO: later (smaller) sequences must not starve it
            del self.swapped[uid]
            self._swap_in(free[0], sw)

    def _make_room(self, worst: int) -> bool:
        """Swap out LRU residents until a ``worst``-block reservation fits;
        False when no eligible victim remains (grace-protected or empty)."""
        while self._held_blocks + sum(self._reserved.values()) + worst \
                > self.kv.pool.num_usable:
            victim = self._swap_victim()
            if victim is None:
                return False
            self._swap_out(victim)
        return True

    def _worst_blocks(self, req: Request) -> int:
        """Blocks the request could ever own: prompt plus generation budget,
        capped by the cache-capacity retirement rule."""
        worst = min(len(req.prompt) + req.max_new_tokens, self.max_seq)
        return self.kv.pool.blocks_for(worst)

    def _prefill_len(self, P: int) -> int:
        """Admission prefill length: the true prompt length, rounded up to a
        block multiple under the paged layout (so KV splices whole blocks)
        and to the next power of two under ``bucket_prompts`` (so the
        prefill jit cache is bounded by log2(max_seq) entries)."""
        L = P
        if self.bucket_prompts:
            L = 1 << (max(L, 1) - 1).bit_length()
        if self.kv is not None:
            bs = self.block_size
            L = -(-L // bs) * bs
            return min(L, self.kv.max_blocks_per_seq * bs)
        return min(L, self.max_seq)

    def _pick_token(self, logits_row, uid: int, index: int) -> int:
        """logits_row: (V,). Greedy unless a sampler is configured.

        Sampling keys are a pure function of (seed, uid, index), so a
        request's sampled stream is independent of slot placement and batch
        composition."""
        if self._sampler is None:
            return int(jnp.argmax(logits_row))
        key = self._keys(jnp.asarray([uid], jnp.int32),
                         jnp.asarray([index], jnp.int32))
        return int(self._sampler(logits_row[None], key)[0])

    def _admit(self, slot: int, req: Request) -> None:
        P = len(req.prompt)
        if self.kv is not None:
            logits = self._paged_prefill(slot, req)
        else:
            Lp = self._prefill_len(P)
            self.prefill_lengths[Lp] = self.prefill_lengths.get(Lp, 0) + 1
            batch = {"tokens": jnp.asarray(np.pad(req.prompt, (0, Lp - P)),
                                           jnp.int32)[None]}
            if Lp != P:
                # causal attention keeps every position < P unaffected by the
                # right-padding; logits must come from the true last token
                batch["last_pos"] = jnp.int32(P - 1)
            with obs.get_telemetry().span("serve.prefill", uid=req.uid,
                                          prompt_len=P, padded_len=Lp):
                logits, pcache = self._prefill(self.params, batch)
                self.cache = self._splice(self.cache, pcache,
                                          jnp.int32(slot))
        first = self._pick_token(logits[0, -1], req.uid, 0)
        self.slot_uid[slot] = req.uid
        self.slot_budget[slot] = req.max_new_tokens
        self.cache_len[slot] = P
        self.tokens[slot, 0] = first
        self.generated[slot] = [first]
        self._uid_prompt_len[req.uid] = P
        self._resident_since[slot] = self.decode_steps
        self.admission_waits[req.uid] = max(
            0, self.decode_steps - max(req.submitted_at, 0))
        self.tokens_out += 1
        if self.draft is not None:
            self.draft.admit(slot, [int(t) for t in req.prompt])
            self.draft.commit(slot, [], first)  # first emission, no drafts
        if self._should_retire(slot, first):  # budget of 1, or prefill hit EOS
            self._retire(slot, "eos" if first == self.eos_id else "length")

    def _paged_prefill(self, slot: int, req: Request):
        """Chunked paged prefill with prefix sharing; returns last-token
        logits. Cache-hit prefix chunks skip the kernel entirely (their
        blocks are mapped, already populated); only miss-suffix chunks run,
        writing prompt KV straight into the slot's pool blocks. A full-prompt
        hit still runs the *final* chunk read-only — shared blocks must not
        be rewritten, but the last position's logits are needed to emit the
        first token."""
        P = len(req.prompt)
        bs = self.block_size
        self._reserved[slot] = self._worst_blocks(req)
        shared, covered = self.kv.match_prefix(req.prompt)
        blocks = self.kv.admit(slot, req.uid, P, shared=shared)
        n_blocks = len(blocks)
        Lp = n_blocks * bs
        self.prefill_lengths[Lp] = self.prefill_lengths.get(Lp, 0) + 1
        table_row = jnp.asarray(self.kv.tables[slot:slot + 1])
        padded = np.pad(np.asarray(req.prompt, np.int32), (0, Lp - P))
        first_miss = n_blocks if covered >= P else covered // bs
        logits = None
        tel = obs.get_telemetry()
        for c in range(first_miss, n_blocks):
            toks = jnp.asarray(padded[c * bs:(c + 1) * bs])[None]
            last = jnp.int32(min(P - 1 - c * bs, bs - 1))
            with tel.span("serve.prefill_chunk", uid=req.uid, chunk=c,
                          of=n_blocks):
                logits, self.cache = self._prefill_chunk(
                    self.params, self.cache, toks, jnp.int32(c * bs),
                    table_row, last)
            self.prefill_chunks += 1
        self.prefill_chunks_skipped += first_miss
        if logits is None:  # every block hit: read-only last-chunk recompute
            c = n_blocks - 1
            toks = jnp.asarray(padded[c * bs:(c + 1) * bs])[None]
            with tel.span("serve.prefill_chunk", uid=req.uid, chunk=c,
                          of=n_blocks, readonly=True):
                logits, _ = self._prefill_chunk_ro(
                    self.params, self.cache, toks, jnp.int32(c * bs),
                    table_row, jnp.int32(P - 1 - c * bs))
            self.prefill_chunks += 1
        self.kv.index_prompt(slot, req.prompt)
        return logits

    def _should_retire(self, slot: int, tok: int) -> bool:
        if self.eos_id is not None and tok == self.eos_id:
            return True
        if len(self.generated[slot]) >= int(self.slot_budget[slot]):
            return True
        # the next decode writes at position cache_len; retire only once that
        # would fall off the cache — position max_seq-1 is still serveable
        return self.cache_len[slot] >= self.max_seq

    def _retire(self, slot: int, reason: str) -> None:
        uid = self.slot_uid[slot]
        self.finished[uid] = Finished(
            uid=uid, tokens=list(self.generated[slot]), reason=reason,
            prompt_len=self._uid_prompt_len.pop(uid))
        self.slot_uid[slot] = None
        if self.draft is not None:
            self.draft.release(slot)
        if self.kv is not None:
            # blocks go back to the pool; the slot's table row resets to the
            # null block so its masked idle-slot writes stay harmless
            self.kv.release(slot)
            self._reserved.pop(slot, None)
        # cache_len stays frozen: the stale KV keeps idle-slot math
        # well-defined and is overwritten by the next admission's splice

    # -- serving-rung knobs (live-migratable; see engine.jobs.ServeJob) -----

    def set_slot_cap(self, cap: Optional[int]) -> None:
        """Cap concurrently-resident requests (decode microbatch cap).

        Takes effect at admission: resident sequences above a lowered cap
        keep streaming and the population shrinks as they retire — no
        request is ever evicted mid-decode. ``None`` removes the cap."""
        self.slot_cap = None if cap is None else max(1, int(cap))

    def set_kv_dtype(self, dtype=None) -> None:
        """Cast the live KV cache (``None`` restores the as-built dtype).

        Halving cache bytes (bf16) halves the bandwidth every decode step
        streams — the serving analogue of a bf16 training rung. Lossy on
        the way down: re-upcasting does not recover the rounded bits."""
        if isinstance(dtype, str):
            dtype = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                     "float16": jnp.float16}[dtype]
        dtype = self._base_cache_dtype if dtype is None else jnp.dtype(dtype)
        leaves = jax.tree_util.tree_leaves(self.cache)
        if not leaves or all(a.dtype == dtype for a in leaves):
            return
        self.cache = jax.tree_util.tree_map(
            lambda a: a.astype(dtype), self.cache)

    def set_attn_impl(self, impl: Optional[str]) -> None:
        """Rebuild the decode/prefill callables under a different attention
        impl (``None`` restores the as-built model). Params, cache and all
        slot bookkeeping carry over — only the compiled steps change."""
        if impl == getattr(self, "_attn_impl_override", None):
            return
        self._attn_impl_override = impl
        if impl is None:
            model = self._base_model
        else:
            # rebuild from the as-built model's own kwargs so only the
            # attention impl changes (chunk/remat/dtype/moe_cf carry over)
            from repro.models.registry import rebuild_model
            model = rebuild_model(self._base_model, impl=impl)
        if model is self.model:
            return
        self.model = model
        self._prefill = jax.jit(model.prefill)
        if self.kv is not None:
            self._decode = build_paged_decode_step(
                model, greedy=self._sampler is None)
            self._prefill_chunk = build_paged_prefill_step(model)
            self._prefill_chunk_ro = build_paged_prefill_step(model,
                                                              write=False)
        else:
            self._decode = build_decode_step(model,
                                             greedy=self._sampler is None)
        self._build_spec_steps(model)

    def set_draft_depth(self, k: Optional[int]) -> None:
        """Serving-rung knob: verify ``k`` draft tokens per engine step
        (0 disables speculation; ``None`` restores the as-built depth).

        Takes effect on the next step — residents and the KV cache are
        untouched, because rollback is already cache_len bookkeeping: a
        depth change just alters how many candidate positions the next
        verify pass scores. Emitted streams are invariant to depth (greedy
        is token-identical at any k; sampled stays distribution-faithful),
        which is what makes draft depth safe to walk under thermal or
        energy pressure."""
        depth = self._base_draft_depth if k is None else max(0, int(k))
        if depth == self.draft_depth:
            return
        self.draft_depth = depth
        if depth > 0 and self.draft is None:
            # late enable on an engine built without a source: self-draft
            # from each resident's own emitted history
            from repro.spec.draft import NGramDraft
            self.draft = NGramDraft()
            for slot in range(self.max_batch):
                if self.slot_uid[slot] is not None:
                    self.draft.admit(slot, self.generated[slot])

    # -- stepping ----------------------------------------------------------

    def _pool_pressure(self, req: Request) -> bool:
        """True when admitting ``req`` could starve a resident later:
        its worst case plus every resident's reservation plus externally
        held blocks would overrun the pool."""
        if self.kv is None:
            return False
        return self._held_blocks + sum(self._reserved.values()) + \
            self._worst_blocks(req) > self.kv.pool.num_usable

    def _admit_waiting(self) -> None:
        for slot in range(self.max_batch):
            while True:
                if not self.queue or not self.accepting:
                    return
                if self.slot_cap is not None and \
                        sum(1 for u in self.slot_uid if u is not None) >= \
                        self.slot_cap:
                    return
                if self.slot_uid[slot] is not None:
                    break  # occupied; try the next slot
                head = self.queue[0]
                if self._pool_pressure(head):
                    # reserve the head request's worst case against every
                    # resident's, so allocate-on-boundary can never corrupt
                    # a live sequence mid-decode. Under pressure the policy
                    # decides who pays: "serialize" stalls the whole queue
                    # behind the head (retried next step); "shed" rejects
                    # the head with a retry-after hint and lets a smaller
                    # request behind it take the slot; "swap" parks cold
                    # residents' blocks on the host to make room, falling
                    # back to serialize when every resident is grace-
                    # protected.
                    if self.admission_policy == "swap" and \
                            self._make_room(self._worst_blocks(head)):
                        pass  # pressure cleared; fall through to admission
                    elif self.admission_policy == "shed":
                        # shed the head and move on to the next slot: at
                        # most max_batch rejections per step, so sustained
                        # pressure degrades the queue gradually instead of
                        # emptying it in one tick
                        self._reject(self.queue.popleft(), "shed")
                        break
                    else:
                        return
                req = self.queue.popleft()
                try:
                    self._admit(slot, req)
                except BlockPoolExhausted:
                    # the reservation check makes this unreachable for the
                    # engine's own traffic; a racing external allocation
                    # (between the check and the pool call) can still trip
                    # it. kv.admit fails atomically before any slot state is
                    # written, so rolling back the reservation restores the
                    # engine — then degrade per policy rather than crash.
                    self._reserved.pop(slot, None)
                    if self.admission_policy == "shed":
                        self._reject(req, "shed")
                    else:
                        self.queue.appendleft(req)
                        return
                break

    def _append_positions(self, active: List[int], n: int) -> None:
        """Allocate-on-boundary for the next ``n`` cache positions of every
        active slot (n = 1 plain decode, k+1 speculative window). Re-append
        of an already-owned private position is a no-op, so a speculative
        round that rolled back simply re-covers the same positions."""
        for slot in active:
            cl = int(self.cache_len[slot])
            for i in range(n):
                ev = self.kv.append(slot, cl + i)
                if ev is not None and ev.kind == "cow":
                    # first divergent write into a shared block: give this
                    # sequence a private copy, device-side, before decode
                    with obs.get_telemetry().span("serve.cow_copy",
                                                  slot=slot, src=ev.src,
                                                  dst=ev.block):
                        self.cache = self._copy_block(
                            self.cache, jnp.int32(ev.src),
                            jnp.int32(ev.block))
                    self.cow_copies += 1

    def _ship_dirty_tables(self) -> None:
        rows = self.kv.take_dirty()
        if not rows:
            return
        # ship only the table rows that changed since last step; bulk dirt
        # (e.g. after a swap storm) falls back to one full upload instead
        # of a row-by-row drip
        if len(rows) > max(1, self.max_batch // 2):
            self._dev_tables = jnp.asarray(self.kv.tables)
            self.table_uploads += 1
        else:
            for r in rows:
                self._dev_tables = self._set_row(
                    self._dev_tables, jnp.int32(r),
                    jnp.asarray(self.kv.tables[r]))
        self.table_rows_shipped += len(rows)

    def _spec_k(self, active: List[int]) -> int:
        """Effective draft depth this step: the configured depth clamped so
        the verify window (a) never writes past the cache (positions
        cache_len..cache_len+k must fit), and (b) never allocates past a
        paged reservation — k at most the smallest remaining generation
        budget keeps the worst-case block accounting exact. 0 falls back
        to the one-token step."""
        if self.draft_depth < 1 or self.draft is None or \
                self._spec_decode is None:
            return 0
        k = min(self.draft_depth,
                self.max_seq - 1 - max(int(self.cache_len[s])
                                       for s in active),
                min(int(self.slot_budget[s]) - len(self.generated[s])
                    for s in active))
        return max(k, 0)

    def step(self) -> List[Tuple[int, int]]:
        """Admit waiting requests, run one batched decode, retire finishers.

        Returns (uid, token) pairs emitted this step.
        """
        self._expire_deadlines()
        if self.swapped:
            self._try_swap_in()
        self._admit_waiting()
        active = [s for s in range(self.max_batch) if self.slot_uid[s] is not None]
        if not active:
            # nothing resident but work still pending (queued behind held
            # blocks, or parked on host with no headroom): guard against a
            # run() loop that can never make progress
            if self.queue or self.swapped:
                self._stalled_steps += 1
                if self._stalled_steps > 10000:
                    raise RuntimeError(
                        f"engine stalled: {len(self.queue)} queued, "
                        f"{len(self.swapped)} swapped, no admissible slot "
                        f"for {self._stalled_steps} steps")
            return []
        self._stalled_steps = 0
        k = self._spec_k(active)
        if k >= 1:
            return self._step_speculative(active, k)
        if self.kv is not None:
            self._append_positions(active, 1)
            self._ship_dirty_tables()
            with obs.get_telemetry().span("serve.decode",
                                          batch=len(active)):
                next_tok, logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(self.tokens),
                    jnp.asarray(self.cache_len), self._dev_tables)
        else:
            with obs.get_telemetry().span("serve.decode",
                                          batch=len(active)):
                next_tok, logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(self.tokens),
                    jnp.asarray(self.cache_len))
        if self._sampler is None:
            next_np = np.asarray(next_tok)
        else:
            uids = np.asarray([self.slot_uid[s] if self.slot_uid[s] is not None
                               else 0 for s in range(self.max_batch)], np.int32)
            idxs = np.asarray([len(self.generated[s])
                               for s in range(self.max_batch)], np.int32)
            keys = self._keys(jnp.asarray(uids), jnp.asarray(idxs))
            next_np = np.asarray(self._sampler(logits[:, -1], keys))[:, None]
        self.decode_steps += 1
        self._active_slot_steps += len(active)
        emitted = []
        for slot in active:
            tok = int(next_np[slot, 0])
            self.generated[slot].append(tok)
            self.cache_len[slot] += 1
            self.tokens[slot, 0] = tok
            self.tokens_out += 1
            emitted.append((self.slot_uid[slot], tok))
            if self.draft is not None:
                # keep the draft's view of the stream current even while
                # speculation is off (depth walked to 0, or a clamped round)
                self.draft.commit(slot, [], tok)
            if self._should_retire(slot, tok):
                self._retire(slot, "eos" if (self.eos_id is not None and
                                             tok == self.eos_id) else "length")
        return emitted

    def _step_speculative(self, active: List[int], k: int) -> List[Tuple[int, int]]:
        """One speculative round: draft k tokens per active slot, score the
        (k+1)-token window [last_emitted, d_1..d_k] in ONE verify pass,
        emit the accepted prefix plus exactly one non-draft token.

        Rollback is pure cache_len bookkeeping: the verify pass scattered
        KV for every window position, but cache_len only advances over the
        emitted tokens — rejected positions' KV stays resident, masked dead
        by the ragged-length kernels, and is overwritten in place by the
        next round's scatter. Greedy verification makes the emitted stream
        token-identical to one-token greedy decode; sampled mode is
        distribution-faithful rejection sampling on the engine's
        fold_in(seed, uid, index) streams."""
        S = k + 1
        tel = obs.get_telemetry()
        with tel.span("serve.spec_draft", batch=len(active), k=k):
            drafts, dprobs = self.draft.propose(active, k)
        win = np.zeros((self.max_batch, S), np.int32)
        win[:, 0] = self.tokens[:, 0]
        probs_b = None
        if dprobs is not None:
            probs_b = np.zeros((self.max_batch, k, dprobs.shape[-1]),
                               np.float32)
        for row, slot in enumerate(active):
            win[slot, 1:] = drafts[row]
            if probs_b is not None:
                probs_b[slot] = dprobs[row]
        if self.kv is not None:
            self._append_positions(active, S)
            self._ship_dirty_tables()
            with tel.span("serve.spec_verify", batch=len(active), k=k):
                logits, self.cache = self._spec_decode(
                    self.params, self.cache, jnp.asarray(win),
                    jnp.asarray(self.cache_len), self._dev_tables)
        else:
            with tel.span("serve.spec_verify", batch=len(active), k=k):
                logits, self.cache = self._spec_decode(
                    self.params, self.cache, jnp.asarray(win),
                    jnp.asarray(self.cache_len))
        if self._sampler is None:
            toks, n_emit = self._greedy_verify(logits,
                                               jnp.asarray(win[:, 1:]))
        else:
            uids = np.asarray(
                [self.slot_uid[s] if self.slot_uid[s] is not None else 0
                 for s in range(self.max_batch)], np.int32)
            idxs = (np.asarray([len(self.generated[s])
                                for s in range(self.max_batch)],
                               np.int32)[:, None]
                    + np.arange(S, dtype=np.int32)[None])
            keys = self._keys2(
                jnp.asarray(np.broadcast_to(uids[:, None],
                                            (self.max_batch, S))),
                jnp.asarray(idxs))
            toks, n_emit = self._rej_verify(
                logits, jnp.asarray(win[:, 1:]),
                None if probs_b is None else jnp.asarray(probs_b), keys)
        tok_np = np.asarray(toks)
        n_np = np.asarray(n_emit)
        self.decode_steps += 1
        self._active_slot_steps += len(active)
        emitted: List[Tuple[int, int]] = []
        accepted_total = 0
        for slot in active:
            uid = self.slot_uid[slot]
            seq = [int(t) for t in tok_np[slot, :int(n_np[slot])]]
            self.spec_rounds += 1
            self.spec_drafted += k
            self.spec_accepted += len(seq) - 1
            accepted_total += len(seq) - 1
            retired = False
            for tok in seq:
                self.generated[slot].append(tok)
                self.cache_len[slot] += 1
                self.tokens[slot, 0] = tok
                self.tokens_out += 1
                emitted.append((uid, tok))
                if self._should_retire(slot, tok):
                    # the retire trims the round: later accepted tokens are
                    # dropped and their KV stays masked dead, exactly like
                    # a rejection
                    self._retire(slot, "eos" if (self.eos_id is not None and
                                                 tok == self.eos_id)
                                 else "length")
                    retired = True
                    break
            if not retired:
                self.draft.commit(slot, seq[:-1], seq[-1])
        m = tel.metrics
        m.counter("spec_drafted_total",
                  "draft tokens proposed to the verifier").inc(k * len(active))
        m.counter("spec_accepted_total",
                  "draft tokens accepted by the verifier").inc(accepted_total)
        m.gauge("spec_acceptance_rate",
                "running accepted/drafted ratio").set(
            self.spec_accepted / max(1, self.spec_drafted))
        return emitted

    def run(self, requests: List[Request]) -> Dict[int, Finished]:
        for req in requests:
            self.submit(req)
        while self.queue or self.swapped or \
                any(u is not None for u in self.slot_uid):
            self.step()
        return self.finished

    @property
    def has_work(self) -> bool:
        """True while anything is queued, resident, or swapped out."""
        return bool(self.queue) or bool(self.swapped) or \
            any(u is not None for u in self.slot_uid)

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots active per decode step (batching efficiency)."""
        if not self.decode_steps:
            return 0.0
        return self._active_slot_steps / (self.decode_steps * self.max_batch)

    def kv_bytes(self, *, peak: bool = False) -> int:
        """KV-cache memory footprint in bytes.

        ``contig``: the whole (max_batch, max_seq) slab tree — allocated up
        front whatever the traffic. ``paged``: pool bytes scaled to blocks in
        use (``peak`` gives the high-water mark) — what a block-granular
        allocator would actually have had to back.
        """
        total = sum(a.size * a.dtype.itemsize
                    for a in jax.tree_util.tree_leaves(self.cache))
        if self.kv is None:
            return total
        blocks = self.kv.pool.peak_blocks_in_use if peak \
            else self.kv.pool.blocks_in_use
        return int(total * blocks / self.kv.pool.num_blocks)

    def stats(self) -> Dict:
        """Engine-level stats: occupancy, prefill buckets, pool accounting."""
        out = {
            "decode_steps": self.decode_steps,
            "tokens_out": self.tokens_out,
            "occupancy": round(self.occupancy, 4),
            "kv_layout": self.kv_layout,
            "prefill_buckets": {str(k): v for k, v in
                                sorted(self.prefill_lengths.items())},
            "prefill_compiles": len(self.prefill_lengths),
            "kv_bytes": self.kv_bytes(),
            "admission_policy": self.admission_policy,
            "accepting": self.accepting,
            "shed": self.shed_count,
            "timeouts": self.timeout_count,
            "rejected": len(self.rejected),
        }
        waits = list(self.admission_waits.values())
        out["admission_wait_mean"] = \
            round(sum(waits) / len(waits), 3) if waits else 0.0
        out["admission_wait_max"] = max(waits) if waits else 0
        out["draft_depth"] = self.draft_depth
        if self.spec_rounds:
            out["spec_rounds"] = self.spec_rounds
            out["spec_drafted"] = self.spec_drafted
            out["spec_accepted"] = self.spec_accepted
            out["spec_acceptance"] = round(
                self.spec_accepted / max(1, self.spec_drafted), 4)
        if self.kv is not None:
            out["held_blocks"] = self._held_blocks
            out["prefill_chunks"] = self.prefill_chunks
            out["prefill_chunks_skipped"] = self.prefill_chunks_skipped
            out["cow_copies"] = self.cow_copies
            out["table_rows_shipped"] = self.table_rows_shipped
            out["table_uploads"] = self.table_uploads
            out["swapped"] = len(self.swapped)
            out["swap_outs"] = self.swap_outs
            out["swap_ins"] = self.swap_ins
            live = {self.slot_uid[s]: int(self.cache_len[s])
                    for s in range(self.max_batch)
                    if self.slot_uid[s] is not None}
            out["pool"] = self.kv.stats(live)
            out["peak_kv_bytes"] = self.kv_bytes(peak=True)
        return out


# ---------------------------------------------------------------------------
# legacy lockstep path (SSM / hybrid / enc-dec / VLM families)
# ---------------------------------------------------------------------------


def lockstep_generate(model, params, batch, *, prompt_len: int,
                      gen: int) -> jnp.ndarray:
    """Fixed-batch, fixed-length generation (the pre-engine serve loop)."""
    cfg = model.cfg
    max_len = prompt_len + gen
    bsz = batch["tokens"].shape[0]
    if cfg.family == "encdec":
        from repro.models import encdec as E
        cache = model.init_cache(bsz, max_len, jnp.float32)
        enc_h = E.encode(params, cfg, jnp.asarray(batch["audio_embed"]))
        ks, vs = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a, i=i: a[i], params["dec_layers"])
            hd = cfg.head_dim
            B, Senc = enc_h.shape[:2]
            ks.append((enc_h @ lp["cross_attn"]["wk"]).reshape(B, Senc, cfg.n_kv_heads, hd))
            vs.append((enc_h @ lp["cross_attn"]["wv"]).reshape(B, Senc, cfg.n_kv_heads, hd))
        cache["cross"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
        tokens = jnp.zeros((bsz, 1), jnp.int32)
        pos0 = 0
    else:
        logits, pcache = model.prefill(params, {k: jnp.asarray(v) for k, v in batch.items()})
        cache = model.init_cache(bsz, max_len, jnp.float32)

        def splice(buf, pc):
            if buf.ndim >= 3 and pc.shape[2] == prompt_len and buf.shape[1] == bsz:
                return buf.at[:, :, :prompt_len].set(pc.astype(buf.dtype))
            return pc.astype(buf.dtype) if pc.shape == buf.shape else buf

        if cfg.family in ("ssm", "hybrid"):
            cache = jax.tree_util.tree_map(lambda b, p: p.astype(b.dtype), cache, pcache)
        else:
            cache = jax.tree_util.tree_map(splice, cache, pcache)
        tokens = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
        pos0 = prompt_len

    step = build_decode_step(model)
    out_tokens = [tokens]
    for t in range(gen - 1):
        tokens, _, cache = step(params, cache, tokens, jnp.int32(pos0 + t))
        out_tokens.append(tokens)
    return jnp.concatenate(out_tokens, axis=1)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def _synthetic_requests(rng, n: int, prompt_len: int, gen: int,
                        vocab: int) -> List[Request]:
    """A ragged request stream: prompt lengths and budgets vary per request
    so retirement and admission interleave instead of running in lockstep."""
    reqs = []
    for uid in range(n):
        p = max(2, prompt_len + int(rng.integers(-prompt_len // 2, prompt_len // 2 + 1)))
        g = max(1, gen + int(rng.integers(-gen // 2, gen // 2 + 1)))
        reqs.append(Request(uid=uid,
                            prompt=rng.integers(0, vocab, p).astype(np.int32),
                            max_new_tokens=g))
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4, help="serving slots")
    ap.add_argument("--requests", type=int, default=0,
                    help="requests in the stream (default: 3x batch)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=0,
                    help="cache capacity (default: 2*(prompt+gen))")
    ap.add_argument("--attn-impl", default="auto",
                    choices=("auto", "naive", "pallas"),
                    help="decode attention path; auto resolves via "
                         "kernels/backend.auto_decode_impl")
    ap.add_argument("--kv-layout", default="contig",
                    choices=("contig", "paged"),
                    help="KV cache layout: contiguous per-slot slabs, or "
                         "block-pooled paged cache (repro.paging)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged layout: token positions per KV block")
    ap.add_argument("--kv-blocks", type=int, default=None,
                    help="paged layout: physical blocks in the pool "
                         "(default: contiguous-equivalent capacity)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k filter for sampling (0 = full vocab)")
    ap.add_argument("--sample-seed", type=int, default=0)
    ap.add_argument("--admission-policy", default="serialize",
                    choices=("serialize", "shed", "swap"),
                    help="overload behavior: serialize queues behind the "
                         "head-of-line request; shed rejects with retry-after; "
                         "swap parks cold residents' blocks in host memory "
                         "(paged layout only)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="paged layout: disable prompt-prefix block sharing")
    ap.add_argument("--swap-grace", type=int, default=2,
                    help="swap policy: steps a just-admitted/restored "
                         "sequence is protected from swap-out")
    ap.add_argument("--draft-depth", type=int, default=0,
                    help="speculative decoding: draft tokens verified per "
                         "engine step (0 = off); also a serving rung the "
                         "arbiter walks down under pressure")
    ap.add_argument("--draft-source", default="ngram",
                    help="where drafts come from: 'ngram' (self-drafting "
                         "n-gram head) or a registry arch name served "
                         "reduced as a draft model")
    ap.add_argument("--bucket-prompts", action="store_true",
                    help="round admission prefill lengths up to power-of-two "
                         "buckets (bounds prefill jit-cache growth)")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--lockstep", action="store_true",
                    help="legacy fixed-batch loop (forced for SSM/hybrid/"
                         "encdec/VLM families)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--telemetry-out", default=None,
                    help="directory for the repro.obs telemetry bundle "
                         "(metrics.jsonl, spans.jsonl, trace.json, "
                         "audit.json)")
    args = ap.parse_args(argv)

    tel = obs.enable() if args.telemetry_out else None
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.family == "cnn":
        raise SystemExit("CNN archs have no decode path")

    max_seq = args.max_seq or 2 * (args.prompt_len + args.gen)
    impl = args.attn_impl
    if impl == "auto":
        impl = auto_decode_impl(max_seq)
    model = build_model(cfg, impl=impl)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(args.seed)

    if args.lockstep or cfg.family not in ENGINE_FAMILIES:
        from repro.data.pipeline import synthetic_lm_batch
        batch = synthetic_lm_batch(rng, args.batch, args.prompt_len, cfg.vocab_size)
        if cfg.family == "vlm":
            batch["image_embed"] = jnp.asarray(
                rng.standard_normal((args.batch, cfg.n_image_tokens, cfg.d_model)),
                jnp.float32) * 0.02
        if cfg.family == "encdec":
            batch["audio_embed"] = jnp.asarray(
                rng.standard_normal((args.batch, cfg.n_audio_frames, cfg.d_model)),
                jnp.float32) * 0.02
        t0 = time.time()
        gen = lockstep_generate(model, params, batch,
                                prompt_len=args.prompt_len, gen=args.gen)
        dt = time.time() - t0
        n_tok = args.gen * args.batch
        print(f"arch={cfg.name} mode=lockstep impl={impl} batch={args.batch} "
              f"{n_tok} tokens in {dt*1e3:.0f}ms ({n_tok/max(dt,1e-9):.1f} tok/s)")
        print("sample:", np.asarray(gen[0])[:12])
        return gen

    n_req = args.requests or 3 * args.batch
    reqs = _synthetic_requests(rng, n_req, args.prompt_len, args.gen,
                               cfg.vocab_size)
    draft = None
    if args.draft_depth > 0:
        from repro.spec.draft import build_draft_source
        draft = build_draft_source(
            args.draft_source, target_cfg=cfg, max_batch=args.batch,
            max_seq=max_seq, temperature=args.temperature,
            top_k=args.top_k, seed=args.sample_seed)
    engine = ContinuousBatchingEngine(
        model, params, max_batch=args.batch, max_seq=max_seq,
        eos_id=args.eos_id, kv_layout=args.kv_layout,
        block_size=args.block_size, num_blocks=args.kv_blocks,
        temperature=args.temperature, top_k=args.top_k,
        sample_seed=args.sample_seed, bucket_prompts=args.bucket_prompts,
        admission_policy=args.admission_policy,
        prefix_cache=not args.no_prefix_cache, swap_grace=args.swap_grace,
        draft_depth=args.draft_depth, draft_source=draft)
    t0 = time.time()
    finished = engine.run(reqs)
    dt = time.time() - t0
    tok_s = engine.tokens_out / max(dt, 1e-9)
    print(f"arch={cfg.name} mode=continuous impl={impl} kv={args.kv_layout} "
          f"slots={args.batch} requests={n_req} tokens={engine.tokens_out} "
          f"steps={engine.decode_steps} occupancy={engine.occupancy:.2f} "
          f"wall={dt*1e3:.0f}ms ({tok_s:.1f} tok/s)")
    if engine.spec_rounds:
        print(f"spec: depth={engine.draft_depth} source={args.draft_source} "
              f"accepted {engine.spec_accepted}/{engine.spec_drafted} drafts "
              f"({engine.spec_accepted / max(1, engine.spec_drafted):.2f})")
    if args.kv_layout == "paged":
        st = engine.stats()
        pool = st["pool"]
        print(f"pool: {pool['peak_blocks_in_use']}/{pool['num_blocks']} peak "
              f"blocks, peak KV {engine.kv_bytes(peak=True)/1e6:.2f}MB "
              f"(contig-equivalent slab would be fully resident)")
        if "prefix" in pool:
            pf = pool["prefix"]
            print(f"prefix: hit_rate={pf['hit_rate']:.2f} "
                  f"chunks run={st['prefill_chunks']} "
                  f"skipped={st['prefill_chunks_skipped']} "
                  f"cow={st['cow_copies']} "
                  f"swap out/in={st['swap_outs']}/{st['swap_ins']}")
    sample = finished[0].tokens[:12] if 0 in finished else []
    print("sample uid=0:", sample)
    if args.json_out:
        payload = obs.versioned({
            "arch": cfg.name, "impl": impl, "slots": args.batch,
            "requests": n_req, "tokens": engine.tokens_out,
            "steps": engine.decode_steps, "occupancy": round(engine.occupancy, 4),
            "wall_s": round(dt, 4), "tok_s": round(tok_s, 2),
            "stats": engine.stats(),
            "finished": {str(u): {"reason": f.reason, "n_tokens": len(f.tokens),
                                  "prompt_len": f.prompt_len}
                         for u, f in finished.items()},
        })
        with open(args.json_out, "w") as f:
            json.dump(obs.encode_record(payload), f, indent=1)
    if tel is not None:
        tel.save(args.telemetry_out)
        print(f"[obs] telemetry bundle -> {args.telemetry_out} "
              f"({len(tel.tracer.spans())} spans)")
    return finished


if __name__ == "__main__":
    main()
