import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, record memory/cost analysis and roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out reports/dryrun.json
  ... --multi-pod           # 2x16x16 (pod,data,model) instead of 16x16
  ... --mb 8 --remat full   # override the cell's execution-choice defaults
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs import ASSIGNED, SHAPES, applicable, get_config
from repro.core.choices import MeshChoice
from repro.core.profiler import roofline_from_compiled
from repro.engine.rungs import Rung
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (batch_shardings, batch_specs, cache_shardings,
                                decode_specs, param_shardings, replicated)
from repro.launch.steps import build_decode_step, build_prefill_step
from repro.models.registry import build_model
from repro.models.sharding import axis_rules
from repro.optim.optimizers import sgd
from repro.optim.compression import Compressor

# Per-arch execution-choice defaults for the BASELINE dry-run. Microbatch is
# sized so live activations fit v5e HBM with remat=full; the hillclimb
# (EXPERIMENTS.md §Perf) moves these knobs.
TRAIN_MB = {
    "whisper-small": 8, "zamba2-2.7b": 8, "llama3.2-1b": 2, "granite-3-2b": 8,
    "command-r-35b": 16, "nemotron-4-15b": 8, "llama-3.2-vision-11b": 16,
    "deepseek-moe-16b": 2, "deepseek-v3-671b": 16, "rwkv6-7b": 8,
}


def default_choice(arch: str, shape_name: str, multi_pod: bool) -> MeshChoice:
    mesh_shape = (2, 16, 16) if multi_pod else (16, 16)
    axis_names = ("pod", "data", "model") if multi_pod else ("data", "model")
    wide = False  # wide-EP measured worse than narrow (EXPERIMENTS §Perf); hillclimb knob
    if shape_name == "train_4k":
        # per-microbatch batch must stay divisible by the DP extent
        dp_total = 32 if multi_pod else 16
        mb = max(1, min(TRAIN_MB[arch], 256 // dp_total))
        if TRAIN_MB[arch] > mb:
            mb = 256 // dp_total
        return MeshChoice(mesh_shape, axis_names, microbatch=mb,
                          remat="full", chunk=1024, wide_ep=wide)
    return MeshChoice(mesh_shape, axis_names, microbatch=1, remat="none",
                      chunk=2048, wide_ep=wide)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               choice: Optional[MeshChoice] = None, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    choice = choice or default_choice(arch, shape_name, multi_pod)
    # the dry-run lowers exactly what the live engine would execute: the
    # Rung is the executable face of the MeshChoice (engine/rungs.py)
    rung = Rung.from_mesh_choice(choice, param_dtype="bfloat16")
    rec["choice"] = choice.name
    rec["rung"] = rung.signature()
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = choice.rules()
    model = build_model(cfg, impl=rung.attn_impl, chunk=rung.chunk,
                        remat=rung.remat, param_dtype=rung.dtype,
                        moe_cf=choice.moe_cf)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))

    # set_mesh (not `with mesh:`) — on new JAX only set_mesh installs the
    # abstract mesh that with_sharding_constraint/shard_map resolve during
    # tracing; repro.compat falls back to `with mesh:` on 0.4.x.
    from repro.compat import set_mesh
    with set_mesh(mesh):
        with axis_rules(rules):
            p_shard = param_shardings(params_sds, mesh, rules)
            if shape.mode == "train":
                opt = sgd()
                comp = Compressor(rung.compression)
                step = rung.train_step_fn(model, opt, compressor=comp)
                state_sds = {"params": params_sds, "opt": (), "err": (),
                             "step": jax.ShapeDtypeStruct((), jnp.int32)}
                state_shard = {"params": p_shard, "opt": (), "err": (),
                               "step": replicated(mesh)}
                batch_sds = batch_specs(cfg, shape)
                b_shard = batch_shardings(batch_sds, mesh, rules)
                metrics_shard = {"loss": replicated(mesh), "grad_norm": replicated(mesh)}
                lowered = jax.jit(step, in_shardings=(state_shard, b_shard),
                                  out_shardings=(state_shard, metrics_shard),
                                  donate_argnums=(0,)).lower(state_sds, batch_sds)
            elif shape.mode == "prefill":
                fn = build_prefill_step(model)
                batch_sds = batch_specs(cfg, shape)
                b_shard = batch_shardings(batch_sds, mesh, rules)
                cache_sds = jax.eval_shape(
                    lambda p, b: model.prefill(p, b)[1], params_sds, batch_sds)
                c_shard = cache_shardings(cache_sds, mesh, rules)
                logits_shard = batch_shardings(
                    {"x": jax.ShapeDtypeStruct(
                        (shape.global_batch, 1, cfg.vocab_size),
                        jnp.float32)}, mesh, rules)["x"]
                lowered = jax.jit(fn, in_shardings=(p_shard, b_shard),
                                  out_shardings=(logits_shard, c_shard)
                                  ).lower(params_sds, batch_sds)
            else:  # decode
                # raw step: the AOT jit below owns shardings + donation
                fn = build_decode_step(model, jit=False)
                inputs, cache_sds = decode_specs(model, cfg, shape)
                c_shard = cache_shardings(cache_sds, mesh, rules)
                tok_shard = batch_shardings({"t": inputs["tokens"]}, mesh, rules)["t"]
                logits_shard = batch_shardings(
                    {"x": jax.ShapeDtypeStruct(
                        (shape.global_batch, 1, cfg.vocab_size), jnp.float32)},
                    mesh, rules)["x"]
                lowered = jax.jit(
                    fn, in_shardings=(p_shard, c_shard, tok_shard, replicated(mesh)),
                    out_shardings=(tok_shard, logits_shard, c_shard),
                    donate_argnums=(1,),
                ).lower(params_sds, cache_sds, inputs["tokens"], inputs["cache_len"])

            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            hlo = compiled.as_text()
            terms = roofline_from_compiled(compiled, hlo, choice.n_chips)

    n_tokens = shape.global_batch * (shape.seq_len if shape.mode == "train" else 1)
    model_flops_factor = 6 if shape.mode == "train" else 2
    n_active = cfg.active_param_count()
    model_flops = model_flops_factor * n_active * n_tokens
    rec.update(
        status="ok",
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        per_device_bytes=terms.per_device_memory,
        per_device_gb=round(terms.per_device_memory / 2 ** 30, 3),
        arg_gb=round(mem.argument_size_in_bytes / 2 ** 30, 3),
        temp_gb=round(mem.temp_size_in_bytes / 2 ** 30, 3),
        fits_hbm=bool(terms.per_device_memory <= 16 * 2 ** 30),
        hlo_flops_global=terms.flops,
        hlo_bytes_global=terms.bytes_accessed,
        collective_bytes_global=terms.collective_bytes,
        compute_s=terms.compute_s, memory_s=terms.memory_s,
        collective_s=terms.collective_s,
        dominant=terms.dominant, latency_s=terms.latency_s,
        model_flops=model_flops,
        useful_flops_ratio=round(model_flops / max(terms.flops, 1), 4),
        roofline_fraction=round(
            (model_flops / (choice.n_chips * 197e12)) / max(terms.latency_s, 1e-12), 4),
        collectives=_collective_summary(hlo),
    )
    if verbose:
        print(json.dumps(rec, indent=None, default=str))
    return rec


def _collective_summary(hlo: str) -> dict:
    from repro.core.profiler import parse_collective_bytes
    return parse_collective_bytes(hlo)


def _merge_out(path, reports):
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    existing = []
    if os.path.exists(path):
        with open(path) as f:
            try:
                existing = json.load(f)
            except Exception:
                existing = []
    key = lambda r: (r["arch"], r["shape"], r["mesh"])
    merged = {key(r): r for r in existing}
    for r in reports:
        merged[key(r)] = r
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(list(merged.values()), f, indent=1, default=str)
    os.replace(tmp, path)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mb", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--compression", default=None)
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--attn-impl", default=None, choices=("chunked", "pallas"))
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = []
    archs = list(ASSIGNED) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    reports = []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                choice = default_choice(arch, shape, mp)
                over = {}
                if args.mb is not None:
                    over["microbatch"] = args.mb
                if args.remat is not None:
                    over["remat"] = args.remat
                if args.compression is not None:
                    over["compression"] = args.compression
                if args.chunk is not None:
                    over["chunk"] = args.chunk
                if args.attn_impl is not None:
                    over["attn_impl"] = args.attn_impl
                if over:
                    choice = dataclasses.replace(choice, **over)
                try:
                    reports.append(lower_cell(arch, shape, multi_pod=mp, choice=choice))
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    reports.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if mp else "16x16",
                                    "status": "FAILED", "error": f"{type(e).__name__}: {e}"})
                if args.out:
                    _merge_out(args.out, reports)  # crash-safe incremental write
    n_fail = sum(1 for r in reports if r.get("status") == "FAILED")
    print(f"cells: {len(reports)}, failed: {n_fail}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
