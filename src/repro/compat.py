"""Version-compat shims over JAX APIs that moved between releases.

The repo targets the modern ``jax.sharding.get_abstract_mesh`` /
``jax.set_mesh`` API (jax >= 0.5); on older installs (0.4.x) those names
either don't exist or — in the case of the private
``jax._src.mesh.get_abstract_mesh`` — return an axis-env tuple with entirely
different semantics. Everything that needs "the mesh currently in scope"
goes through this module so the rest of the codebase can pretend it runs on
one JAX version.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax


def get_abstract_mesh() -> Optional["jax.sharding.Mesh"]:
    """The mesh in scope for tracing, or None when there isn't one.

    On new JAX this is ``jax.sharding.get_abstract_mesh()`` (an AbstractMesh,
    possibly empty). On 0.4.x we read ``thread_resources.env.physical_mesh``,
    which both ``with mesh:`` and our :func:`set_mesh` fallback install.
    Callers must handle both ``None`` and ``mesh.empty``.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    try:
        from jax._src import mesh as _mesh_lib
        env = _mesh_lib.thread_resources.env.physical_mesh
        return None if env.empty else env
    except Exception:
        return None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, axis_names=None):
    """``jax.shard_map`` (new API) or ``jax.experimental.shard_map.shard_map``.

    Translates the new-API kwargs to their 0.4.x spellings: ``check_vma`` was
    ``check_rep``, and ``axis_names`` (the *manual* axes) is the complement of
    the old ``auto`` set.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        kwargs = {}
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        if axis_names is not None:
            kwargs["axis_names"] = axis_names
        return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
    from jax.experimental.shard_map import shard_map as _sm
    kwargs = {}
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kwargs["auto"] = auto
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)


def make_mesh(axis_shapes, axis_names, *, auto_axes: bool = True):
    """``jax.make_mesh`` with Auto axis_types when the install supports them.

    ``axis_types`` (and ``jax.sharding.AxisType``) only exist on new JAX;
    0.4.x meshes are implicitly fully-auto, so dropping the argument is
    semantics-preserving.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if auto_axes and axis_type is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(axis_type.Auto,) * len(axis_shapes))
    return jax.make_mesh(axis_shapes, axis_names)


@contextlib.contextmanager
def set_mesh(mesh):
    """``jax.set_mesh(mesh)`` when available, else ``with mesh:``.

    New JAX distinguishes entering a concrete mesh from installing the
    abstract mesh that ``with_sharding_constraint`` resolves against; on
    0.4.x ``with mesh:`` covers both roles.
    """
    fn = getattr(jax, "set_mesh", None)
    if fn is not None:
        with fn(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
