from repro.checkpoint.store import save_pytree, load_pytree  # noqa: F401
from repro.checkpoint.manager import CheckpointManager  # noqa: F401
