"""Pytree (de)serialization: msgpack + zstd (zlib fallback), atomic writes.

Arrays are stored as raw little-endian buffers with dtype/shape metadata;
the tree structure is encoded as nested msgpack maps/lists. Restore is
mesh-agnostic: ``load_pytree`` returns numpy arrays which the caller
device_puts under whatever sharding the *current* mesh dictates — this is
what makes elastic re-meshing (Swan migration at cluster scale) a pure
restore-time concern.

Crash consistency: ``save_pytree`` writes to a temp file in the target
directory and ``os.replace``s it into place, so a crash at any point leaves
either the previous file or the new one, never a torn mix. On top of that
every file carries a header checksum (``_MAGIC`` + crc32 over the
compressed payload), so a file that *was* torn anyway — non-atomic
filesystem, truncated copy, bit rot — is detected at load time as
:class:`CheckpointCorrupt` instead of being deserialized into garbage.
``CheckpointManager.restore_latest`` uses that signal to fall back to the
previous step.

``zstandard`` is an optional dependency: when absent we compress with zlib.
The formats are self-describing (zstd frames start with the magic
``28 B5 2F FD``), so either build can read checkpoints written by the other —
except that reading a zstd checkpoint on a zlib-only install raises.
Headerless files written by older builds still load (no checksum to check).
"""
from __future__ import annotations

import os
import struct
import tempfile
import zlib
from typing import Any

import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # optional dep: fall back to stdlib zlib
    zstd = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
# checksummed-container header: magic + u32 crc32(compressed payload)
_MAGIC = b"SWCK\x01\x00"
_HEADER = struct.Struct(">6sI")

_ARR = "__arr__"
_TUPLE = "__tuple__"


class CheckpointCorrupt(RuntimeError):
    """The file's checksum/framing does not match its contents (torn write,
    truncation, bit rot). The caller should fall back to an older step."""


def _encode(node):
    if isinstance(node, dict):
        return {str(k): _encode(v) for k, v in node.items()}
    if isinstance(node, (list,)):
        return [_encode(v) for v in node]
    if isinstance(node, tuple):
        return {_TUPLE: [_encode(v) for v in node]}
    if hasattr(node, "dtype"):  # jax or numpy array
        a = np.asarray(node)
        dtype = str(a.dtype)
        if dtype == "bfloat16":
            a = a.view(np.uint16)
        return {_ARR: True, "dtype": dtype, "shape": list(a.shape),
                "data": a.tobytes()}
    if isinstance(node, (int, float, str, bool)) or node is None:
        return node
    raise TypeError(f"cannot serialize {type(node)}")


def _decode(node):
    if isinstance(node, dict):
        if node.get(_ARR):
            dtype = node["dtype"]
            if dtype == "bfloat16":
                import ml_dtypes  # noqa: F401 (via jax)
                a = np.frombuffer(node["data"], np.uint16).reshape(node["shape"])
                return a.view(ml_dtypes.bfloat16)
            return np.frombuffer(node["data"], np.dtype(dtype)).reshape(node["shape"]).copy()
        if _TUPLE in node:
            return tuple(_decode(v) for v in node[_TUPLE])
        return {k: _decode(v) for k, v in node.items()}
    if isinstance(node, list):
        return [_decode(v) for v in node]
    return node


def serialize_pytree(tree: Any, *, level: int = 3) -> bytes:
    """Full checksummed file image (header + compressed msgpack payload)."""
    payload = msgpack.packb(_encode(tree), use_bin_type=True)
    if zstd is not None:
        comp = zstd.ZstdCompressor(level=level).compress(payload)
    else:
        comp = zlib.compress(payload, level)
    return _HEADER.pack(_MAGIC, zlib.crc32(comp) & 0xFFFFFFFF) + comp


def deserialize_pytree(data: bytes, *, source: str = "<bytes>") -> Any:
    """Inverse of :func:`serialize_pytree`; also reads legacy headerless
    files. Raises :class:`CheckpointCorrupt` on checksum/framing mismatch."""
    if data[:len(_MAGIC)] == _MAGIC:
        if len(data) < _HEADER.size:
            raise CheckpointCorrupt(f"{source}: truncated header")
        _, crc = _HEADER.unpack_from(data)
        comp = data[_HEADER.size:]
        if zlib.crc32(comp) & 0xFFFFFFFF != crc:
            raise CheckpointCorrupt(
                f"{source}: checksum mismatch (torn or corrupt write)")
    else:
        comp = data  # legacy headerless file: no checksum to verify
    try:
        if comp[:4] == _ZSTD_MAGIC:
            if zstd is None:
                raise RuntimeError(
                    f"{source} is zstd-compressed but zstandard is not "
                    f"installed")
            payload = zstd.ZstdDecompressor().decompress(comp)
        else:
            payload = zlib.decompress(comp)
        return _decode(msgpack.unpackb(payload, raw=False))
    except (zlib.error, msgpack.exceptions.UnpackException, ValueError,
            KeyError, TypeError) as e:
        # a checksummed file that passed crc cannot land here unless the
        # writer was buggy; legacy files land here when truncated
        raise CheckpointCorrupt(f"{source}: undecodable payload: {e}") from e
    except Exception as e:  # zstd raises its own error type
        if zstd is not None and isinstance(e, zstd.ZstdError):
            raise CheckpointCorrupt(
                f"{source}: undecodable payload: {e}") from e
        raise


def save_pytree(tree: Any, path: str, *, level: int = 3) -> None:
    data = serialize_pytree(tree, level=level)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)  # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str) -> Any:
    with open(path, "rb") as f:
        data = f.read()
    return deserialize_pytree(data, source=path)
