"""Pytree (de)serialization: msgpack + zstd (zlib fallback), atomic writes.

Arrays are stored as raw little-endian buffers with dtype/shape metadata;
the tree structure is encoded as nested msgpack maps/lists. Restore is
mesh-agnostic: ``load_pytree`` returns numpy arrays which the caller
device_puts under whatever sharding the *current* mesh dictates — this is
what makes elastic re-meshing (Swan migration at cluster scale) a pure
restore-time concern.

``zstandard`` is an optional dependency: when absent we compress with zlib.
The formats are self-describing (zstd frames start with the magic
``28 B5 2F FD``), so either build can read checkpoints written by the other —
except that reading a zstd checkpoint on a zlib-only install raises.
"""
from __future__ import annotations

import os
import tempfile
import zlib
from typing import Any

import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # optional dep: fall back to stdlib zlib
    zstd = None

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"

_ARR = "__arr__"
_TUPLE = "__tuple__"


def _encode(node):
    if isinstance(node, dict):
        return {str(k): _encode(v) for k, v in node.items()}
    if isinstance(node, (list,)):
        return [_encode(v) for v in node]
    if isinstance(node, tuple):
        return {_TUPLE: [_encode(v) for v in node]}
    if hasattr(node, "dtype"):  # jax or numpy array
        a = np.asarray(node)
        if a.dtype == np.dtype("bfloat16") if hasattr(np, "bfloat16") else False:
            pass
        dtype = str(a.dtype)
        if dtype == "bfloat16":
            a = a.view(np.uint16)
        return {_ARR: True, "dtype": dtype, "shape": list(a.shape),
                "data": a.tobytes()}
    if isinstance(node, (int, float, str, bool)) or node is None:
        return node
    raise TypeError(f"cannot serialize {type(node)}")


def _decode(node):
    if isinstance(node, dict):
        if node.get(_ARR):
            dtype = node["dtype"]
            if dtype == "bfloat16":
                import ml_dtypes  # noqa: F401 (via jax)
                a = np.frombuffer(node["data"], np.uint16).reshape(node["shape"])
                return a.view(ml_dtypes.bfloat16)
            return np.frombuffer(node["data"], np.dtype(dtype)).reshape(node["shape"]).copy()
        if _TUPLE in node:
            return tuple(_decode(v) for v in node[_TUPLE])
        return {k: _decode(v) for k, v in node.items()}
    if isinstance(node, list):
        return [_decode(v) for v in node]
    return node


def save_pytree(tree: Any, path: str, *, level: int = 3) -> None:
    payload = msgpack.packb(_encode(tree), use_bin_type=True)
    if zstd is not None:
        comp = zstd.ZstdCompressor(level=level).compress(payload)
    else:
        comp = zlib.compress(payload, level)
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(comp)
        os.replace(tmp, path)  # atomic
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def load_pytree(path: str) -> Any:
    with open(path, "rb") as f:
        comp = f.read()
    if comp[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise RuntimeError(
                f"{path} is zstd-compressed but zstandard is not installed")
        payload = zstd.ZstdDecompressor().decompress(comp)
    else:
        payload = zlib.decompress(comp)
    return _decode(msgpack.unpackb(payload, raw=False))
