"""Rolling checkpoint manager with elastic, crash-tolerant restore.

- ``save(step, state)``: atomic write + retention of the last ``keep`` steps.
  Retention never removes the checkpoint just written (even when ``keep`` is
  misconfigured to 0) and tolerates concurrent pruners — a file already gone
  is a success, not a crash.
- ``restore_latest(mesh=None, specs=None)``: loads numpy trees and, when a
  mesh is given, device_puts each leaf under the *current* mesh's sharding —
  the checkpoint is mesh-shape-agnostic, so restoring onto a smaller surviving
  mesh (node failure) or a grown one (elastic scale-up) is the same code path.
  A truncated/corrupt newest checkpoint (crash mid-write on a non-atomic
  filesystem, torn copy) is *skipped with a warning* and the previous step is
  restored instead — an interrupted save costs at most ``ckpt_every`` steps
  of progress, never the whole run.
"""
from __future__ import annotations

import os
import re
import warnings
from typing import Any, List, Optional

import jax

from repro import obs
from repro.checkpoint.store import CheckpointCorrupt, load_pytree, save_pytree

_PAT = re.compile(r"^step_(\d+)\.ckpt$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}.ckpt")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _PAT.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, step: int, state: Any) -> str:
        with obs.get_telemetry().span("ckpt.save", step=step):
            # pull to host (works for sharded arrays: addressable data
            # gathered)
            host_state = jax.tree_util.tree_map(
                lambda a: jax.device_get(a) if hasattr(a, "dtype") else a,
                state)
            path = self._path(step)
            save_pytree({"step": step, "state": host_state}, path)
            # retention: keep >= 1 whatever the configuration says — pruning
            # the checkpoint that was just written would turn save() into
            # delete()
            keep = max(int(self.keep), 1)
            for s in self.steps()[:-keep]:
                if s == step:
                    continue
                try:
                    os.unlink(self._path(s))
                except FileNotFoundError:
                    pass  # a concurrent pruner/restart got there first
            return path

    def restore(self, step: int, *, mesh=None, specs: Optional[Any] = None):
        with obs.get_telemetry().span("ckpt.restore", step=step):
            payload = load_pytree(self._path(step))
            state = payload["state"]
            if mesh is not None:
                state = shard_restore(state, mesh, specs)
            return payload["step"], state

    def restore_latest(self, *, mesh=None, specs: Optional[Any] = None):
        """Restore the newest *readable* checkpoint.

        Walks steps newest-first; a corrupt or vanished file (crash between
        temp write and rename leaves only a ``.tmp``; a torn write fails the
        store checksum) is skipped with a warning and the previous step is
        tried. Returns None when no checkpoint can be read.
        """
        for step in reversed(self.steps()):
            try:
                return self.restore(step, mesh=mesh, specs=specs)
            except (CheckpointCorrupt, FileNotFoundError, EOFError,
                    OSError) as e:
                warnings.warn(
                    f"checkpoint step {step} unreadable ({e}); falling back "
                    f"to the previous step", RuntimeWarning, stacklevel=2)
        return None


def shard_restore(state, mesh, specs=None):
    """device_put a host pytree under ``mesh`` with per-leaf PartitionSpecs.

    specs=None -> infer from parameter names via models.sharding rules,
    dropping axes that don't divide (elastic-safe).
    """
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    if specs is None:
        from repro.models.sharding import mesh_safe_specs
        specs = mesh_safe_specs(state, mesh)

    def put(a, spec):
        if not hasattr(a, "dtype"):
            return a
        return jax.device_put(a, NamedSharding(mesh, spec if spec is not None else P()))

    return jax.tree_util.tree_map(put, state, specs)
