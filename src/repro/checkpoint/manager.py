"""Rolling checkpoint manager with elastic restore.

- ``save(step, state)``: atomic write + retention of the last ``keep`` steps.
- ``restore_latest(mesh=None, specs=None)``: loads numpy trees and, when a
  mesh is given, device_puts each leaf under the *current* mesh's sharding —
  the checkpoint is mesh-shape-agnostic, so restoring onto a smaller surviving
  mesh (node failure) or a grown one (elastic scale-up) is the same code path.
  This is Swan's execution-choice migration applied to cluster state.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax

from repro.checkpoint.store import load_pytree, save_pytree

_PAT = re.compile(r"^step_(\d+)\.ckpt$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}.ckpt")

    def steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = _PAT.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, step: int, state: Any) -> str:
        # pull to host (works for sharded arrays: addressable data gathered)
        host_state = jax.tree_util.tree_map(
            lambda a: jax.device_get(a) if hasattr(a, "dtype") else a, state)
        path = self._path(step)
        save_pytree({"step": step, "state": host_state}, path)
        for s in self.steps()[:-self.keep]:
            os.unlink(self._path(s))
        return path

    def restore(self, step: int, *, mesh=None, specs: Optional[Any] = None):
        payload = load_pytree(self._path(step))
        state = payload["state"]
        if mesh is not None:
            state = shard_restore(state, mesh, specs)
        return payload["step"], state

    def restore_latest(self, *, mesh=None, specs: Optional[Any] = None):
        steps = self.steps()
        if not steps:
            return None
        return self.restore(steps[-1], mesh=mesh, specs=specs)


def shard_restore(state, mesh, specs=None):
    """device_put a host pytree under ``mesh`` with per-leaf PartitionSpecs.

    specs=None -> infer from parameter names via models.sharding rules,
    dropping axes that don't divide (elastic-safe).
    """
    from jax.sharding import NamedSharding
    from jax.sharding import PartitionSpec as P

    if specs is None:
        from repro.models.sharding import mesh_safe_specs
        specs = mesh_safe_specs(state, mesh)

    def put(a, spec):
        if not hasattr(a, "dtype"):
            return a
        return jax.device_put(a, NamedSharding(mesh, spec if spec is not None else P()))

    return jax.tree_util.tree_map(put, state, specs)
