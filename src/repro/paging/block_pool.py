"""Host-side block-pool allocator for paged KV caches.

The KV cache is carved into fixed-size blocks of ``block_size`` token
positions. Sequences own ordered lists of physical block ids (their *block
table*); allocation and free are O(1) free-list operations. This is the
memory-manager half of the paged subsystem — the device side (pool arrays +
the block-table flash-decode kernel) never sees the free list, only the
(B, max_blocks_per_seq) int32 tables built from it.

Physical block 0 is reserved as the *null block*: retired serving slots keep
decoding masked garbage until re-admission, and their table rows are reset to
0 so those writes land in a block no live sequence owns — stale table entries
pointing at freed (possibly re-allocated) blocks would otherwise corrupt the
new owner's cache.

Exhaustion raises ``BlockPoolExhausted`` instead of handing out a live
block twice; the serve engine checks ``can_allocate`` at admission and
leaves requests queued rather than corrupting resident sequences.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Mapping, Optional


class BlockPoolExhausted(RuntimeError):
    """No free blocks left; the caller must retire or wait, never overwrite."""


def _blocks_for(n_tokens: int, block_size: int) -> int:
    return max(1, -(-int(n_tokens) // block_size))


class BlockPool:
    """Fixed-size-block allocator with per-sequence block tables."""

    NULL_BLOCK = 0

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # LIFO free list: freshly-freed blocks are reused first (their pool
        # pages are the ones most likely still warm in cache)
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self._tables: Dict[Hashable, List[int]] = {}
        self.peak_blocks_in_use = 0
        self.total_allocs = 0

    # -- capacity ----------------------------------------------------------

    @property
    def num_usable(self) -> int:
        """Allocatable blocks (total minus the reserved null block)."""
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_usable - self.num_free

    def blocks_for(self, n_tokens: int) -> int:
        return _blocks_for(n_tokens, self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_for(n_tokens) <= self.num_free

    # -- alloc / free ------------------------------------------------------

    def _take_block(self) -> int:
        if not self._free:
            raise BlockPoolExhausted(
                f"pool exhausted: {self.num_usable} blocks "
                f"({self.num_usable * self.block_size} token slots) all live")
        self.total_allocs += 1
        blk = self._free.pop()
        in_use = self.blocks_in_use
        if in_use > self.peak_blocks_in_use:
            self.peak_blocks_in_use = in_use
        return blk

    def allocate(self, seq_id: Hashable, n_tokens: int) -> List[int]:
        """Allocate blocks covering ``n_tokens`` positions for a new sequence."""
        if seq_id in self._tables:
            raise ValueError(f"sequence {seq_id!r} already has a block table")
        need = self.blocks_for(n_tokens)
        if need > self.num_free:
            raise BlockPoolExhausted(
                f"need {need} blocks for {n_tokens} tokens, "
                f"only {self.num_free} free")
        table = [self._take_block() for _ in range(need)]
        self._tables[seq_id] = table
        return list(table)

    def append_token(self, seq_id: Hashable, position: int) -> Optional[int]:
        """Ensure the block holding ``position`` exists (allocate-on-boundary).

        Returns the newly-allocated physical block id, or None when the
        position already lands in an owned block.
        """
        table = self._tables[seq_id]
        blk_idx = int(position) // self.block_size
        if blk_idx < len(table):
            return None
        if blk_idx != len(table):
            raise ValueError(
                f"non-contiguous append: position {position} wants block "
                f"{blk_idx}, sequence owns {len(table)}")
        blk = self._take_block()
        table.append(blk)
        return blk

    def free(self, seq_id: Hashable) -> int:
        """Return a sequence's blocks to the free list; returns count freed."""
        table = self._tables.pop(seq_id)
        self._free.extend(table)
        return len(table)

    # -- introspection -----------------------------------------------------

    def block_table(self, seq_id: Hashable) -> List[int]:
        return list(self._tables[seq_id])

    def owned_blocks(self, seq_id: Hashable) -> int:
        return len(self._tables.get(seq_id, ()))

    def utilization(self) -> float:
        """Fraction of usable blocks currently live."""
        return self.blocks_in_use / max(self.num_usable, 1)

    def fragmentation(self, live_tokens: Mapping[Hashable, int]) -> float:
        """Internal fragmentation: fraction of allocated token slots not
        backing a live token. ``live_tokens`` maps seq_id -> valid positions
        (the serve engine's per-slot cache_len)."""
        allocated = sum(len(t) for t in self._tables.values()) * self.block_size
        if not allocated:
            return 0.0
        live = sum(min(int(live_tokens.get(s, 0)), len(t) * self.block_size)
                   for s, t in self._tables.items())
        return 1.0 - live / allocated

    def stats(self, live_tokens: Optional[Mapping[Hashable, int]] = None) -> dict:
        out = {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "blocks_in_use": self.blocks_in_use,
            "num_free": self.num_free,
            "peak_blocks_in_use": self.peak_blocks_in_use,
            "utilization": round(self.utilization(), 4),
            "total_allocs": self.total_allocs,
            "n_sequences": len(self._tables),
        }
        if live_tokens is not None:
            out["fragmentation"] = round(self.fragmentation(live_tokens), 4)
        return out
