"""Host-side block-pool allocator for paged KV caches.

The KV cache is carved into fixed-size blocks of ``block_size`` token
positions. Sequences own ordered lists of physical block ids (their *block
table*); allocation and free are O(1) free-list operations. This is the
memory-manager half of the paged subsystem — the device side (pool arrays +
the block-table flash-decode kernel) never sees the free list, only the
(B, max_blocks_per_seq) int32 tables built from it.

Physical block 0 is reserved as the *null block*: retired serving slots keep
decoding masked garbage until re-admission, and their table rows are reset to
0 so those writes land in a block no live sequence owns — stale table entries
pointing at freed (possibly re-allocated) blocks would otherwise corrupt the
new owner's cache.

Blocks are **refcounted** so prompt-prefix deduplication can map one physical
block into many sequences' tables (``allocate(shared=...)``): a block returns
to the free list only when its last owner releases it. ``append_token`` into
a block another sequence still references triggers **copy-on-write** — the
appender gets a fresh block and the caller is told to copy the device data
(the pool itself never touches device arrays).

A block whose refcount drops to zero while a prefix cache still indexes it
parks on the **cached-free** list instead of the free list: still allocatable
(evicted LRU via ``on_evict`` so the index can drop its entries) but
resurrectable by a later prefix hit at zero cost.

Exhaustion raises ``BlockPoolExhausted`` instead of handing out a live
block twice; the serve engine checks ``can_allocate`` at admission and
leaves requests queued rather than corrupting resident sequences.
"""
from __future__ import annotations

import dataclasses
from typing import (Callable, Dict, Hashable, List, Mapping, Optional,
                    Sequence, Tuple)


class BlockPoolExhausted(RuntimeError):
    """No free blocks left; the caller must retire or wait, never overwrite."""


@dataclasses.dataclass
class BlockEvent:
    """What ``append_token`` did to back a write position.

    ``kind == "alloc"``: ``block`` was freshly taken on a block boundary.
    ``kind == "cow"``: the position's block was shared; the sequence now owns
    the private copy ``block`` and the caller must copy device data from
    ``src`` (the still-shared original) before writing.
    """
    kind: str  # "alloc" | "cow"
    block: int
    src: Optional[int] = None


def _blocks_for(n_tokens: int, block_size: int) -> int:
    return max(1, -(-int(n_tokens) // block_size))


class BlockPool:
    """Fixed-size-block allocator with per-sequence block tables."""

    NULL_BLOCK = 0

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is the null block)")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        # LIFO free list: freshly-freed blocks are reused first (their pool
        # pages are the ones most likely still warm in cache)
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self._tables: Dict[Hashable, List[int]] = {}
        # per-block owner count; 0 = free or cached-free. The null block's
        # refcount is pinned at 1 so no path can ever free or hand it out.
        self._refs: List[int] = [0] * self.num_blocks
        self._refs[self.NULL_BLOCK] = 1
        # blocks with refcount 0 that a prefix index still maps: insertion-
        # ordered dict as an LRU (oldest entry evicted first). Values unused.
        self._cached_free: Dict[int, None] = {}
        # called with the block id when a cached-free block is evicted to
        # satisfy an allocation, so the prefix index drops its entries
        self.on_evict: Optional[Callable[[int], None]] = None
        # cache_filter(block) -> True parks a ref-0 block on the cached-free
        # list instead of the free list (a prefix index still maps it); set
        # by PagedKVCache so every release path — free() and the COW decref —
        # routes identically
        self.cache_filter: Optional[Callable[[int], bool]] = None
        self.peak_blocks_in_use = 0
        self.total_allocs = 0
        self.total_shares = 0
        self.total_cow = 0
        self.total_evictions = 0

    # -- capacity ----------------------------------------------------------

    @property
    def num_usable(self) -> int:
        """Allocatable blocks (total minus the reserved null block)."""
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        """Blocks allocatable right now (free list + evictable cached)."""
        return len(self._free) + len(self._cached_free)

    @property
    def num_cached(self) -> int:
        """Unreferenced blocks kept alive for prefix reuse (evictable)."""
        return len(self._cached_free)

    @property
    def blocks_in_use(self) -> int:
        return self.num_usable - self.num_free

    @property
    def shared_blocks(self) -> int:
        """Physical blocks mapped by more than one sequence."""
        return sum(1 for r in self._refs[1:] if r > 1)

    def blocks_for(self, n_tokens: int) -> int:
        return _blocks_for(n_tokens, self.block_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.blocks_for(n_tokens) <= self.num_free

    def refcount(self, block: int) -> int:
        return self._refs[block]

    def is_cached(self, block: int) -> bool:
        return block in self._cached_free

    # -- alloc / free ------------------------------------------------------

    def _take_block(self) -> int:
        if self._free:
            blk = self._free.pop()
        elif self._cached_free:
            # evict the least-recently-cached block and let the prefix
            # index forget it before it is recycled under a new identity
            blk = next(iter(self._cached_free))
            del self._cached_free[blk]
            self.total_evictions += 1
            if self.on_evict is not None:
                self.on_evict(blk)
        else:
            raise BlockPoolExhausted(
                f"pool exhausted: {self.num_usable} blocks "
                f"({self.num_usable * self.block_size} token slots) all live")
        self.total_allocs += 1
        self._refs[blk] = 1
        in_use = self.blocks_in_use
        if in_use > self.peak_blocks_in_use:
            self.peak_blocks_in_use = in_use
        return blk

    def allocate(self, seq_id: Hashable, n_tokens: int,
                 shared: Sequence[int] = ()) -> List[int]:
        """Allocate blocks covering ``n_tokens`` positions for a new sequence.

        ``shared`` maps already-populated physical blocks (a prefix-cache
        hit) into the head of the new table: each is refcounted up — and
        resurrected off the cached-free list when unowned — instead of
        taken from the free list. Fails atomically on exhaustion.
        """
        if seq_id in self._tables:
            raise ValueError(f"sequence {seq_id!r} already has a block table")
        shared = list(shared)
        need = self.blocks_for(n_tokens)
        if len(shared) > need:
            raise ValueError(f"{len(shared)} shared blocks exceed the "
                             f"{need} needed for {n_tokens} tokens")
        fresh = need - len(shared)
        # a cached-free shared block is about to be resurrected, not drawn
        # from the allocatable budget
        budget = len(self._free) + len(self._cached_free) \
            - sum(1 for b in shared if b in self._cached_free)
        if fresh > budget:
            raise BlockPoolExhausted(
                f"need {fresh} blocks for {n_tokens} tokens "
                f"({len(shared)} shared), only {budget} free")
        for blk in shared:
            self._adopt(blk)
        table = shared + [self._take_block() for _ in range(fresh)]
        self._tables[seq_id] = table
        return list(table)

    def _adopt(self, blk: int) -> None:
        """Take a reference on a prefix-hit block."""
        if blk == self.NULL_BLOCK:
            raise ValueError("cannot share the null block")
        if self._refs[blk] == 0:
            if blk not in self._cached_free:
                raise ValueError(f"block {blk} is free, not shareable")
            del self._cached_free[blk]
            in_use = self.blocks_in_use
            if in_use > self.peak_blocks_in_use:
                self.peak_blocks_in_use = in_use
        self._refs[blk] += 1
        self.total_shares += 1

    def append_token(self, seq_id: Hashable, position: int) -> Optional[BlockEvent]:
        """Make the block holding ``position`` privately writable.

        Allocates on a block boundary; a position landing in a block other
        sequences (or only the prefix cache) still reference triggers
        copy-on-write. Returns the :class:`BlockEvent` describing what
        happened, or None when the position already lands in a private
        owned block.
        """
        table = self._tables[seq_id]
        blk_idx = int(position) // self.block_size
        if blk_idx < len(table):
            blk = table[blk_idx]
            if self._refs[blk] > 1:
                # shared: divergence point — the appender pays for the copy
                new = self._take_block()
                table[blk_idx] = new
                self._release(blk)
                self.total_cow += 1
                return BlockEvent("cow", new, src=blk)
            return None
        if blk_idx != len(table):
            raise ValueError(
                f"non-contiguous append: position {position} wants block "
                f"{blk_idx}, sequence owns {len(table)}")
        blk = self._take_block()
        table.append(blk)
        return BlockEvent("alloc", blk)

    def free(self, seq_id: Hashable) -> int:
        """Release a sequence's references; returns the table length.

        A block drops to the free list only when its last reference goes —
        or to the cached-free list when ``cache_filter`` claims it.
        """
        table = self._tables.pop(seq_id)
        for blk in table:
            self._release(blk)
        return len(table)

    def _release(self, blk: int) -> None:
        if self._refs[blk] <= 0:
            raise RuntimeError(f"double free of block {blk}")
        self._refs[blk] -= 1
        if self._refs[blk] == 0:
            if self.cache_filter is not None and self.cache_filter(blk):
                self._cached_free[blk] = None
            else:
                self._free.append(blk)

    def uncache(self, blk: int) -> None:
        """Drop a cached-free block to the free list (index removed it)."""
        if blk in self._cached_free:
            del self._cached_free[blk]
            self._free.append(blk)

    # -- introspection -----------------------------------------------------

    def block_table(self, seq_id: Hashable) -> List[int]:
        return list(self._tables[seq_id])

    def owned_blocks(self, seq_id: Hashable) -> int:
        return len(self._tables.get(seq_id, ()))

    def utilization(self) -> float:
        """Fraction of usable blocks currently live."""
        return self.blocks_in_use / max(self.num_usable, 1)

    def fragmentation(self, live_tokens: Mapping[Hashable, int]) -> float:
        """Internal fragmentation: fraction of allocated token slots not
        backing a live token. ``live_tokens`` maps seq_id -> valid positions
        (the serve engine's per-slot cache_len). Refcount-aware: a block
        shared by many sequences contributes its slots once, covered by the
        deepest owner's live length."""
        bs = self.block_size
        covered: Dict[int, int] = {}  # physical block -> live slots backed
        for s, t in self._tables.items():
            live = int(live_tokens.get(s, 0))
            for i, blk in enumerate(t):
                c = max(0, min(live - i * bs, bs))
                if c > covered.get(blk, -1):
                    covered[blk] = c
        if not covered:
            return 0.0
        allocated = len(covered) * bs
        return 1.0 - sum(covered.values()) / allocated

    def stats(self, live_tokens: Optional[Mapping[Hashable, int]] = None) -> dict:
        out = {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "blocks_in_use": self.blocks_in_use,
            "num_free": self.num_free,
            "cached_blocks": self.num_cached,
            "shared_blocks": self.shared_blocks,
            "peak_blocks_in_use": self.peak_blocks_in_use,
            "utilization": round(self.utilization(), 4),
            "total_allocs": self.total_allocs,
            "total_shares": self.total_shares,
            "total_cow": self.total_cow,
            "total_evictions": self.total_evictions,
            "n_sequences": len(self._tables),
        }
        if live_tokens is not None:
            out["fragmentation"] = round(self.fragmentation(live_tokens), 4)
        return out

    def publish_metrics(self, metrics, stats: Optional[dict] = None,
                        **labels) -> None:
        """Export pool accounting as gauges into a ``repro.obs``
        MetricsRegistry. ``stats`` may be a precomputed :meth:`stats` dict
        (e.g. one that already carries fragmentation from live tokens)."""
        st = stats if stats is not None else self.stats()
        for key in ("blocks_in_use", "num_free", "cached_blocks",
                    "shared_blocks", "peak_blocks_in_use", "utilization",
                    "fragmentation", "total_allocs", "total_shares",
                    "total_cow", "total_evictions", "n_sequences"):
            if key in st:
                metrics.gauge(f"pool_{key}").labels(**labels).set(
                    float(st[key]))

    def check_invariants(self) -> None:
        """Assert conservation: every usable block is exactly one of free,
        cached-free, or referenced; refcounts equal table occurrences plus
        (never) the null block. Test/chaos hook — O(num_blocks)."""
        owners: Dict[int, int] = {}
        for t in self._tables.values():
            for blk in t:
                owners[blk] = owners.get(blk, 0) + 1
        free_set = set(self._free)
        cached = set(self._cached_free)
        assert not (free_set & cached), "block both free and cached"
        assert self.NULL_BLOCK not in free_set | cached, "null block freed"
        assert self.NULL_BLOCK not in owners, "null block in a table"
        for blk in range(1, self.num_blocks):
            refs = self._refs[blk]
            assert refs == owners.get(blk, 0), \
                f"block {blk}: refcount {refs} != {owners.get(blk, 0)} owners"
            in_free = blk in free_set or blk in cached
            assert (refs == 0) == in_free, \
                f"block {blk}: refs={refs} but free/cached={in_free}"
        total = len(free_set) + len(cached) + len(owners)
        assert total == self.num_usable, \
            f"leaked blocks: {self.num_usable - total}"
