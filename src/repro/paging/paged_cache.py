"""Device-facing half of the paged KV cache.

``PagedKVCache`` binds a ``BlockPool`` to the dense (max_batch,
max_blocks_per_seq) int32 block-table array the decode step ships to the
device: slot admission/append/release keep the numpy table in sync with the
pool's per-sequence tables, and retired slots' rows reset to the null block
so their masked-garbage decode writes can never land in a live block.

The pool *arrays* themselves (``(num_blocks, block_size, ...)`` per layer)
belong to the model (``model.init_paged_cache``) and flow through the jitted
decode step donated, exactly like the contiguous slabs; this class only
manages which physical block backs which (slot, logical-block) coordinate.

Prefix sharing: a :class:`PrefixIndex` hash-conses full prompt-prefix blocks
(chained digests, so a block's key commits to everything before it) plus the
partially-filled final prompt block (keyed by its token count). Admission
looks up the longest indexed prefix and maps those physical blocks into the
new sequence's table via the pool's refcounts — identical prompt prefixes
cost their KV once. Divergence is handled by the pool's copy-on-write.

Dirty-row tracking: every mutation to a table row records the slot, so the
serve engine ships only changed rows to the device instead of re-uploading
the whole dense table every decode step.

``gather_paged_kv`` is the naive oracle: materialize a sequence's contiguous
view by indexing the pool through its table. The paged Pallas kernel must
match it (and hence the contiguous path) at f32.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Hashable, List, Mapping, Optional, Sequence, Set, Tuple

import jax.numpy as jnp
import numpy as np

from repro.paging.block_pool import BlockEvent, BlockPool


def gather_paged_kv(pool, block_table):
    """Materialize contiguous caches from a block pool (naive oracle).

    pool: (num_blocks, block_size, ...) — one layer's K, V or latent pool.
    block_table: (B, T) int32 physical block ids per logical block.
    Returns (B, T * block_size, ...): the virtual contiguous cache each
    sequence sees; positions past its valid length read whatever the mapped
    (or null) block holds and must be masked by ``kv_len`` downstream.
    """
    table = jnp.clip(jnp.asarray(block_table, jnp.int32), 0,
                     pool.shape[0] - 1)
    gathered = pool[table]  # (B, T, block_size, ...)
    B, T, bs = gathered.shape[:3]
    return gathered.reshape((B, T * bs) + gathered.shape[3:])


class PrefixIndex:
    """Hash-cons of populated prompt-prefix blocks.

    Keys are *chained* sha1 digests — block i's key hashes block i's tokens
    into the digest of blocks 0..i-1 — so equal keys imply equal full
    prefixes, never just equal block contents. The partially-filled final
    prompt block gets its own key tagged with the token count, enabling
    sharing right up to the divergence point (the pool's COW takes over on
    the first append). First insertion wins; later identical prefixes map
    onto the existing block.
    """

    def __init__(self, block_size: int):
        self.block_size = int(block_size)
        self._map: Dict[str, int] = {}          # key -> physical block
        self._keys: Dict[int, List[str]] = {}   # block -> keys (eviction)
        self.lookups = 0   # prompt blocks examined at admission
        self.hits = 0      # prompt blocks resolved to an indexed block

    def __len__(self) -> int:
        return len(self._map)

    def maps_block(self, blk: int) -> bool:
        return blk in self._keys

    def _chain_keys(self, tokens: np.ndarray) -> Tuple[List[str], Optional[str]]:
        """(full-block keys, partial-tail key or None) for a prompt."""
        tokens = np.asarray(tokens, np.int32)
        bs = self.block_size
        h = hashlib.sha1()
        keys = []
        n_full = len(tokens) // bs
        for i in range(n_full):
            h.update(tokens[i * bs:(i + 1) * bs].tobytes())
            keys.append(h.hexdigest())
        r = len(tokens) - n_full * bs
        partial = None
        if r:
            h.update(b"partial:%d:" % r)
            h.update(tokens[n_full * bs:].tobytes())
            partial = "p" + h.hexdigest()
        return keys, partial

    def match(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest indexed prefix of ``tokens``: (physical blocks, tokens
        covered). The partial tail only matches when every full block before
        it did — anything else would splice mismatched prefixes."""
        keys, partial = self._chain_keys(tokens)
        bs = self.block_size
        blocks: List[int] = []
        for key in keys:
            blk = self._map.get(key)
            if blk is None:
                break
            blocks.append(blk)
        covered = len(blocks) * bs
        if partial is not None and len(blocks) == len(keys):
            blk = self._map.get(partial)
            if blk is not None:
                blocks.append(blk)
                covered = len(tokens)
        self.lookups += len(keys) + (1 if partial is not None else 0)
        self.hits += len(blocks)
        return blocks, covered

    def insert(self, tokens: np.ndarray, blocks: Sequence[int]) -> int:
        """Index a freshly-prefilled prompt's blocks; returns insertions.

        ``blocks`` is the sequence's table prefix covering the prompt
        (full blocks plus the partial tail block, if any)."""
        keys, partial = self._chain_keys(tokens)
        if partial is not None:
            keys = keys + [partial]
        added = 0
        for key, blk in zip(keys, blocks):
            if key in self._map:
                continue  # an identical prefix beat us to it
            self._map[key] = blk
            self._keys.setdefault(blk, []).append(key)
            added += 1
        return added

    def forget_block(self, blk: int) -> None:
        """Drop every key mapping to ``blk`` (pool evicted/recycled it)."""
        for key in self._keys.pop(blk, ()):
            self._map.pop(key, None)

    def stats(self) -> dict:
        return {
            "entries": len(self._map),
            "blocks": len(self._keys),
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": round(self.hits / self.lookups, 4)
            if self.lookups else 0.0,
        }


class PagedKVCache:
    """Block pool + per-slot block-table rows for the serve engine."""

    def __init__(self, num_blocks: int, block_size: int, max_batch: int,
                 max_blocks_per_seq: int, prefix_cache: bool = False):
        self.pool = BlockPool(num_blocks, block_size)
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        # rows default to the null block: idle slots' masked decode writes
        # land somewhere no live sequence reads
        self.tables = np.zeros((max_batch, max_blocks_per_seq), np.int32)
        self._slot_seq: List[Optional[Hashable]] = [None] * max_batch
        # rows touched since the engine last shipped them to the device
        self._dirty: Set[int] = set(range(max_batch))
        self.prefix: Optional[PrefixIndex] = None
        if prefix_cache:
            self.prefix = PrefixIndex(block_size)
            self.pool.cache_filter = self.prefix.maps_block
            self.pool.on_evict = self.prefix.forget_block

    # -- prefix sharing ----------------------------------------------------

    def match_prefix(self, tokens: np.ndarray) -> Tuple[List[int], int]:
        """Longest indexed prefix: (shared physical blocks, tokens covered).
        ([], 0) when the prefix cache is off."""
        if self.prefix is None:
            return [], 0
        return self.prefix.match(tokens)

    def index_prompt(self, slot: int, tokens: np.ndarray) -> int:
        """Index the slot's populated prompt blocks for future sharing."""
        if self.prefix is None:
            return 0
        n = self.pool.blocks_for(max(len(tokens), 1))
        return self.prefix.insert(tokens, list(self.tables[slot, :n]))

    # -- slot lifecycle ----------------------------------------------------

    def admit(self, slot: int, seq_id: Hashable, n_tokens: int,
              shared: Sequence[int] = ()) -> List[int]:
        """Allocate blocks for a prompt and install them in the slot's row.

        ``shared`` (from :meth:`match_prefix`) maps already-populated blocks
        into the head of the table via pool refcounts."""
        blocks = self.pool.allocate(seq_id, n_tokens, shared=shared)
        if len(blocks) > self.max_blocks_per_seq:
            self.pool.free(seq_id)
            raise ValueError(
                f"{n_tokens} tokens need {len(blocks)} blocks > table width "
                f"{self.max_blocks_per_seq}")
        self.tables[slot, :] = BlockPool.NULL_BLOCK
        self.tables[slot, :len(blocks)] = blocks
        self._slot_seq[slot] = seq_id
        self._dirty.add(slot)
        return blocks

    def append(self, slot: int, position: int) -> Optional[BlockEvent]:
        """Allocate-on-boundary (or copy-on-write) for the decode write at
        ``position``. Returns the pool's :class:`BlockEvent` — the engine
        must device-copy ``event.src`` into ``event.block`` on a "cow"."""
        if position // self.block_size >= self.max_blocks_per_seq:
            raise ValueError(f"position {position} exceeds the table width "
                             f"({self.max_blocks_per_seq} blocks of "
                             f"{self.block_size})")
        seq_id = self._slot_seq[slot]
        event = self.pool.append_token(seq_id, position)
        if event is not None:
            self.tables[slot, position // self.block_size] = event.block
            self._dirty.add(slot)
        return event

    def release(self, slot: int) -> int:
        """Free the slot's blocks and reset its row to the null block.

        Indexed prompt blocks park on the pool's cached-free list (still
        allocatable, but a later identical prefix resurrects them free)."""
        seq_id = self._slot_seq[slot]
        self._slot_seq[slot] = None
        self.tables[slot, :] = BlockPool.NULL_BLOCK
        self._dirty.add(slot)
        return self.pool.free(seq_id)

    def slot_blocks(self, slot: int) -> List[int]:
        """The slot's live physical blocks, in logical order (swap-out)."""
        return self.pool.block_table(self._slot_seq[slot])

    # -- dirty-row shipping --------------------------------------------------

    def take_dirty(self) -> List[int]:
        """Rows mutated since the last call; clears the set. The engine
        updates only these rows on the device-resident table."""
        rows = sorted(self._dirty)
        self._dirty.clear()
        return rows

    def stats(self, live_tokens: Optional[Mapping[Hashable, int]] = None) -> dict:
        out = self.pool.stats(live_tokens)
        if self.prefix is not None:
            out["prefix"] = self.prefix.stats()
        return out

    def publish_metrics(self, metrics, stats: Optional[dict] = None,
                        **labels) -> None:
        """Pool gauges plus prefix-sharing hit-rate under one registry."""
        st = stats if stats is not None else self.stats()
        self.pool.publish_metrics(metrics, stats=st, **labels)
        prefix = st.get("prefix")
        if prefix:
            metrics.gauge("prefix_hit_rate").labels(**labels).set(
                float(prefix["hit_rate"]))
            metrics.gauge("prefix_entries").labels(**labels).set(
                float(prefix["entries"]))
            metrics.gauge("prefix_lookups").labels(**labels).set(
                float(prefix["lookups"]))
