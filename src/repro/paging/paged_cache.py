"""Device-facing half of the paged KV cache.

``PagedKVCache`` binds a ``BlockPool`` to the dense (max_batch,
max_blocks_per_seq) int32 block-table array the decode step ships to the
device: slot admission/append/release keep the numpy table in sync with the
pool's per-sequence tables, and retired slots' rows reset to the null block
so their masked-garbage decode writes can never land in a live block.

The pool *arrays* themselves (``(num_blocks, block_size, ...)`` per layer)
belong to the model (``model.init_paged_cache``) and flow through the jitted
decode step donated, exactly like the contiguous slabs; this class only
manages which physical block backs which (slot, logical-block) coordinate.

``gather_paged_kv`` is the naive oracle: materialize a sequence's contiguous
view by indexing the pool through its table. The paged Pallas kernel must
match it (and hence the contiguous path) at f32.
"""
from __future__ import annotations

from typing import Hashable, List, Mapping, Optional

import jax.numpy as jnp
import numpy as np

from repro.paging.block_pool import BlockPool


def gather_paged_kv(pool, block_table):
    """Materialize contiguous caches from a block pool (naive oracle).

    pool: (num_blocks, block_size, ...) — one layer's K, V or latent pool.
    block_table: (B, T) int32 physical block ids per logical block.
    Returns (B, T * block_size, ...): the virtual contiguous cache each
    sequence sees; positions past its valid length read whatever the mapped
    (or null) block holds and must be masked by ``kv_len`` downstream.
    """
    table = jnp.clip(jnp.asarray(block_table, jnp.int32), 0,
                     pool.shape[0] - 1)
    gathered = pool[table]  # (B, T, block_size, ...)
    B, T, bs = gathered.shape[:3]
    return gathered.reshape((B, T * bs) + gathered.shape[3:])


class PagedKVCache:
    """Block pool + per-slot block-table rows for the serve engine."""

    def __init__(self, num_blocks: int, block_size: int, max_batch: int,
                 max_blocks_per_seq: int):
        self.pool = BlockPool(num_blocks, block_size)
        self.block_size = block_size
        self.max_blocks_per_seq = max_blocks_per_seq
        # rows default to the null block: idle slots' masked decode writes
        # land somewhere no live sequence reads
        self.tables = np.zeros((max_batch, max_blocks_per_seq), np.int32)
        self._slot_seq: List[Optional[Hashable]] = [None] * max_batch

    def admit(self, slot: int, seq_id: Hashable, n_tokens: int) -> List[int]:
        """Allocate blocks for a prompt and install them in the slot's row."""
        blocks = self.pool.allocate(seq_id, n_tokens)
        if len(blocks) > self.max_blocks_per_seq:
            self.pool.free(seq_id)
            raise ValueError(
                f"{n_tokens} tokens need {len(blocks)} blocks > table width "
                f"{self.max_blocks_per_seq}")
        self.tables[slot, :] = BlockPool.NULL_BLOCK
        self.tables[slot, :len(blocks)] = blocks
        self._slot_seq[slot] = seq_id
        return blocks

    def append(self, slot: int, position: int) -> Optional[int]:
        """Allocate-on-boundary for the decode write at ``position``."""
        if position // self.block_size >= self.max_blocks_per_seq:
            raise ValueError(f"position {position} exceeds the table width "
                             f"({self.max_blocks_per_seq} blocks of "
                             f"{self.block_size})")
        seq_id = self._slot_seq[slot]
        blk = self.pool.append_token(seq_id, position)
        if blk is not None:
            self.tables[slot, position // self.block_size] = blk
        return blk

    def release(self, slot: int) -> int:
        """Free the slot's blocks and reset its row to the null block."""
        seq_id = self._slot_seq[slot]
        self._slot_seq[slot] = None
        self.tables[slot, :] = BlockPool.NULL_BLOCK
        return self.pool.free(seq_id)

    def stats(self, live_tokens: Optional[Mapping[Hashable, int]] = None) -> dict:
        return self.pool.stats(live_tokens)
