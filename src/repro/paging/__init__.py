"""Paged KV-cache subsystem: block-pool allocation + block-table caches.

``BlockPool`` is the host-side allocator (fixed-size KV blocks, refcounted
free-list alloc/free with copy-on-write and a cached-free prefix tier,
per-sequence block tables, utilization/fragmentation stats);
``PagedKVCache`` binds a pool to the per-slot block-table rows the serve
engine ships to the device each decode step, plus the ``PrefixIndex`` that
hash-conses prompt-prefix blocks so identical prefixes share physical KV;
``gather_paged_kv`` is the naive gather oracle the paged Pallas kernel is
tested against.
"""
from repro.paging.block_pool import BlockEvent, BlockPool, BlockPoolExhausted
from repro.paging.paged_cache import (PagedKVCache, PrefixIndex,
                                      gather_paged_kv)

__all__ = ["BlockEvent", "BlockPool", "BlockPoolExhausted", "PagedKVCache",
           "PrefixIndex", "gather_paged_kv"]
