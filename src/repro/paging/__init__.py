"""Paged KV-cache subsystem: block-pool allocation + block-table caches.

``BlockPool`` is the host-side allocator (fixed-size KV blocks, free-list
alloc/free, per-sequence block tables, utilization/fragmentation stats);
``PagedKVCache`` binds a pool to the per-slot block-table rows the serve
engine ships to the device each decode step; ``gather_paged_kv`` is the
naive gather oracle the paged Pallas kernel is tested against.
"""
from repro.paging.block_pool import BlockPool, BlockPoolExhausted
from repro.paging.paged_cache import PagedKVCache, gather_paged_kv

__all__ = ["BlockPool", "BlockPoolExhausted", "PagedKVCache",
           "gather_paged_kv"]
