"""Trip-count-aware cost analysis over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE, so any
program built on ``lax.scan`` (layers, microbatches, attention chunks) is
under-reported by the loop trip counts. The compiled HLO text, however,
carries ``backend_config={"known_trip_count":{"n":...}}`` on every
counted-loop ``while`` op. This module re-derives the three roofline inputs
from the text with proper loop weighting:

  flops            - dot/dot_general (2 * prod(out) * prod(contracted)) and
                     convolution ops; elementwise flops are ignored (<1% for
                     the LM workloads here)
  bytes accessed   - XLA's own model: operands + outputs per top-level op
                     (fusions count their call-site operands/outputs, their
                     internals are register/VMEM traffic)
  collective bytes - payload (output bytes) of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute

Totals are computed per-computation, then composed through the call graph:
``fusion``/``call`` add their callee's flops at each call site; ``while``
multiplies (body + condition) by known_trip_count.

Validation: matches XLA cost_analysis on loop-free graphs and the 6*N*D
analytic count on transformer train steps (tests/test_hlo_cost.py).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
                "s4": 1, "u4": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
                "c64": 8, "c128": 16, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|pred|"
                       r"f8e4m3fn|f8e5m2|c64|c128|token)\[([0-9,]*)\]")

_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)")

_CALLED = re.compile(r"(?:calls=|to_apply=|body=)%([\w.\-]+)")
_COND = re.compile(r"condition=%([\w.\-]+)")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_list(txt: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(txt):
        shape = [int(d) for d in dims.split(",") if d]
        out.append((dt, shape))
    return out


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, shape in _shape_list(txt):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


@dataclasses.dataclass
class _Op:
    name: str
    out_shape: str
    kind: str
    rest: str


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[_Op]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._own: Dict[str, Cost] = {}
        self._total: Dict[str, Cost] = {}

    # -- parsing -------------------------------------------------------------
    def _parse(self, text: str):
        current = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->.*\{", stripped)
            if m and not stripped.startswith("//") and "=" not in stripped.split("(")[0]:
                current = m.group(2)
                self.computations[current] = []
                if m.group(1):
                    self.entry = current
                # parameters get shapes from the signature
                for pname, ptxt in re.findall(r"([\w.\-]+):\s*((?:\([^)]*\)|[^,)]+))",
                                              m.group(3)):
                    self.computations[current].append(
                        _Op(pname, ptxt, "parameter", ""))
                continue
            if current is None:
                continue
            if stripped == "}":
                current = None
                continue
            om = _OP_RE.match(stripped)
            if om:
                name, out_shape, kind, rest = om.groups()
                self.computations[current].append(_Op(name, out_shape, kind, rest))

    # -- per-computation costs -------------------------------------------------
    def _operand_shapes(self, comp: str, rest: str) -> List[str]:
        # operand names appear before the first "),"-terminated arg list
        arglist = rest.split("), ")[0] if "), " in rest else rest.rstrip(")")
        names = _OPERANDS.findall(arglist.split(" calls=")[0])
        table = {op.name: op.out_shape for op in self.computations[comp]}
        return [table[n] for n in names if n in table]

    def own_cost(self, comp: str) -> Cost:
        if comp in self._own:
            return self._own[comp]
        c = Cost()
        table = {op.name: op.out_shape for op in self.computations[comp]}
        for op in self.computations[comp]:
            k = op.kind
            if k in ("parameter", "constant", "get-tuple-element", "tuple",
                     "bitcast", "after-all", "partition-id", "replica-id",
                     # control-flow call sites: tuples are pointer-passed and
                     # the bodies' real traffic is added via the call graph
                     "while", "conditional", "call", "optimization-barrier"):
                continue
            out_b = _shape_bytes(op.out_shape)
            if k in ("dynamic-slice", "gather", "slice"):
                # XLA's model: reads only the sliced/gathered elements
                c.bytes += 2 * out_b
                continue
            if k in ("dynamic-update-slice",):
                # reads+writes only the update window (output aliases operand)
                operands = self._operand_shapes(comp, op.rest)
                upd = _shape_bytes(operands[1]) if len(operands) > 1 else out_b
                c.bytes += 2 * upd
                continue
            if k in ("broadcast", "iota", "constant"):
                c.bytes += out_b
                continue
            if k in ("dot", "dot_general"):
                operands = self._operand_shapes(comp, op.rest)
                lhs = operands[0] if operands else ""
                cm = _CONTRACT.search(op.rest)
                contracted = 1
                if cm and lhs:
                    lshape = _shape_list(lhs)
                    if lshape:
                        dims = lshape[0][1]
                        for idx in (int(i) for i in cm.group(1).split(",") if i):
                            if idx < len(dims):
                                contracted *= dims[idx]
                out_elems = 0
                for dt, shape in _shape_list(op.out_shape):
                    n = 1
                    for d in shape:
                        n *= d
                    out_elems += n
                c.flops += 2.0 * out_elems * contracted
                c.bytes += out_b + sum(_shape_bytes(s) for s in operands)
            elif k == "convolution":
                operands = self._operand_shapes(comp, op.rest)
                kern = operands[1] if len(operands) > 1 else ""
                kelems = 0
                for dt, shape in _shape_list(kern):
                    n = 1
                    for d in shape[:-1]:  # exclude output-feature dim (approx)
                        n *= d
                    kelems += n
                out_elems = sum(int(np_prod(s)) for _, s in _shape_list(op.out_shape))
                c.flops += 2.0 * out_elems * max(kelems, 1)
                c.bytes += out_b + sum(_shape_bytes(s) for s in operands)
            elif k in COLLECTIVES or any(k.startswith(cc) for cc in COLLECTIVES):
                base = k.replace("-start", "")
                if base.endswith("-done"):
                    continue
                for cc in COLLECTIVES:
                    if base.startswith(cc):
                        base = cc
                        break
                c.coll[base] = c.coll.get(base, 0.0) + out_b
                c.bytes += out_b + sum(_shape_bytes(s)
                                       for s in self._operand_shapes(comp, op.rest))
            elif k == "fusion":
                # bytes at call-site, but:
                #  - an operand whose only use inside the fusion is a
                #    (dynamic-)slice is physically read slice-sized
                #  - a fusion whose root is dynamic-update-slice writes only
                #    the update window (output aliases the target operand)
                callee = _CALLED.findall(op.rest)
                shapes = self._operand_shapes(comp, op.rest)
                sliced = self._sliced_params(callee[0]) if callee else {}
                c.bytes += min(self._dus_root_bytes(callee[0]) if callee
                               else float("inf"), out_b)
                for i, s in enumerate(shapes):
                    c.bytes += min(sliced.get(i, float("inf")), _shape_bytes(s))
            elif k in ("map", "reduce", "sort", "scatter",
                       "reduce-window", "select-and-scatter", "custom-call",
                       "async-start", "async-done"):
                # bytes at call-site; flops composed in total_cost
                c.bytes += out_b + sum(_shape_bytes(s)
                                       for s in self._operand_shapes(comp, op.rest))
            else:
                # plain elementwise / data-movement op at top level
                c.bytes += out_b + sum(_shape_bytes(s)
                                       for s in self._operand_shapes(comp, op.rest))
        self._own[comp] = c
        return c

    def _sliced_params(self, callee: str) -> Dict[int, int]:
        """{param_index: bytes actually read} for fusion params whose only
        consumers are slice-type ops inside the callee."""
        if not hasattr(self, "_sliced_cache"):
            self._sliced_cache: Dict[str, Dict[int, int]] = {}
        if callee in self._sliced_cache:
            return self._sliced_cache[callee]
        ops = self.computations.get(callee, [])
        params = [op for op in ops if op.kind == "parameter"]
        # order: XLA names fusion params param_0.., matching operand order
        def pidx(name):
            m = re.match(r"param_(\d+)", name)
            return int(m.group(1)) if m else None
        uses: Dict[str, List[Tuple[str, str]]] = {}
        for op in ops:
            if op.kind == "parameter":
                continue
            for ref in _OPERANDS.findall(op.rest.split(" calls=")[0]):
                uses.setdefault(ref, []).append((op.kind, op.out_shape))
        # params that are only the *target* of a dynamic-update-slice are
        # aliased in place: no read traffic at the call boundary
        dus_targets = set()
        for op in ops:
            if op.kind == "dynamic-update-slice":
                refs = _OPERANDS.findall(op.rest)
                if refs:
                    dus_targets.add(refs[0])
        out: Dict[int, int] = {}
        for p in params:
            i = pidx(p.name)
            if i is None:
                continue
            u = uses.get(p.name, [])
            if p.name in dus_targets:
                # in-place accumulation buffer: only slice-sized traffic even
                # if guarded by selects/converts
                out[i] = sum(2 * _shape_bytes(s) for k, s in u
                             if k in ("dynamic-slice", "slice", "gather"))
            elif u and all(k in ("dynamic-slice", "slice", "gather") for k, _ in u):
                out[i] = sum(2 * _shape_bytes(s) for _, s in u)
        self._sliced_cache[callee] = out
        return out

    def _dus_root_bytes(self, callee: str) -> float:
        """If the fusion's output is produced by dynamic-update-slice(s), the
        physical write is the update window(s), not the whole aliased buffer."""
        ops = self.computations.get(callee, [])
        if not ops:
            return float("inf")
        dus = [op for op in ops if op.kind == "dynamic-update-slice"]
        if not dus:
            return float("inf")
        root = ops[-1]
        if root.kind not in ("dynamic-update-slice", "tuple", "convert", "bitcast", "copy"):
            return float("inf")
        table = {op.name: op.out_shape for op in ops}
        total = 0.0
        for op in dus:
            refs = _OPERANDS.findall(op.rest)
            if len(refs) > 1 and refs[1] in table:
                total += 2.0 * _shape_bytes(table[refs[1]])
            else:
                return float("inf")
        return total

    def total_cost(self, comp: Optional[str] = None, _stack=()) -> Cost:
        comp = comp or self.entry or next(iter(self.computations))
        if comp in self._total:
            return self._total[comp]
        if comp in _stack:
            return Cost()
        total = Cost()
        total += self.own_cost(comp)
        for op in self.computations[comp]:
            called = _CALLED.findall(op.rest)
            if not called:
                continue
            if op.kind == "while":
                trip = 1
                tm = _TRIP.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                body = self.total_cost(called[0], _stack + (comp,))
                sub = body.scaled(trip)
                cond = _COND.search(op.rest)
                if cond:
                    sub += self.total_cost(cond.group(1), _stack + (comp,)).scaled(trip)
                total += sub
            elif op.kind in ("fusion", "call", "map", "conditional", "async-start"):
                for cal in called:
                    callee = self.total_cost(cal, _stack + (comp,))
                    # fusion internals don't touch HBM: take flops+colls only
                    total += Cost(callee.flops, 0.0 if op.kind == "fusion" else callee.bytes,
                                  dict(callee.coll))
            # reduce/scatter/sort to_apply bodies are scalar lambdas: ignore
        self._total[comp] = total
        return total


def np_prod(shape) -> float:
    n = 1
    for d in shape:
        n *= d
    return n


def analyze(hlo_text: str) -> Cost:
    """Trip-count-weighted (flops, bytes, collective bytes) for the entry."""
    return HloModule(hlo_text).total_cost()


def bytes_breakdown(hlo_text: str, n: int = 20):
    """The n largest REAL HBM-traffic contributors (op bytes x loop trips),
    restricted to computations whose bytes analyze() actually counts (entry,
    while bodies/conds, call/map bodies — NOT fusion internals)."""
    mod = HloModule(hlo_text)
    mult: Dict[str, float] = {}

    def walk(comp, m):
        mult[comp] = mult.get(comp, 0.0) + m
        for op in mod.computations[comp]:
            called = _CALLED.findall(op.rest)
            if not called:
                continue
            if op.kind == "fusion":
                continue  # fusion internals are not HBM traffic
            f = m
            if op.kind == "while":
                tm = _TRIP.search(op.rest)
                f = m * (int(tm.group(1)) if tm else 1)
            for cal in called:
                walk(cal, f)
            cm = _COND.search(op.rest)
            if cm:
                walk(cm.group(1), f)

    walk(mod.entry or next(iter(mod.computations)), 1.0)
    rows = []
    for comp, m in mult.items():
        if m == 0:
            continue
        for op in mod.computations[comp]:
            k = op.kind
            if k in ("parameter", "constant", "get-tuple-element", "tuple",
                     "bitcast", "after-all", "partition-id", "replica-id",
                     "while", "conditional", "call", "optimization-barrier"):
                continue
            out_b = _shape_bytes(op.out_shape)
            if k in ("dynamic-slice", "gather", "slice"):
                b = 2 * out_b
            elif k == "dynamic-update-slice":
                ops_ = mod._operand_shapes(comp, op.rest)
                b = 2 * (_shape_bytes(ops_[1]) if len(ops_) > 1 else out_b)
            elif k in ("broadcast", "iota"):
                b = out_b
            elif k == "fusion":
                callee = _CALLED.findall(op.rest)
                shapes = mod._operand_shapes(comp, op.rest)
                sliced = mod._sliced_params(callee[0]) if callee else {}
                b = min(mod._dus_root_bytes(callee[0]) if callee else float("inf"), out_b)
                b += sum(min(sliced.get(i, float("inf")), _shape_bytes(s))
                         for i, s in enumerate(shapes))
            else:
                b = out_b + sum(_shape_bytes(s) for s in mod._operand_shapes(comp, op.rest))
            if b:
                rows.append((b * m, b, m, comp, k, op.name))
    rows.sort(reverse=True)
    return rows[:n]


def top_ops(hlo_text: str, n: int = 15):
    """Debug: (flops, comp, op line) for the n costliest dots, weighted by the
    product of enclosing-loop trip counts; plus the n largest tensors."""
    mod = HloModule(hlo_text)
    # trip multiplier per computation via call graph walk
    mult: Dict[str, float] = {}

    def walk(comp, m):
        mult[comp] = mult.get(comp, 0.0) + m
        for op in mod.computations[comp]:
            called = _CALLED.findall(op.rest)
            if not called:
                continue
            f = m
            if op.kind == "while":
                tm = _TRIP.search(op.rest)
                f = m * (int(tm.group(1)) if tm else 1)
            for cal in called:
                if mult.get(cal, 0) < 1e12:  # guard
                    walk(cal, f)
            cm = _COND.search(op.rest)
            if cm:
                walk(cm.group(1), f)

    entry = mod.entry or next(iter(mod.computations))
    walk(entry, 1.0)
    dots, tensors = [], []
    for comp, ops in mod.computations.items():
        m = mult.get(comp, 0.0)
        if m == 0:
            continue
        table = {op.name: op.out_shape for op in ops}
        for op in ops:
            if op.kind in ("dot", "dot_general"):
                operands = mod._operand_shapes(comp, op.rest)
                lhs = operands[0] if operands else ""
                cmm = _CONTRACT.search(op.rest)
                contracted = 1
                if cmm and lhs:
                    ls = _shape_list(lhs)
                    if ls:
                        for idx in (int(i) for i in cmm.group(1).split(",") if i):
                            if idx < len(ls[0][1]):
                                contracted *= ls[0][1][idx]
                fl = 2.0 * sum(np_prod(s) for _, s in _shape_list(op.out_shape)) * contracted
                dots.append((fl * m, m, comp, op.name, op.out_shape[:80]))
            b = _shape_bytes(op.out_shape)
            if b > 0:
                tensors.append((b, m, comp, op.kind, op.name))
    dots.sort(reverse=True)
    tensors.sort(reverse=True)
    return dots[:n], tensors[:n]
