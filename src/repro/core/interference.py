"""Interference inference without privileged access (paper §4.1).

Android sandboxing denies /proc, so Swan infers interference purely from its
own observed step latency vs. the explored profile. Same mechanism here: an
EWMA of observed step time compared against the active choice's expected
latency. Severity > 0 means some co-tenant (foreground app there, co-tenant
job / straggling node here) wants the resources; the controller downgrades.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class InterferenceMonitor:
    expected_latency_s: float
    ewma_alpha: float = 0.3
    trigger_ratio: float = 1.25  # observed/expected above this => interference
    clear_ratio: float = 1.08  # below this => clear
    _ewma: Optional[float] = None

    def observe(self, latency_s: float) -> float:
        if self._ewma is None:
            self._ewma = latency_s
        else:
            self._ewma = (1 - self.ewma_alpha) * self._ewma + self.ewma_alpha * latency_s
        return self.severity

    @property
    def severity(self) -> float:
        """0 = clean; >0 = fractional slowdown beyond the trigger."""
        if self._ewma is None:
            return 0.0
        ratio = self._ewma / max(self.expected_latency_s, 1e-12)
        return max(0.0, ratio - 1.0)

    @property
    def interfering(self) -> bool:
        if self._ewma is None:
            return False
        return self._ewma / max(self.expected_latency_s, 1e-12) >= self.trigger_ratio

    @property
    def clear(self) -> bool:
        if self._ewma is None:
            return True
        return self._ewma / max(self.expected_latency_s, 1e-12) <= self.clear_ratio

    def rebase(self, expected_latency_s: float) -> None:
        """After migrating to a new choice, expectations change."""
        self.expected_latency_s = expected_latency_s
        self._ewma = None
