"""Profiling execution choices (paper §4.2).

SoC choices are profiled with the analytic device model (stands in for the
paper's few-batch on-device benchmarking; see core/energy.py). TPU mesh
choices are profiled via AOT compilation: ``jit(...).lower().compile()`` gives
FLOPs/bytes (cost_analysis) and the collective schedule (HLO text), from which
the three roofline terms and a latency/energy estimate are derived — the
work-conserving analogue of benchmarking a few batches, except no device time
is spent at all.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.core import energy as E
from repro.core.choices import CoreChoice, MeshChoice
from repro.core.cost import ChoiceProfile

# ---------------------------------------------------------------------------
# SoC analytic profiler (paper's local benchmarking)
# ---------------------------------------------------------------------------


def soc_throughput(choice: CoreChoice, model: E.SocModel, mem_intensity: float) -> float:
    """Effective GFLOP/s of a core combination for a given workload.

    - heterogenous combinations pace OMP barriers to the slowest core;
    - parallel overhead grows with thread count;
    - memory-bound fraction suffers the cache-thrash penalty (O2) that grows
      with the number of *threads sharing the cache*.
    """
    cores = [model.cores[c] for c in choice.cores]
    n = len(cores)
    slowest = min(c.gflops for c in cores)
    raw = slowest * n  # barrier-paced data parallelism
    raw /= 1.0 + model.parallel_overhead * (n - 1)
    thrash = 1.0 + model.thrash_coef * mem_intensity * (n - 1)
    return raw / thrash


def profile_soc_choice(choice: CoreChoice, model: E.SocModel, workload: str,
                       *, batches: int = 1) -> ChoiceProfile:
    gflops = E.WORKLOAD_GFLOPS_PER_STEP[workload]
    mem = E.WORKLOAD_MEM_INTENSITY[workload]
    thr = soc_throughput(choice, model, mem)
    latency = gflops / thr  # seconds per local step (batch 16)
    power = model.base_power_w + sum(model.cores[c].power_w for c in choice.cores)
    return ChoiceProfile(
        choice=choice, latency_s=latency * batches, energy_j=power * latency * batches,
        power_w=power, cost_key=choice.cost_key(model),
        meta={"workload": workload, "throughput_gflops": thr})


def greedy_baseline_profile(model: E.SocModel, workload: str) -> ChoiceProfile:
    """PyTorch default: one thread per low-latency core, no affinity pinning
    (paper §5.1 baseline). Unpinned threads migrate => migration_penalty."""
    classes = model.classes()
    fast = classes.get("big", ()) + classes.get("prime", ())
    choice = CoreChoice(fast, model.name)
    prof = profile_soc_choice(choice, model, workload)
    lat = prof.latency_s * model.migration_penalty
    # during migration stalls the cores idle, so average power drops
    core_w = sum(model.cores[c].power_w for c in choice.cores)
    power = model.base_power_w + core_w / model.migration_penalty
    return ChoiceProfile(choice=choice, latency_s=lat, energy_j=power * lat,
                         power_w=power, cost_key=choice.cost_key(model),
                         meta={"workload": workload, "baseline": True})


# ---------------------------------------------------------------------------
# TPU AOT profiler
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*(\([^)]*\)|[a-z0-9_\[\],{}/ ]+?)\s", re.I)

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
                "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(txt: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output-shape bytes of every collective op in an HLO dump.

    Works on post-SPMD-partitioning HLO (per-device shapes), so the totals are
    per-device collective payload — the right operand for the collective
    roofline term.
    """
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?[%\w.\-]*\s*=\s*(\([^)]*\)|\S+)\s+(all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)", line)
        if not m:
            continue
        kind = m.group(2).lower()
        if line.split("=")[0].strip().endswith("-done"):
            continue
        shape_txt = m.group(1)
        b = _shape_bytes(shape_txt)
        if b:
            out[kind] = out.get(kind, 0) + b
    return out


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes: float
    per_device_memory: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def latency_s(self) -> float:
        # overlap model: compute overlaps memory (roofline max);
        # collectives partially overlap (conservative: max with sum/2)
        base = max(self.compute_s, self.memory_s)
        return max(base, self.collective_s) + 0.5 * min(base, self.collective_s)


def roofline_from_compiled(compiled, lowered_text: Optional[str], n_chips: int,
                           compression_ratio: float = 1.0) -> RooflineTerms:
    """cost_analysis() on a compiled SPMD executable reports PER-DEVICE
    flops/bytes (verified empirically: an 8-way batch-sharded matmul reports
    total/8). The per-device HLO's collective shapes are likewise per-device.
    So each roofline term is per_device_quantity / per_chip_rate — numerically
    identical to the assignment's global/(chips*rate) formulas. ``flops`` and
    ``bytes_accessed`` in the result are GLOBAL (= per-device * n_chips) for
    reporting."""
    from repro.core.hlo_cost import analyze
    if lowered_text is None:
        lowered_text = compiled.as_text()
    cost = analyze(lowered_text)  # trip-count-weighted (XLA's counts scans once)
    flops_dev = cost.flops
    bytes_dev = cost.bytes
    coll_dev = int(cost.collective_bytes * compression_ratio)
    mem_stats = compiled.memory_analysis()
    per_dev = int(getattr(mem_stats, "temp_size_in_bytes", 0)
                  + getattr(mem_stats, "argument_size_in_bytes", 0)
                  + getattr(mem_stats, "output_size_in_bytes", 0)
                  - getattr(mem_stats, "alias_size_in_bytes", 0))
    return RooflineTerms(
        compute_s=flops_dev / E.TPU_PEAK_FLOPS,
        memory_s=bytes_dev / E.TPU_HBM_BW,
        collective_s=coll_dev / E.TPU_ICI_BW,
        flops=flops_dev * n_chips, bytes_accessed=bytes_dev * n_chips,
        collective_bytes=coll_dev * n_chips,
        per_device_memory=per_dev)


def profile_mesh_choice(choice: MeshChoice, compiled, lowered_text: str,
                        compression_ratio: float = 1.0) -> ChoiceProfile:
    terms = roofline_from_compiled(compiled, lowered_text, choice.n_chips,
                                   compression_ratio)
    lat = terms.latency_s
    util = terms.compute_s / max(lat, 1e-12)
    power = E.tpu_power(util) * choice.n_chips
    return ChoiceProfile(
        choice=choice, latency_s=lat, energy_j=power * lat, power_w=power,
        cost_key=choice.cost_key(), memory_bytes=terms.per_device_memory,
        meta={"terms": terms, "utilization": util})
