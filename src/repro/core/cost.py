"""Swan §4.3: cost ordering + pruning of dominated execution choices.

Rules (paper, quoted):
  1. using more cores of the same type is costlier,
  2. any number of low-latency cores is costlier than any number of
     low-power cores,
  3. Prime cores are costlier than low-latency cores.

Both choice kinds encode these as a lexicographic ``cost_key()``; pruning then
removes every choice that is dominated — i.e. some other choice is at least as
fast AND at least as cheap (one strictly) — so every surviving "downgrade"
genuinely relinquishes compute while every survivor offers a real
latency/cost trade-off (this is what removes ShuffleNet's 4-core choice, O2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class ChoiceProfile:
    """One explored execution choice + its measured/estimated profile."""
    choice: Any
    latency_s: float  # per local step
    energy_j: float  # per local step
    power_w: float
    cost_key: Tuple
    memory_bytes: int = 0  # per-device peak (TPU choices)
    meta: Optional[dict] = None

    @property
    def name(self) -> str:
        return getattr(self.choice, "name", str(self.choice))


def total_order(profiles: Sequence[ChoiceProfile]) -> List[ChoiceProfile]:
    """Sort by increasing expected training time (paper §4.3 step 1)."""
    return sorted(profiles, key=lambda p: (p.latency_s, p.cost_key))


def pareto_prune(profiles: Sequence[ChoiceProfile]) -> List[ChoiceProfile]:
    """Drop choices dominated on (latency, cost_key).

    Walk in increasing-latency order keeping the running cheapest cost; a
    choice survives only if it is strictly cheaper than everything faster
    than it (equivalently: each successive survivor must relinquish
    resources). The fastest choice always survives.
    """
    ordered = total_order(profiles)
    kept: List[ChoiceProfile] = []
    best_cost: Optional[Tuple] = None
    for p in ordered:
        if best_cost is None or p.cost_key < best_cost:
            kept.append(p)
            best_cost = p.cost_key
    return kept


def ladder(profiles: Sequence[ChoiceProfile]) -> List[ChoiceProfile]:
    """Pruned choices as a downgrade ladder: fastest/costliest first."""
    return pareto_prune(profiles)


def pick_fastest(profiles: Sequence[ChoiceProfile],
                 *, memory_limit: Optional[int] = None,
                 energy_budget_j: Optional[float] = None) -> ChoiceProfile:
    """The choice Swan runs under no interference (paper §4.3)."""
    feasible = [p for p in profiles
                if (memory_limit is None or p.memory_bytes <= memory_limit)
                and (energy_budget_j is None or p.energy_j <= energy_budget_j)]
    if not feasible:
        raise ValueError("no feasible execution choice under the given constraints")
    return total_order(feasible)[0]


def ladder_sensitivities(n: int, *, head: float = 1.0, floor: float = 0.1,
                         decay: float = 0.4) -> List[float]:
    """Interference sensitivity by ladder position (fastest first).

    The pruning invariant (each survivor relinquishes resources the faster
    ones hold) means each downgrade overlaps less with a co-tenant's demand;
    model that as geometric decay toward a floor. engine/rungs.py uses this to
    turn a ChoiceProfile ladder into Rungs whose simulated interference
    shrinks as the engine steps down — the mechanism behind Table 3's
    foreground-impact recovery.
    """
    return [max(floor, head * decay ** i) for i in range(max(n, 1))]


def pick_most_efficient(profiles: Sequence[ChoiceProfile],
                        *, memory_limit: Optional[int] = None) -> ChoiceProfile:
    feasible = [p for p in profiles
                if memory_limit is None or p.memory_bytes <= memory_limit]
    if not feasible:
        raise ValueError("no feasible execution choice")
    return min(feasible, key=lambda p: p.energy_j)
