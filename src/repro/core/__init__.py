from repro.core.choices import CoreChoice, MeshChoice, enumerate_core_choices, enumerate_mesh_choices  # noqa: F401
from repro.core.cost import ChoiceProfile, ladder, pareto_prune, pick_fastest  # noqa: F401
from repro.core.controller import SwanController  # noqa: F401
from repro.core.planner import SwanPlan, explore_soc, plan_from_profiles  # noqa: F401
