"""The Swan planner: explore -> prune -> order -> select (+ fleet amortization).

``explore_soc`` is the paper's on-device exploration: one unexplored choice is
benchmarked per training request (work-conserving: the benchmark batches are
real training). ``fleet_explore`` is §4.2's coordinator amortization: the
choice list is partitioned among devices of the same SoC model, and the merged
profiles are shipped to every device, so each user bears 1/N of the
exploration cost and new devices skip it entirely.

``SwanPlan`` is what a device (or pod) runs with: the pruned ladder plus the
selected operating point.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

from repro.core import energy as E
from repro.core.choices import CoreChoice, enumerate_core_choices
from repro.core.controller import SwanController
from repro.core.cost import (ChoiceProfile, ladder, pareto_prune, pick_fastest,
                             pick_most_efficient, total_order)
from repro.core.profiler import greedy_baseline_profile, profile_soc_choice


@dataclasses.dataclass
class SwanPlan:
    workload: str
    device: str
    profiles: List[ChoiceProfile]  # all explored
    ladder: List[ChoiceProfile]  # pruned, fastest first
    selected: ChoiceProfile

    def controller(self, **kw) -> SwanController:
        return SwanController(self.ladder, **kw)

    def rung_ladder(self, **overrides):
        """The pruned ladder as executable engine Rungs (MeshChoice-backed
        plans only — SoC CoreChoices have no jittable step)."""
        from repro.engine.rungs import rungs_from_ladder
        return rungs_from_ladder(self.ladder, **overrides)

    @property
    def explored_names(self) -> List[str]:
        return [p.name for p in self.profiles]


class ExplorationState:
    """Per-device incremental exploration (paper §4.1 'Monitoring' +
    'Exploring Execution Choices'): explores only while idle & discharging."""

    def __init__(self, choices: Sequence, profiler: Callable):
        self.pending = list(choices)
        self.profiler = profiler
        self.done: List[ChoiceProfile] = []

    @property
    def complete(self) -> bool:
        return not self.pending

    def explore_one(self, *, idle: bool = True, discharging: bool = True) -> Optional[ChoiceProfile]:
        if not idle or not discharging or self.complete:
            return None
        choice = self.pending.pop(0)
        prof = self.profiler(choice)
        self.done.append(prof)
        return prof


def explore_soc(device: str, workload: str,
                choices: Optional[Sequence[CoreChoice]] = None) -> SwanPlan:
    model = E.SOC_MODELS[device]
    choices = choices if choices is not None else enumerate_core_choices(model)
    profiles = [profile_soc_choice(c, model, workload) for c in choices]
    lad = ladder(profiles)
    return SwanPlan(workload=workload, device=device, profiles=profiles,
                    ladder=lad, selected=pick_fastest(profiles))


def fleet_explore(device: str, workload: str, n_devices: int) -> Dict[int, List[str]]:
    """§4.2 coordinator amortization: split the choice list among same-model
    devices; returns {device_rank: [choice names to explore]}."""
    model = E.SOC_MODELS[device]
    choices = enumerate_core_choices(model)
    assignment: Dict[int, List[str]] = {i: [] for i in range(n_devices)}
    for i, c in enumerate(choices):
        assignment[i % n_devices].append(c.name)
    return assignment


def merge_fleet_profiles(parts: Sequence[Sequence[ChoiceProfile]]) -> List[ChoiceProfile]:
    """Merge per-device exploration shards (dedupe by choice name, keep the
    median-latency report to resist stragglers/outliers)."""
    by_name: Dict[str, List[ChoiceProfile]] = {}
    for shard in parts:
        for p in shard:
            by_name.setdefault(p.name, []).append(p)
    merged = []
    for name, ps in by_name.items():
        ps = sorted(ps, key=lambda p: p.latency_s)
        merged.append(ps[len(ps) // 2])
    return total_order(merged)


def plan_from_profiles(workload: str, device: str,
                       profiles: Sequence[ChoiceProfile],
                       *, objective: str = "fastest",
                       memory_limit: Optional[int] = None) -> SwanPlan:
    lad = ladder(list(profiles))
    sel = (pick_most_efficient(profiles, memory_limit=memory_limit)
           if objective == "efficient"
           else pick_fastest(profiles, memory_limit=memory_limit))
    return SwanPlan(workload=workload, device=device, profiles=list(profiles),
                    ladder=lad, selected=sel)
