"""Execution choices — the objects Swan explores, prunes and migrates between.

Two concrete kinds behind one protocol:

- CoreChoice: a subset of SoC CPU cores (the paper's original choice space).
- MeshChoice: a (pod, data, model) submesh + sharding recipe + microbatch +
  remat + compression on a TPU fleet (the TPU-native choice space, DESIGN.md
  §2). The recipe rebinds the logical-axis rules in models/sharding.py, which
  is how a choice changes distribution without touching model code.

Both expose ``cost_key()`` — the Swan §4.3 total order (see core/cost.py).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

from repro.core.energy import SocModel

# ---------------------------------------------------------------------------
# SoC core combinations (paper-original)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CoreChoice:
    cores: Tuple[int, ...]  # core ids, e.g. (4,5,6,7)
    soc: str  # SocModel name

    @property
    def name(self) -> str:
        return "".join(str(c) for c in self.cores)

    def counts(self, model: SocModel) -> Tuple[int, int, int]:
        """(n_prime, n_big, n_little) used."""
        np_ = nb = nl = 0
        for c in self.cores:
            kind = model.cores[c].name
            np_ += kind == "prime"
            nb += kind == "big"
            nl += kind == "little"
        return np_, nb, nl

    def cost_key(self, model: SocModel) -> Tuple:
        # Swan §4.3: prime > big > little (lexicographic), more cores costlier
        return self.counts(model)


def enumerate_core_choices(model: SocModel) -> List[CoreChoice]:
    """The paper's §4.2/Appendix-B state space: contiguous prefixes within
    each class plus class-combining choices (not the full 2^8 powerset)."""
    classes = model.classes()
    out: List[CoreChoice] = []
    little = classes.get("little", ())
    big = classes.get("big", ())
    prime = classes.get("prime", ())
    fast = big + prime
    for k in range(1, len(little) + 1):  # 0, 01, 012, 0123
        out.append(CoreChoice(little[:k], model.name))
    for k in range(1, len(fast) + 1):  # 4, 45, 456, 4567
        out.append(CoreChoice(fast[:k], model.name))
    if prime:  # prime-only and prime+big pairs
        out.append(CoreChoice(prime, model.name))
        if big:
            out.append(CoreChoice((big[0],) + prime, model.name))
    if little and fast:  # all-cores
        out.append(CoreChoice(little + fast, model.name))
    # dedupe, keep deterministic order
    seen, uniq = set(), []
    for c in out:
        if c.cores not in seen:
            seen.add(c.cores)
            uniq.append(c)
    return uniq


# ---------------------------------------------------------------------------
# TPU mesh choices
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshChoice:
    mesh_shape: Tuple[int, ...]  # (data, model) or (pod, data, model)
    axis_names: Tuple[str, ...]
    microbatch: int = 1  # gradient-accumulation steps
    remat: str = "none"  # none | dots | full
    compression: str = "none"  # optim/compression scheme for cross-pod reduce
    prime_pod: bool = True  # occupies the serving-priority pod?
    seq_shard: bool = False  # sequence parallelism for activations
    moe_cf: float = 1.25
    chunk: int = 1024  # attention KV chunk
    wide_ep: bool = False  # experts sharded over (model x data); tokens move
    attn_impl: str = "chunked"  # attention kernel: chunked (jnp) | pallas

    @property
    def name(self) -> str:
        mesh = "x".join(map(str, self.mesh_shape))
        tags = [f"mb{self.microbatch}", f"remat-{self.remat}"]
        if self.compression != "none":
            tags.append(self.compression)
        if self.seq_shard:
            tags.append("sp")
        if self.wide_ep:
            tags.append("wide-ep")
        if self.attn_impl != "chunked":
            tags.append(f"attn-{self.attn_impl}")
        return f"{mesh}[{','.join(tags)}]"

    @property
    def n_chips(self) -> int:
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n

    @property
    def tp_degree(self) -> int:
        if "model" in self.axis_names:
            return self.mesh_shape[self.axis_names.index("model")]
        return 1

    def cost_key(self) -> Tuple:
        # Swan §4.3 adapted (DESIGN.md §2): occupying the serving-priority
        # ("prime") pod is costliest, then total chips, then TP degree
        # (TP holds ICI links hostage; relinquishing them helps co-tenants).
        return (int(self.prime_pod), self.n_chips, self.tp_degree)

    def rung_fields(self) -> dict:
        """The executable subset of this choice — what engine.rungs.Rung can
        switch live (mesh-shape switches cost a checkpoint round-trip; the
        rest migrate in place)."""
        return {"microbatch": self.microbatch, "attn_impl": self.attn_impl,
                "mesh_shape": self.mesh_shape, "chunk": self.chunk,
                "remat": self.remat, "compression": self.compression}

    def rules(self) -> dict:
        """Logical-axis rule set for models/sharding.py."""
        has_pod = "pod" in self.axis_names
        batch = ("pod", "data") if has_pod else ("data",)
        return {
            "batch": batch,
            "seq": "model" if self.seq_shard else None,
            "fsdp": "data",
            "tp": "model",
            "ep": ("model", "data") if self.wide_ep else "model",
            "kvseq": "model",
        }


def enumerate_mesh_choices(total_chips: int = 256, *, multi_pod: bool = False,
                           microbatches=(1, 4, 16), remats=("none", "dots", "full"),
                           max_tp: int = 64,
                           attn_impls=("chunked",)) -> List[MeshChoice]:
    """The TPU execution-choice state space for one pod (or two).

    ``attn_impls`` widens the space along the kernel dimension — pass
    ``("chunked", "pallas")`` to let the planner trade the jnp online-softmax
    fallback against the fused Pallas flash kernels per choice.
    """
    out: List[MeshChoice] = []
    shapes = []
    chips = total_chips
    while chips >= max(total_chips // 8, 8):
        tp = 1
        while tp <= min(max_tp, chips):
            if chips % tp == 0:
                shapes.append((chips // tp, tp))
            tp *= 2
        chips //= 2
    for (dp, tp), mb, rm, ai in itertools.product(shapes, microbatches, remats,
                                                  attn_impls):
        if multi_pod:
            out.append(MeshChoice((2, dp, tp), ("pod", "data", "model"),
                                  microbatch=mb, remat=rm, attn_impl=ai))
        else:
            out.append(MeshChoice((dp, tp), ("data", "model"),
                                  microbatch=mb, remat=rm, attn_impl=ai,
                                  prime_pod=(dp * tp == total_chips)))
    return out
