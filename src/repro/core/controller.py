"""Swan's control loop (paper Fig. 4b): migrate between pruned choices.

Downgrade on inferred interference (relinquish compute to the interferer),
upgrade after a sustained clear window (hysteresis avoids flapping). The
ladder comes from core/cost.pareto_prune, so each downgrade step is guaranteed
to free resources the interferer wants — that is the invariant pruning buys.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.core.cost import ChoiceProfile
from repro.core.interference import InterferenceMonitor


@dataclasses.dataclass
class Migration:
    step: int
    from_idx: int
    to_idx: int
    reason: str


class SwanController:
    def __init__(self, ladder: List[ChoiceProfile], *, upgrade_patience: int = 5,
                 on_migrate: Optional[Callable] = None):
        if not ladder:
            raise ValueError("empty choice ladder")
        self.ladder = ladder  # index 0 = fastest/costliest
        self.idx = 0
        self.upgrade_patience = upgrade_patience
        self.on_migrate = on_migrate
        self.monitor = InterferenceMonitor(ladder[0].latency_s)
        self.migrations: List[Migration] = []
        self._clear_streak = 0
        self._step = 0
        self._skip_next = False

    @property
    def active(self) -> ChoiceProfile:
        return self.ladder[self.idx]

    def can_downgrade(self) -> bool:
        return self.idx + 1 < len(self.ladder)

    def can_upgrade(self) -> bool:
        return self.idx > 0

    def _migrate(self, new_idx: int, reason: str):
        if new_idx == self.idx:
            return
        self.migrations.append(Migration(self._step, self.idx, new_idx, reason))
        self.idx = new_idx
        self.monitor.rebase(self.active.latency_s)
        self._clear_streak = 0
        # the first sample on the new choice carries the migration's own tail
        # (compile, remesh transfer); observing it would re-anchor the monitor
        # on a one-off spike and immediately re-migrate
        self._skip_next = True
        if self.on_migrate:
            self.on_migrate(self.active, reason)

    def propose(self, observed_latency_s: float) -> Optional[str]:
        """Feed one observed local-step latency and return what this choice's
        monitor *wants* — ``"down"``, ``"up"`` or ``None`` — without
        migrating. An arbiter (engine/runtime.SwanRuntime) collects proposals
        across co-tenant jobs and commits at most one; a vetoed proposal
        keeps its monitor state, so persistent pressure re-proposes next
        step. The first sample after a migration is skipped (see _migrate)."""
        self._step += 1
        if self._skip_next:
            self._skip_next = False
            return None
        self.monitor.observe(observed_latency_s)
        if self.monitor.interfering:
            # a pressured step never counts toward the upgrade patience —
            # even when the proposal is vetoed or the ladder is bottomed out
            self._clear_streak = 0
            return "down" if self.can_downgrade() else None
        if self.monitor.clear:
            self._clear_streak += 1
            if self._clear_streak >= self.upgrade_patience and self.can_upgrade():
                return "up"
        else:
            self._clear_streak = 0
        return None

    def note_external_skip(self) -> None:
        """The caller discarded a post-migration sample itself (e.g. the
        session's wall-clock warmup-step skip); don't drop a second, clean
        sample on top of it."""
        self._skip_next = False

    def commit(self, direction: str, reason: str) -> ChoiceProfile:
        """Apply a proposal (the arbiter's accept path)."""
        if direction == "down" and self.can_downgrade():
            self._migrate(self.idx + 1, reason)
        elif direction == "up" and self.can_upgrade():
            self._migrate(self.idx - 1, reason)
        return self.active

    def observe_step(self, observed_latency_s: float) -> ChoiceProfile:
        """Feed one observed local-step latency; returns the (possibly new)
        active choice for the next step (propose + self-commit — the
        single-job path with no arbiter in the loop)."""
        proposal = self.propose(observed_latency_s)
        if proposal == "down":
            return self.commit("down", "interference")
        if proposal == "up":
            return self.commit("up", "clear")
        return self.active

    def force_downgrade(self, reason: str = "external") -> ChoiceProfile:
        """Hard interference (device loss / preemption notice)."""
        if self.can_downgrade():
            self._migrate(self.idx + 1, reason)
        return self.active

    def calibrate(self, latency_s: float) -> None:
        """Install a *measured* clean-step latency as the active choice's
        expectation. Live engines (engine/session.py) measure real step
        times; ladder profiles only seed the estimate, so the first clean
        steps on each rung re-anchor the monitor here."""
        self.monitor.rebase(latency_s)
