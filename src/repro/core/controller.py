"""Swan's control loop (paper Fig. 4b): migrate between pruned choices.

Downgrade on inferred interference (relinquish compute to the interferer),
upgrade after a sustained clear window (hysteresis avoids flapping). The
ladder comes from core/cost.pareto_prune, so each downgrade step is guaranteed
to free resources the interferer wants — that is the invariant pruning buys.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.core.cost import ChoiceProfile
from repro.core.interference import InterferenceMonitor


@dataclasses.dataclass
class Migration:
    step: int
    from_idx: int
    to_idx: int
    reason: str


class SwanController:
    def __init__(self, ladder: List[ChoiceProfile], *, upgrade_patience: int = 5,
                 on_migrate: Optional[Callable] = None):
        if not ladder:
            raise ValueError("empty choice ladder")
        self.ladder = ladder  # index 0 = fastest/costliest
        self.idx = 0
        self.upgrade_patience = upgrade_patience
        self.on_migrate = on_migrate
        self.monitor = InterferenceMonitor(ladder[0].latency_s)
        self.migrations: List[Migration] = []
        self._clear_streak = 0
        self._step = 0

    @property
    def active(self) -> ChoiceProfile:
        return self.ladder[self.idx]

    def _migrate(self, new_idx: int, reason: str):
        if new_idx == self.idx:
            return
        self.migrations.append(Migration(self._step, self.idx, new_idx, reason))
        self.idx = new_idx
        self.monitor.rebase(self.active.latency_s)
        self._clear_streak = 0
        if self.on_migrate:
            self.on_migrate(self.active, reason)

    def observe_step(self, observed_latency_s: float) -> ChoiceProfile:
        """Feed one observed local-step latency; returns the (possibly new)
        active choice for the next step."""
        self._step += 1
        self.monitor.observe(observed_latency_s)
        if self.monitor.interfering and self.idx + 1 < len(self.ladder):
            self._migrate(self.idx + 1, "interference")
        elif self.monitor.clear:
            self._clear_streak += 1
            if self._clear_streak >= self.upgrade_patience and self.idx > 0:
                self._migrate(self.idx - 1, "clear")
        else:
            self._clear_streak = 0
        return self.active

    def force_downgrade(self, reason: str = "external") -> ChoiceProfile:
        """Hard interference (device loss / preemption notice)."""
        if self.idx + 1 < len(self.ladder):
            self._migrate(self.idx + 1, reason)
        return self.active

    def calibrate(self, latency_s: float) -> None:
        """Install a *measured* clean-step latency as the active choice's
        expectation. Live engines (engine/session.py) measure real step
        times; ladder profiles only seed the estimate, so the first clean
        steps on each rung re-anchor the monitor here."""
        self.monitor.rebase(latency_s)
