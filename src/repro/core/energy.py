"""Hardware, power and energy models.

Two device classes:

1. TPU v5e (the dry-run/roofline target): published peak numbers from the
   assignment spec; chip power is a simple idle+dynamic model used for the
   energy term of TPU execution-choice profiles.

2. Smartphone SoCs (the paper's §5 devices): per-core-class throughput/power
   synthesized to reproduce the paper's published *relative* behavior —
   Fig. 1b core ordering, Fig. 2's power<->energy inversion (O1), the
   depthwise cache-thrash slowdown (O2), and Table 2's speedup bands (O3).
   GreenHub raw data and the physical phones are unavailable; constants are
   calibrated so benchmarks land inside the paper's reported ranges, which is
   the strongest reproduction available (DESIGN.md §8). The baseline's lack
   of affinity pinning appears as ``migration_penalty`` (the paper's own
   implementation insight: Swan pins threads via sched_setaffinity, stock
   PyTorch does not).

Energy-loan accounting (paper §5.1 "Real-world energy budget"): daily charger
income and daily non-FL usage are fixed per device; the loan tracks FL energy
and a device is unavailable whenever trace_level - loan would cross the
critical battery level.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

# --- TPU v5e (assignment constants) ----------------------------------------
TPU_PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
TPU_HBM_BW = 819e9       # B/s per chip
TPU_ICI_BW = 50e9        # B/s per link
TPU_HBM_BYTES = 16 * 1024 ** 3
TPU_POWER_IDLE_W = 70.0
TPU_POWER_PEAK_W = 220.0


def tpu_power(utilization: float) -> float:
    u = min(max(utilization, 0.0), 1.0)
    return TPU_POWER_IDLE_W + (TPU_POWER_PEAK_W - TPU_POWER_IDLE_W) * u


# --- Smartphone SoC models ---------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoreClass:
    name: str  # little | big | prime
    gflops: float  # effective matmul throughput per core
    power_w: float  # active power per core


@dataclasses.dataclass(frozen=True)
class SocModel:
    name: str
    cores: Tuple[CoreClass, ...]  # one entry PER core, index = core id
    base_power_w: float  # screen-off platform power
    battery_j: float
    thrash_coef: float  # depthwise cache-thrash coefficient (device-specific)
    migration_penalty: float  # unpinned-baseline slowdown (1.0 = none)
    parallel_overhead: float = 0.04  # OMP sync cost per extra thread

    @property
    def core_ids(self) -> Tuple[int, ...]:
        return tuple(range(len(self.cores)))

    def classes(self) -> Dict[str, Tuple[int, ...]]:
        out: Dict[str, list] = {}
        for i, c in enumerate(self.cores):
            out.setdefault(c.name, []).append(i)
        return {k: tuple(v) for k, v in out.items()}


def _soc(name, n_little, little_gf, n_big, big_gf, n_prime, prime_gf,
         little_w, big_w, prime_w, base_w, battery_j, thrash, mig):
    cores = tuple([CoreClass("little", little_gf, little_w)] * n_little
                  + [CoreClass("big", big_gf, big_w)] * n_big
                  + [CoreClass("prime", prime_gf, prime_w)] * n_prime)
    return SocModel(name, cores, base_w, battery_j, thrash, mig)


# Calibrated per DESIGN.md §8; relative core ordering follows paper Fig. 1b,
# thrash/migration constants solved in closed form against Table 2 speedups.
SOC_MODELS: Dict[str, SocModel] = {
    "pixel3": _soc("pixel3", 4, 0.5, 4, 3.2, 0, 0.0,
                   0.25, 1.6, 0.0, 0.8, 40e3, thrash=2.01, mig=1.0),
    "s10e": _soc("s10e", 4, 0.55, 3, 5.5, 1, 6.5,
                 0.3, 2.0, 3.5, 0.9, 43e3, thrash=20.4, mig=2.1),
    "oneplus8": _soc("oneplus8", 4, 0.6, 3, 6.0, 1, 7.2,
                     0.3, 2.1, 3.6, 0.9, 60e3, thrash=8.56, mig=2.1),
    "mi10": _soc("mi10", 4, 0.6, 3, 6.1, 1, 7.3,
                 0.3, 2.1, 3.6, 0.9, 66e3, thrash=8.68, mig=2.1),
    "tab_s6": _soc("tab_s6", 4, 0.55, 3, 5.6, 1, 6.6,
                   0.3, 2.0, 3.5, 1.0, 98e3, thrash=12.0, mig=1.9),
}

# workload memory-intensity (fraction of time in depthwise-like memory-bound
# ops; drives O2): resnet is matmul-dominated, shuffle/mobile are depthwise.
WORKLOAD_MEM_INTENSITY = {"resnet34": 0.01, "mobilenet-v2": 0.733, "shufflenet-v2": 0.9}
# per-sample forward+backward GFLOPs at batch 16 (relative scale is what matters)
WORKLOAD_GFLOPS_PER_STEP = {"resnet34": 18.0, "mobilenet-v2": 2.5, "shufflenet-v2": 1.9}


# --- battery / energy loan ----------------------------------------------------

@dataclasses.dataclass
class EnergyLoan:
    """Paper §5.1: fixed daily charger income & usage; FL energy is a loan.

    The device is unavailable whenever applying the loan to the trace's
    battery level would put it below the critical level.
    """
    battery_j: float
    daily_charge_j: float
    daily_usage_j: float
    critical_frac: float = 0.15
    loan_j: float = 0.0

    def borrow(self, joules: float) -> None:
        self.loan_j += joules

    def repay(self, joules: float) -> None:
        """Pay the loan down by ``joules`` (a charger tick; the runtime calls
        this while a ChargingTrace is active). The loan never goes negative —
        charging beyond the loan tops the battery, it does not bank credit."""
        self.loan_j = max(0.0, self.loan_j - max(joules, 0.0))

    def repay_daily(self) -> None:
        surplus = max(self.daily_charge_j - self.daily_usage_j, 0.0)
        self.loan_j = max(0.0, self.loan_j - surplus)

    def available(self, trace_level_frac: float) -> bool:
        effective = trace_level_frac - self.loan_j / self.battery_j
        return effective > self.critical_frac
